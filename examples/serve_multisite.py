"""Serve batched requests across two wind-site engines via Heron weights.

A real (CPU-scale) end-to-end serving pass: reduced llama3.2 replicas
behind the Heron planning layer — Planner-L's WRR weights steer actual
requests into two continuous-batching ServingEngines.

    PYTHONPATH=src python examples/serve_multisite.py [--requests 32]
"""
import argparse

from repro.launch.serve import serve_demo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--sites", type=int, default=2)
    ap.add_argument("--arch", default="llama3.2-1b")
    args = ap.parse_args()
    out = serve_demo(arch=args.arch, num_requests=args.requests,
                     num_sites=args.sites)
    assert out["completed"] == out["submitted"]


if __name__ == "__main__":
    main()
