"""Serve a burst of requests across two wind-site engines via Heron weights.

A real (CPU-scale) end-to-end serving pass: reduced llama3.2 replicas
behind the Heron planning layer — Planner-L's WRR weights steer actual
requests into two continuous-batching ServingEngines. Requests arrive as
one burst (the shape power-drop rerouting produces), exercising the
batched admission pipeline: grouped power-of-2 prefills + chunked
prefill-from-cache tails. ``--admit-mode serial`` runs the
one-request-at-a-time reference for an A/B.

    PYTHONPATH=src python examples/serve_multisite.py [--requests 32]
"""
import argparse

from repro.launch.serve import serve_demo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--sites", type=int, default=2)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--admit-mode", choices=("batched", "serial"),
                    default="batched")
    ap.add_argument("--admit-budget", type=int, default=None)
    args = ap.parse_args()
    out = serve_demo(arch=args.arch, num_requests=args.requests,
                     num_sites=args.sites, admit_mode=args.admit_mode,
                     admit_token_budget=args.admit_budget)
    assert out["completed"] == out["submitted"]
    for s in out["per_site"]:
        # the tails are the interesting part under bursts: admission cost
        # lands in p99 TTFT long before it moves the mean
        assert s["p99_ttft"] >= s["p50_ttft"] >= 0.0


if __name__ == "__main__":
    main()
