"""Train a ~100M-parameter llama-family model for a few hundred steps.

Runs the real training substrate (AdamW + microbatching + async atomic
checkpoints + restart) on CPU with a width-reduced llama3.2 config whose
parameter count lands near 100M. On a TPU fleet the same loop runs the
full config under the production mesh (launch/train.py --full).

    PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import tempfile

from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="heron_ckpt_")

    out = train_loop(
        arch="llama3.2-1b",
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        reduce_cfg=True,
        d_model=768, num_layers=12,     # ~90-100M params (reduced vocab)
        lr=1e-3,
        num_microbatches=2,
        ckpt_dir=ckpt,
        ckpt_every=50,
        log_every=20,
    )
    print(f"\n{out['params']/1e6:.1f}M params; "
          f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
          f"over {out['steps_run']} steps; checkpoints in {ckpt}")
    assert out["final_loss"] < out["first_loss"], "loss did not fall"


if __name__ == "__main__":
    main()
