"""End-to-end driver: one simulated week of AI Greenferencing.

Reproduces the paper's §5.2 headline experiment — Heron (Planner-L at
15-min slots) vs the WRR+DynamoLLM and greedy-min-latency baselines over
a week of real-statistics wind power and the coding trace, through the
drought that makes cross-site routing matter.

Policies come from the RoutingPolicy registry (``repro.sim.policy``), so
the same driver exercises anything registered there; ``--scenario
stress`` layers a seeded ScenarioEngine disturbance stack (site failure,
grid trip, demand surge) on top of the wind week to show Heron's
site-health/straggler path absorbing events the power-agnostic baselines
drop. Every run is recorded under artifacts/sim/ (``--no-record`` to
skip) so benchmarks can reload instead of re-simulating.

    PYTHONPATH=src python examples/greenferencing_week.py [--slots 96]
        [--scenario stress] [--seed 0]
"""
import argparse

import numpy as np

from repro.sim.cluster import goodput_improvement, simulate_week
from repro.sim.policy import list_policies
from repro.sim.scenarios import (DemandSurge, GridTrip, ScenarioEngine,
                                 SiteFailure)
from repro.sim.testbed import paper_grid

POLICIES = ("heron", "heron_min_power", "wrr_dynamollm",
            "greedy_min_latency")


def stress_scenario(slots: int, seed: int) -> ScenarioEngine:
    """Site failure + surprise grid trip + demand surge, scaled to the
    simulated window (events land in the middle half)."""
    q = max(slots // 4, 1)
    return ScenarioEngine([
        SiteFailure(site=0, start=q, duration=q),
        GridTrip(site=1, start=2 * q, duration=2, depth=1.0, detect_ticks=1),
        DemandSurge(magnitude=1.5, start=2 * q, duration=q),
    ], seed=seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=96,
                    help="15-min slots to simulate (672 = full week)")
    ap.add_argument("--start", type=int, default=500,
                    help="start slot (500 = the week's deep drought)")
    ap.add_argument("--volume", type=float, default=960.0)
    ap.add_argument("--trace", default="coding",
                    choices=("coding", "conversation"))
    ap.add_argument("--scenario", default="none", choices=("none", "stress"),
                    help="disturbance stack on top of the wind week")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds the ScenarioEngine end-to-end")
    ap.add_argument("--no-record", dest="record", action="store_false",
                    help="skip writing artifacts/sim/ run records")
    args = ap.parse_args()

    g = paper_grid(args.trace, multiplier=args.volume)
    table, sites = g.table, g.sites
    sl = slice(args.start, args.start + args.slots)
    power = g.power_mw[:, sl]
    arr = g.arrivals_rps[:, sl]

    scenario = (stress_scenario(args.slots, args.seed)
                if args.scenario == "stress" else None)
    print(f"simulating {args.slots} slots @ {args.volume:.0f}x volume "
          f"({arr.sum(0).mean():.0f} rps mean) over "
          f"{sum(s.num_gpus for s in sites):,} GPUs at 4 sites "
          f"[scenario={args.scenario}, seed={args.seed}; "
          f"registered policies: {', '.join(list_policies())}]")
    results = {}
    for sched in POLICIES:
        wk = simulate_week(sched, table, sites, power, arr,
                           scenario=scenario, seed=args.seed,
                           record=args.record)
        results[sched] = wk
        print(f"  {sched:20s} goodput {wk.goodput().sum():12,.0f} rps·slots  "
              f"drop-slots {wk.slots_with_drops():3d}  "
              f"mean power {wk.power().mean()/1e6:5.1f} MW")

    ratio = goodput_improvement(results["heron"], results["wrr_dynamollm"])
    print(f"\ngoodput improvement vs WRR+DynamoLLM: "
          f"p50 {np.percentile(ratio, 50):.2f}x  "
          f"p90 {np.percentile(ratio, 90):.2f}x  max {ratio.max():.2f}x "
          f"(paper: up to 1.8x)")
    lat = results["heron"]
    pw = results["heron_min_power"]
    m = (lat.goodput() > 0) & (pw.goodput() > 0)
    if m.any() and pw.mean_e2e()[m].mean() > 0:
        dl = 1 - lat.mean_e2e()[m].mean() / pw.mean_e2e()[m].mean()
        dp = lat.power()[m].mean() / max(pw.power()[m].mean(), 1e-9) - 1
        print(f"min-latency vs min-power: {dl:+.0%} E2E for {dp:+.0%} power "
              f"(paper: 25% ↔ 42%)")


if __name__ == "__main__":
    main()
