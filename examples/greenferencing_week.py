"""End-to-end driver: one simulated week of AI Greenferencing.

Reproduces the paper's §5.2 headline experiment — Heron (Planner-L at
15-min slots) vs the WRR+DynamoLLM and greedy-min-latency baselines over
a week of real-statistics wind power and the coding trace, through the
drought that makes cross-site routing matter.

    PYTHONPATH=src python examples/greenferencing_week.py [--slots 96]
"""
import argparse

import numpy as np

from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW
from repro.sim.cluster import goodput_improvement, simulate_week


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=96,
                    help="15-min slots to simulate (672 = full week)")
    ap.add_argument("--start", type=int, default=500,
                    help="start slot (500 = the week's deep drought)")
    ap.add_argument("--volume", type=float, default=960.0)
    ap.add_argument("--trace", default="coding",
                    choices=("coding", "conversation"))
    args = ap.parse_args()

    trace = make_trace(args.trace, base_rps=1.0, seed=11)
    table = build_table(PAPER_MODEL, trace, H100_DGX,
                        load_grid=(0.25, 1.0, 4.0, 16.0),
                        freq_grid=(1.2, 2.0))
    fleet = make_default_fleet(seed=7)
    sites, thr = [], []
    for s in fleet.sites:
        pods = int(s.percentile_mw(20.0) // SUPERPOD_PEAK_MW)
        sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
        thr.append(s.percentile_mw(20.0))
    sl = slice(args.start, args.start + args.slots)
    power = np.minimum(fleet.week(), np.array(thr)[:, None])[:, sl]
    arr = trace.class_arrivals(multiplier=args.volume)[:, sl] / (15 * 60)

    print(f"simulating {args.slots} slots @ {args.volume:.0f}x volume "
          f"({arr.sum(0).mean():.0f} rps mean) over "
          f"{sum(s.num_gpus for s in sites):,} GPUs at 4 sites")
    results = {}
    for sched in ("heron", "heron_min_power", "wrr_dynamollm",
                  "greedy_min_latency"):
        wk = simulate_week(sched, table, sites, power, arr)
        results[sched] = wk
        print(f"  {sched:20s} goodput {wk.goodput().sum():12,.0f} rps·slots  "
              f"drop-slots {wk.slots_with_drops():3d}  "
              f"mean power {wk.power().mean()/1e6:5.1f} MW")

    ratio = goodput_improvement(results["heron"], results["wrr_dynamollm"])
    print(f"\ngoodput improvement vs WRR+DynamoLLM: "
          f"p50 {np.percentile(ratio, 50):.2f}x  "
          f"p90 {np.percentile(ratio, 90):.2f}x  max {ratio.max():.2f}x "
          f"(paper: up to 1.8x)")
    lat = results["heron"]
    pw = results["heron_min_power"]
    m = (lat.goodput() > 0) & (pw.goodput() > 0)
    if m.any() and pw.mean_e2e()[m].mean() > 0:
        dl = 1 - lat.mean_e2e()[m].mean() / pw.mean_e2e()[m].mean()
        dp = lat.power()[m].mean() / max(pw.power()[m].mean(), 1e-9) - 1
        print(f"min-latency vs min-power: {dl:+.0%} E2E for {dp:+.0%} power "
              f"(paper: 25% ↔ 42%)")


if __name__ == "__main__":
    main()
