"""Quickstart: the Heron cross-site router in 60 seconds.

Builds the paper's evaluation world — 4 European wind sites right-sized at
the 20th percentile, the Azure-like coding trace, a Llama-3.1-70B lookup
table — plans one 15-min slot with Planner-L, refines it with Planner-S,
and dispatches a slot of requests through the WRR + packing scheduler.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec
from repro.core.router import HeronRouter
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW


def main():
    # 1. the workload: one week of Azure-like coding trace, 9 classes
    trace = make_trace("coding", base_rps=1.0, seed=11)
    print(f"trace: {trace.arrivals.sum():,} requests/week, "
          f"class mix {np.round(trace.class_mix(), 2)}")

    # 2. the profiling exercise -> lookup tables e2e(c,f,t,l), power(...)
    table = build_table(PAPER_MODEL, trace, H100_DGX,
                        load_grid=(0.25, 1.0, 4.0, 16.0),
                        freq_grid=(1.2, 2.0))
    print(f"lookup table: {len(table)} SLO-valid rows "
          f"({PAPER_MODEL.name} on {H100_DGX.name})")

    # 3. the fleet: 4 wind farms, compute right-sized at the 20th pctile
    fleet = make_default_fleet(seed=7)
    sites = []
    for s in fleet.sites:
        pods = int(s.percentile_mw(20.0) // SUPERPOD_PEAK_MW)
        sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
        print(f"  {s.name:12s} peak {s.peak_mw:.0f} MW -> "
              f"{pods} SuperPODs ({pods * SUPERPOD_GPUS:,} GPUs)")

    # 4. Heron plans a slot (Planner-L) and refines it (Planner-S)
    router = HeronRouter(table=table, sites=sites, objective="latency")
    thr = np.array([s.percentile_mw(20.0) for s in fleet.sites])
    power_w = np.minimum(fleet.week()[:, 150], thr) * 1e6
    load = trace.class_arrivals(multiplier=600.0)[:, 150] / (15 * 60)
    plan = router.step_slot(power_w, load)
    print(f"Planner-L: {plan.status} in {plan.solve_seconds:.2f}s, "
          f"power {plan.total_power()/1e6:.1f} MW, "
          f"unserved {plan.unserved.sum():.2f} rps")

    plan_s = router.step_seconds(now=5.0, power_w=power_w * 0.9,
                                 observed_load=load)
    print(f"Planner-S (−10% power): unserved {plan_s.unserved.sum():.2f} rps")

    # 5. dispatch one second of arrivals
    res = router.dispatch(load)
    print(f"dispatch: served {res.served.sum():.1f} rps, "
          f"dropped {res.dropped.sum():.2f}, packed {res.packed.sum():.2f}, "
          f"per-site {np.round(res.per_site_load, 1)}")


if __name__ == "__main__":
    main()
