"""Training substrate: AdamW, microbatched train step, grad compression."""
from repro.training.optimizer import AdamW, AdamWState, global_norm, lr_schedule
from repro.training.train_step import default_schedule, make_train_step
from repro.training.compression import compress_int8, decompress_int8

__all__ = ["AdamW", "AdamWState", "global_norm", "lr_schedule",
           "make_train_step", "default_schedule", "compress_int8",
           "decompress_int8"]
