"""AdamW optimizer (dependency-free, pytree-native).

Matches the standard decoupled-weight-decay AdamW with bf16-safe fp32
optimizer state. ``scale_by_schedule`` implements linear warmup + cosine
decay — the schedule used by the train driver.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        """Returns (new_params, new_state). Grad-norm clip + AdamW + decay."""
        gnorm = global_norm(grads)
        clip = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr * lr_scale

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * clip
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def lr_schedule(step, *, warmup: int = 100, total: int = 10_000,
                min_frac: float = 0.1):
    """Linear warmup → cosine decay, as a multiplicative scale in [0, 1]."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
