"""Gradient compression: int8 stochastic-rounding quantisation.

At DP=32 (16 data x 2 pods) the gradient all-reduce moves 2 bytes/param
per step; int8 halves it. Quantisation is per-tensor absmax-scaled with
*deterministic* rounding by default (bitwise reproducible across replicas;
stochastic rounding is available for unbiasedness where the caller wants
it). Used by ``train_step`` behind the ``compress_grads`` flag; the
round-trip error bound is property-tested in tests/test_training.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x, *, stochastic_key=None):
    """x -> (q int8, scale fp32). Per-tensor absmax scaling."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-30) / 127.0
    y = x32 / scale
    if stochastic_key is not None:
        noise = jax.random.uniform(stochastic_key, y.shape) - 0.5
        q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale
