"""Distributed train step: microbatching, compression, overlap knobs.

``make_train_step`` builds the jit-able step the launcher lowers/compiles:

  * **microbatch gradient accumulation** — the global batch is split into
    ``num_microbatches`` scanned slices; under XLA async collectives each
    microbatch's reduce-scatter overlaps the next microbatch's backward
    (the standard compute/comm overlap trick, EXPERIMENTS.md §Perf);
  * **gradient compression** — optional int8 stochastic-rounding quantise
    before the cross-replica mean, dequantise after (halves/quarters DP
    all-reduce bytes; see ``compression.py``);
  * sharding is installed by the *caller* (launch/dryrun) via in/out
    shardings + the model's logical-axis rules; this module is mesh-free.

The step returns (params, opt_state, metrics) and is pure — checkpointing
and the data pipeline live one layer up in ``launch/train.py``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.training.compression import (compress_int8, decompress_int8)
from repro.training.optimizer import AdamW, AdamWState, global_norm, lr_schedule


def make_train_step(loss_fn: Callable, opt: AdamW, *,
                    num_microbatches: int = 1,
                    compress_grads: bool = False,
                    schedule: Optional[Callable] = None,
                    grad_spec=None):
    """Build ``step(params, opt_state, batch) -> (params, opt_state, metrics)``.

    ``loss_fn(params, batch) -> scalar``. ``batch`` leaves are [B, ...] and
    B % num_microbatches == 0. ``grad_spec``: optional PartitionSpec pytree
    matching params — constraining grads to the params' (FSDP) sharding
    turns the cross-replica gradient all-reduce into a reduce-scatter
    (§Perf H5: 104 GB -> ~4 GB per device per step on llama3-8b/train_4k).
    """
    grad_fn = jax.value_and_grad(loss_fn)

    def _constrain(grads):
        if grad_spec is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s)
            if s is not None else g, grads, grad_spec)

    def step(params, opt_state: AdamWState, batch):
        if num_microbatches <= 1:
            loss, grads = grad_fn(params, batch)
            grads = _constrain(grads)
        else:
            def micro(carry, mb):
                acc, = carry
                loss_i, g_i = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, _constrain(g_i))
                return (acc,), loss_i

            mbs = jax.tree.map(
                lambda x: x.reshape(num_microbatches,
                                    x.shape[0] // num_microbatches,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum,), losses = jax.lax.scan(micro, (zero,), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, gsum)
            loss = losses.mean()

        if compress_grads:
            # int8 over-the-wire: quantise, (collective happens on the
            # sharded value under GSPMD), dequantise.
            grads = jax.tree.map(
                lambda g: decompress_int8(*compress_int8(g)), grads)

        lr_scale = schedule(opt_state.step) if schedule is not None else 1.0
        new_params, new_state = opt.update(grads, opt_state, params,
                                           lr_scale=lr_scale)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "lr_scale": jnp.asarray(lr_scale, jnp.float32)}
        return new_params, new_state, metrics

    return step


def default_schedule(total_steps: int, warmup: int = 100):
    return functools.partial(lr_schedule, warmup=warmup, total=total_steps)
