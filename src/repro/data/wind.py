"""Wind power generation traces (EMHIRES-calibrated synthetic).

The paper's evaluation uses hourly EMHIRES generation for 4 wind farms
(Iceland, Norway, Switzerland, UK; assumed peak 250 MW each) scaled in time
to one week at 15-min granularity, plus the long-term (1 year) 20th-
percentile thresholds that size each site's compute:

    Iceland 29 MW · Norway 16.5 MW · Switzerland 7 MW · UK 13.25 MW

The dataset itself is not shipped offline, so we synthesize traces with the
properties the paper measures and leverages:

  * lag-1 autocorrelation ≥ 0.98 at 15-min granularity (§2.3.1: 0.991/0.989)
    — from an Ornstein-Uhlenbeck latent with a long correlation time;
  * cross-site complementarity — site latents mix a shared weather
    component with site-specific systems at low/negative correlation, so
    aggregate CoV ≈ 0.45-0.5 (paper: 0.475 for the 4-country pick);
  * exact long-term percentile calibration — each site's marginal is
    quantile-mapped onto a Beta marginal whose 20th pctile equals the
    paper's threshold, so right-sizing reproduces the same MW numbers.

Everything is deterministic given ``seed``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

SLOT_MINUTES = 15
SLOTS_PER_DAY = 24 * 60 // SLOT_MINUTES
WEEK_SLOTS = 7 * SLOTS_PER_DAY          # 672
YEAR_SLOTS = 365 * SLOTS_PER_DAY

# (name, peak_MW, paper 20th-ptile threshold MW, marginal beta params)
PAPER_SITES = [
    ("iceland",     250.0, 29.00),
    ("norway",      250.0, 16.50),
    ("switzerland", 250.0,  7.00),
    ("uk",          250.0, 13.25),
]


@dataclass
class WindSite:
    name: str
    peak_mw: float
    series_mw: np.ndarray          # [T] generation at 15-min slots
    long_term_mw: np.ndarray       # [T_year] calibration series

    def percentile_mw(self, pct: float) -> float:
        return float(np.percentile(self.long_term_mw, pct))


@dataclass
class WindFleet:
    sites: list[WindSite]

    @property
    def names(self) -> list[str]:
        return [s.name for s in self.sites]

    def week(self) -> np.ndarray:
        """[S, WEEK_SLOTS] MW."""
        return np.stack([s.series_mw[:WEEK_SLOTS] for s in self.sites])

    def aggregate_cov(self) -> float:
        agg = np.stack([s.long_term_mw for s in self.sites]).sum(0)
        return float(agg.std() / agg.mean())

    def site_cov(self, i: int) -> float:
        s = self.sites[i].long_term_mw
        return float(s.std() / s.mean())


def _ou_latent(rng, n, *, tau_slots: float, jitter: float = 0.15):
    """Ornstein-Uhlenbeck latent: autocorr(1) = exp(-1/tau).

    The AR(1) recursion ``z[t] = phi z[t-1] + sig eps[t]`` runs through
    ``scipy.signal.lfilter`` — the same C-loop arithmetic as the scalar
    Python recursion (same draws, same order, same float64 operations),
    so traces are bit-identical to the historical loop while year-long
    latents stop dominating population construction.
    """
    from scipy.signal import lfilter
    phi = np.exp(-1.0 / tau_slots)
    sig = np.sqrt(1 - phi * phi)
    z0 = rng.standard_normal()
    eps = rng.standard_normal(n)
    x = sig * eps
    x[0] = z0
    z = lfilter([1.0], [1.0, -phi], x)
    # slow seasonal modulation (multi-day weather systems)
    t = np.arange(n)
    season = jitter * np.sin(2 * np.pi * t / (SLOTS_PER_DAY * 3.7) + rng.uniform(0, 6))
    return z + season


def _quantile_map_to_beta(z: np.ndarray, a: float, b: float) -> np.ndarray:
    """Rank-preserving map of ``z`` onto a Beta(a, b) marginal in [0, 1]."""
    from scipy.stats import beta as beta_dist
    ranks = z.argsort().argsort()
    u = (ranks + 0.5) / len(z)
    return beta_dist.ppf(u, a, b)


def _calibrate_beta(target_p20: float, mean_hint: float) -> tuple[float, float]:
    """Find Beta(a,b) with ~mean_hint mean whose 20th pctile is target_p20."""
    from scipy.optimize import brentq
    from scipy.stats import beta as beta_dist

    def p20_of(a):
        b = a * (1 - mean_hint) / mean_hint
        return beta_dist.ppf(0.20, a, b) - target_p20

    lo, hi = 0.05, 50.0
    # p20 rises with a (tighter distribution): bracket then solve
    if p20_of(lo) > 0:
        a = lo
    elif p20_of(hi) < 0:
        a = hi
    else:
        a = brentq(p20_of, lo, hi)
    return a, a * (1 - mean_hint) / mean_hint


def make_default_fleet(seed: int = 7, weeks: int = 1) -> WindFleet:
    """The paper's 4-site European fleet, one year of 15-min generation."""
    rng = np.random.default_rng(seed)
    n = YEAR_SLOTS
    # shared weather component + per-site system; lags decorrelate the sites
    shared = _ou_latent(rng, n + 64, tau_slots=SLOTS_PER_DAY * 3.0)
    mean_hints = {"iceland": 0.52, "norway": 0.38, "switzerland": 0.27, "uk": 0.35}
    mix = {"iceland": 0.25, "norway": 0.35, "switzerland": 0.30, "uk": 0.40}
    lags = {"iceland": 0, "norway": 18, "switzerland": 40, "uk": 60}
    sites = []
    for name, peak, thresh in PAPER_SITES:
        own = _ou_latent(rng, n, tau_slots=SLOTS_PER_DAY * 2.4)
        lam = mix[name]
        z = np.sqrt(1 - lam ** 2) * own + lam * shared[lags[name]:lags[name] + n]
        a, b = _calibrate_beta(thresh / peak, mean_hints[name])
        frac = _quantile_map_to_beta(z, a, b)
        series = frac * peak
        sites.append(WindSite(name=name, peak_mw=peak,
                              series_mw=series[: weeks * WEEK_SLOTS].copy(),
                              long_term_mw=series))
    return WindFleet(sites=sites)


def lag1_autocorr(x: np.ndarray) -> float:
    x = np.asarray(x, float)
    x0, x1 = x[:-1] - x[:-1].mean(), x[1:] - x[1:].mean()
    return float((x0 * x1).mean() / (x0.std() * x1.std() + 1e-12))


def make_site_population(num_sites: int, seed: int = 13,
                         peak_range=(100.0, 1200.0)) -> list[WindSite]:
    """A population of farms for scalability/right-sizing studies (Fig 5/14r).

    Peak capacities follow a truncated Pareto (few giant farms, many small),
    matching the Global Energy Monitor's heavy-tailed size distribution.
    """
    rng = np.random.default_rng(seed)
    n = 8 * WEEK_SLOTS
    shared = _ou_latent(rng, n + 512, tau_slots=SLOTS_PER_DAY * 1.5)
    out = []
    for i in range(num_sites):
        peak = float(np.clip(peak_range[0] * (1 + rng.pareto(1.6)), *peak_range))
        own = _ou_latent(rng, n, tau_slots=SLOTS_PER_DAY * (0.8 + rng.uniform(0, 1.2)))
        lam = rng.uniform(0.2, 0.45)
        lag = int(rng.integers(0, 500))
        z = np.sqrt(1 - lam ** 2) * own + lam * shared[lag:lag + n]
        a, b = _calibrate_beta(rng.uniform(0.02, 0.12), rng.uniform(0.25, 0.5))
        series = _quantile_map_to_beta(z, a, b) * peak
        out.append(WindSite(name=f"site{i:03d}", peak_mw=peak,
                            series_mw=series[:WEEK_SLOTS].copy(), long_term_mw=series))
    return out


def make_synthetic_population(num_sites: int, seed: int = 13,
                              peak_range=(100.0, 1200.0),
                              weeks: int = 1) -> list[WindSite]:
    """Planner-scale population: fully vectorized, no per-site calibration.

    ``make_site_population`` pays an exact Beta quantile-map (brentq +
    ``beta.ppf`` over the full series) per site — the right marginal for
    right-sizing studies, but ~100 ms/site, which walls planning
    benchmarks at 4096-10240 sites. This generator keeps the properties
    the *planner* consumes — heavy-tailed Pareto peak capacities,
    high-autocorrelation cross-correlated power series, and a low
    (~2-12% of peak) long-term P20 that sizes each site's compute — but
    maps the latent onto its marginal with a rank map plus a
    closed-form power curve: per-site ranks give an exactly uniform
    ``u``, and ``frac = u ** (log f20 / log 0.2)`` places the 20th
    percentile at the drawn ``f20`` by construction. All draws and the
    rank maps are batched. Not a substitute where the exact Beta
    marginal matters (Fig. 3-5 right-sizing economics).
    """
    rng = np.random.default_rng(seed)
    S = int(num_sites)
    n = max(1, int(weeks)) * WEEK_SLOTS
    peak = np.clip(peak_range[0] * (1 + rng.pareto(1.6, S)), *peak_range)
    tau = SLOTS_PER_DAY * (0.8 + rng.uniform(0.0, 1.2, S))
    phi = np.exp(-1.0 / tau)                       # per-site AR(1) pole
    sig = np.sqrt(1.0 - phi * phi)
    z = np.empty((S, n))
    z[:, 0] = rng.standard_normal(S)
    eps = rng.standard_normal((S, n))
    for t in range(1, n):                          # vectorized across sites
        z[:, t] = phi * z[:, t - 1] + sig * eps[:, t]
    shared = _ou_latent(rng, n, tau_slots=SLOTS_PER_DAY * 1.5)
    lam = rng.uniform(0.2, 0.45, S)[:, None]
    z = np.sqrt(1.0 - lam ** 2) * z + lam * shared[None, :]
    # rank-preserving uniform marginal per site, then a power map
    # pinning the 20th percentile at the drawn P20 fraction
    ranks = z.argsort(axis=1).argsort(axis=1)
    u = (ranks + 0.5) / n
    f20 = rng.uniform(0.02, 0.12, S)
    gamma = np.log(f20) / np.log(0.2)
    series = (u ** gamma[:, None]) * peak[:, None]
    return [WindSite(name=f"site{i:04d}", peak_mw=float(peak[i]),
                     series_mw=series[i, :WEEK_SLOTS].copy(),
                     long_term_mw=series[i])
            for i in range(S)]
