"""Training data pipeline: deterministic synthetic LM token stream.

No datasets ship offline, so the train driver consumes a synthetic
next-token corpus with enough structure to give a falling loss curve
(Zipf unigram mixture + short-range bigram structure). The pipeline is:

  * **deterministic & resumable** — batch ``i`` is a pure function of
    (seed, i); checkpoint restore just sets the step counter (no iterator
    state to persist);
  * **shard-friendly** — each host materialises the full [B, S] batch and
    hands it to jit under the batch in_sharding (GSPMD slices per device);
    at 1000-node scale, swap ``global_batch_fn`` for a per-host slice fn.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch(self, step: int) -> dict:
        """Deterministic batch for ``step``: {tokens, labels} [B, S]."""
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # Zipf unigrams restricted to the vocab
        u = rng.zipf(self.zipf_a, size=(B, S + 1))
        u = (u - 1) % V
        # bigram structure: with p=0.5, next token = (prev * 31 + 7) % V —
        # learnable short-range dependency so loss falls below unigram entropy
        mask = rng.random((B, S)) < 0.5
        nxt = (u[:, :-1] * 31 + 7) % V
        tok = u.copy()
        tok[:, 1:][mask] = nxt[mask]
        tokens = jnp.asarray(tok[:, :-1], jnp.int32)
        labels = jnp.asarray(tok[:, 1:], jnp.int32)
        return {"tokens": tokens, "labels": labels}

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch(step)
            step += 1
