from repro.data.wind import WindSite, WindFleet, make_default_fleet
from repro.data.workload import WorkloadTrace, make_trace, CLASSES

__all__ = ["WindSite", "WindFleet", "make_default_fleet", "WorkloadTrace",
           "make_trace", "CLASSES"]
