"""LLM inferencing workload traces (Azure-2024-calibrated synthetic).

The paper uses one week of Azure *coding* and *conversation* production
traces [11]. The public dataset is not shipped offline; we synthesize
traces matching every property the paper measures and exploits:

  Fig 12 (left)   input lengths 1..~8K tokens; coding ≈ 2× conversation at
                  the median (lognormal marginals below);
  Fig 12 (middle) outputs within ~1K tokens; conversation ≈ 6× coding at
                  the 95th percentile;
  Fig 12 (right)  strong diurnal + weekly arrival pattern;
  Fig 7           arrival-count lag-1 autocorrelation > 0.99 at 15-min
                  granularity (slowly-varying AR modulation keeps it high).

Requests are classified into the paper's 9 buckets {S,M,L}×{S,M,L} by the
33rd/66th length percentiles *of the week itself* (§5.1), so the class
boundaries are data-derived exactly as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.wind import SLOT_MINUTES, SLOTS_PER_DAY, WEEK_SLOTS

CLASSES = ["SS", "SM", "SL", "MS", "MM", "ML", "LS", "LM", "LL"]

# lognormal (median, sigma) for token lengths, calibrated to Fig 12
LENGTH_PARAMS = {
    # input: coding 2x conversation at median; both reach ~8K tails
    "conversation": {"in": (950.0, 0.95), "out": (220.0, 0.85)},
    # conversation outputs ~6x coding at p95
    "coding": {"in": (1900.0, 0.90), "out": (80.0, 0.55)},
}
MAX_INPUT = 8192
MAX_OUTPUT = 1024


@dataclass
class WorkloadTrace:
    name: str
    # per-slot arrival counts [WEEK_SLOTS]
    arrivals: np.ndarray
    # per-request lengths for one *representative pool* (resampled on demand)
    input_lens: np.ndarray
    output_lens: np.ndarray
    in_edges: tuple[float, float]    # 33rd/66th pctile boundaries
    out_edges: tuple[float, float]

    # ---- classification (paper §5.1) ----
    def classify(self, lin: np.ndarray, lout: np.ndarray) -> np.ndarray:
        i = np.digitize(lin, self.in_edges)      # 0,1,2 = S,M,L
        o = np.digitize(lout, self.out_edges)
        return i * 3 + o                          # index into CLASSES

    def class_mix(self) -> np.ndarray:
        """[9] fraction of requests per class over the week."""
        c = self.classify(self.input_lens, self.output_lens)
        return np.bincount(c, minlength=9) / len(c)

    def class_arrivals(self, multiplier: float = 1.0) -> np.ndarray:
        """[9, WEEK_SLOTS] expected per-class arrivals per 15-min slot."""
        mix = self.class_mix()[:, None]
        return mix * self.arrivals[None, :] * multiplier

    def mean_lengths(self) -> list[tuple[float, float]]:
        """[(mean_in, mean_out)] per class — drives the profiling exercise."""
        c = self.classify(self.input_lens, self.output_lens)
        out = []
        for k in range(9):
            m = c == k
            if m.sum() == 0:
                out.append((float(np.mean(self.input_lens)),
                            float(np.mean(self.output_lens))))
            else:
                out.append((float(self.input_lens[m].mean()),
                            float(self.output_lens[m].mean())))
        return out

    def sample_requests(self, n: int, seed: int = 0):
        """(input_lens, output_lens, class_ids) for n fresh requests."""
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, len(self.input_lens), n)
        lin, lout = self.input_lens[idx], self.output_lens[idx]
        return lin, lout, self.classify(lin, lout)


def _diurnal_profile(name: str, rng) -> np.ndarray:
    """[WEEK_SLOTS] multiplicative arrival intensity, mean 1."""
    t = np.arange(WEEK_SLOTS)
    hour = (t % SLOTS_PER_DAY) / SLOTS_PER_DAY * 24
    day = t // SLOTS_PER_DAY
    if name == "coding":
        # work-hours peaked, strong weekday/weekend contrast
        base = 0.35 + 1.0 * np.exp(-0.5 * ((hour - 14.0) / 3.6) ** 2)
        weekly = np.where(day % 7 >= 5, 0.45, 1.0)
    else:
        # conversation: broader daytime bump, smaller weekend dip
        base = 0.45 + 0.85 * np.exp(-0.5 * ((hour - 15.5) / 5.0) ** 2)
        weekly = np.where(day % 7 >= 5, 0.8, 1.0)
    # slowly-varying AR(1) modulation — keeps lag-1 autocorr ~0.99+
    ar = np.empty(WEEK_SLOTS)
    ar[0] = 0.0
    phi, sig = 0.996, 0.012
    eps = rng.standard_normal(WEEK_SLOTS)
    for i in range(1, WEEK_SLOTS):
        ar[i] = phi * ar[i - 1] + sig * eps[i]
    prof = base * weekly * np.exp(ar)
    return prof / prof.mean()


def _lognormal_lengths(rng, n, median, sigma, max_val):
    x = rng.lognormal(np.log(median), sigma, n)
    return np.clip(np.round(x), 1, max_val).astype(np.int64)


def make_trace(name: str, *, base_rps: float = 1.0, seed: int = 11,
               pool: int = 200_000) -> WorkloadTrace:
    """One week of ``coding`` | ``conversation`` workload.

    ``base_rps`` is the mean arrival rate (req/s) before the paper's
    volume multipliers (60× coding / 50× conversation in §5.2).
    """
    assert name in LENGTH_PARAMS, name
    rng = np.random.default_rng(seed + (0 if name == "coding" else 1))
    prof = _diurnal_profile(name, rng)
    per_slot_mean = base_rps * 60 * SLOT_MINUTES
    arrivals = rng.poisson(prof * per_slot_mean).astype(np.int64)
    pin = LENGTH_PARAMS[name]["in"]
    pout = LENGTH_PARAMS[name]["out"]
    lin = _lognormal_lengths(rng, pool, *pin, MAX_INPUT)
    lout = _lognormal_lengths(rng, pool, *pout, MAX_OUTPUT)
    in_edges = (float(np.percentile(lin, 33)), float(np.percentile(lin, 66)))
    out_edges = (float(np.percentile(lout, 33)), float(np.percentile(lout, 66)))
    return WorkloadTrace(name=name, arrivals=arrivals, input_lens=lin,
                         output_lens=lout, in_edges=in_edges, out_edges=out_edges)
