"""LLM inferencing workload traces (Azure-2024-calibrated synthetic).

The paper uses one week of Azure *coding* and *conversation* production
traces [11]. The public dataset is not shipped offline; we synthesize
traces matching every property the paper measures and exploits:

  Fig 12 (left)   input lengths 1..~8K tokens; coding ≈ 2× conversation at
                  the median (lognormal marginals below);
  Fig 12 (middle) outputs within ~1K tokens; conversation ≈ 6× coding at
                  the 95th percentile;
  Fig 12 (right)  strong diurnal + weekly arrival pattern;
  Fig 7           arrival-count lag-1 autocorrelation > 0.99 at 15-min
                  granularity (slowly-varying AR modulation keeps it high).

Requests are classified into the paper's 9 buckets {S,M,L}×{S,M,L} by the
33rd/66th length percentiles *of the week itself* (§5.1), so the class
boundaries are data-derived exactly as in the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Sequence, Union

import numpy as np

from repro.stats import percentiles
from repro.data.wind import SLOT_MINUTES, SLOTS_PER_DAY, WEEK_SLOTS

CLASSES = ["SS", "SM", "SL", "MS", "MM", "ML", "LS", "LM", "LL"]

# lognormal (median, sigma) for token lengths, calibrated to Fig 12
LENGTH_PARAMS = {
    # input: coding 2x conversation at median; both reach ~8K tails
    "conversation": {"in": (950.0, 0.95), "out": (220.0, 0.85)},
    # conversation outputs ~6x coding at p95
    "coding": {"in": (1900.0, 0.90), "out": (80.0, 0.55)},
}
MAX_INPUT = 8192
MAX_OUTPUT = 1024


@dataclass
class WorkloadTrace:
    name: str
    # per-slot arrival counts [WEEK_SLOTS]
    arrivals: np.ndarray
    # per-request lengths for one *representative pool* (resampled on demand)
    input_lens: np.ndarray
    output_lens: np.ndarray
    in_edges: tuple[float, float]    # 33rd/66th pctile boundaries
    out_edges: tuple[float, float]

    # ---- classification (paper §5.1) ----
    def classify(self, lin: np.ndarray, lout: np.ndarray) -> np.ndarray:
        i = np.digitize(lin, self.in_edges)      # 0,1,2 = S,M,L
        o = np.digitize(lout, self.out_edges)
        return i * 3 + o                          # index into CLASSES

    def class_mix(self) -> np.ndarray:
        """[9] fraction of requests per class over the week."""
        c = self.classify(self.input_lens, self.output_lens)
        return np.bincount(c, minlength=9) / len(c)

    def class_arrivals(self, multiplier: float = 1.0) -> np.ndarray:
        """[9, WEEK_SLOTS] expected per-class arrivals per 15-min slot."""
        mix = self.class_mix()[:, None]
        return mix * self.arrivals[None, :] * multiplier

    def mean_lengths(self) -> list[tuple[float, float]]:
        """[(mean_in, mean_out)] per class — drives the profiling exercise."""
        c = self.classify(self.input_lens, self.output_lens)
        out = []
        for k in range(9):
            m = c == k
            if m.sum() == 0:
                out.append((float(np.mean(self.input_lens)),
                            float(np.mean(self.output_lens))))
            else:
                out.append((float(self.input_lens[m].mean()),
                            float(self.output_lens[m].mean())))
        return out

    def sample_requests(self, n: int, seed: int = 0):
        """(input_lens, output_lens, class_ids) for n fresh requests."""
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, len(self.input_lens), n)
        lin, lout = self.input_lens[idx], self.output_lens[idx]
        return lin, lout, self.classify(lin, lout)


def _diurnal_profile(name: str, rng) -> np.ndarray:
    """[WEEK_SLOTS] multiplicative arrival intensity, mean 1."""
    t = np.arange(WEEK_SLOTS)
    hour = (t % SLOTS_PER_DAY) / SLOTS_PER_DAY * 24
    day = t // SLOTS_PER_DAY
    if name == "coding":
        # work-hours peaked, strong weekday/weekend contrast
        base = 0.35 + 1.0 * np.exp(-0.5 * ((hour - 14.0) / 3.6) ** 2)
        weekly = np.where(day % 7 >= 5, 0.45, 1.0)
    else:
        # conversation: broader daytime bump, smaller weekend dip
        base = 0.45 + 0.85 * np.exp(-0.5 * ((hour - 15.5) / 5.0) ** 2)
        weekly = np.where(day % 7 >= 5, 0.8, 1.0)
    # slowly-varying AR(1) modulation — keeps lag-1 autocorr ~0.99+
    ar = np.empty(WEEK_SLOTS)
    ar[0] = 0.0
    phi, sig = 0.996, 0.012
    eps = rng.standard_normal(WEEK_SLOTS)
    for i in range(1, WEEK_SLOTS):
        ar[i] = phi * ar[i - 1] + sig * eps[i]
    prof = base * weekly * np.exp(ar)
    return prof / prof.mean()


def _lognormal_lengths(rng, n, median, sigma, max_val):
    x = rng.lognormal(np.log(median), sigma, n)
    return np.clip(np.round(x), 1, max_val).astype(np.int64)


def make_trace(name: str, *, base_rps: float = 1.0, seed: int = 11,
               pool: int = 200_000) -> WorkloadTrace:
    """One week of ``coding`` | ``conversation`` workload.

    ``base_rps`` is the mean arrival rate (req/s) before the paper's
    volume multipliers (60× coding / 50× conversation in §5.2).
    """
    assert name in LENGTH_PARAMS, name
    rng = np.random.default_rng(seed + (0 if name == "coding" else 1))
    prof = _diurnal_profile(name, rng)
    per_slot_mean = base_rps * 60 * SLOT_MINUTES
    arrivals = rng.poisson(prof * per_slot_mean).astype(np.int64)
    pin = LENGTH_PARAMS[name]["in"]
    pout = LENGTH_PARAMS[name]["out"]
    lin = _lognormal_lengths(rng, pool, *pin, MAX_INPUT)
    lout = _lognormal_lengths(rng, pool, *pout, MAX_OUTPUT)
    in_edges = tuple(percentiles(lin, (33, 66)))
    out_edges = tuple(percentiles(lout, (33, 66)))
    return WorkloadTrace(name=name, arrivals=arrivals, input_lens=lin,
                         output_lens=lout, in_edges=in_edges, out_edges=out_edges)


# ------------------------------------------------------------------
# streamed million-user request generator (co-sim tentpole)
# ------------------------------------------------------------------
# internal generation granularity: requests are drawn per fixed BLOCK_S-
# second block with a per-(seed, block) substream, then re-chunked to the
# caller's chunk_s — so the SAME seed yields the SAME request stream for
# ANY chunk size (pinned by tests/test_e2e.py)
STREAM_BLOCK_S = 60.0


@dataclass
class RequestChunk:
    """One time-slice of the streamed workload (struct-of-arrays).

    All arrays share the row index; rows are sorted by ``arrival_s``.
    ``site`` is the request's *home affinity* (the region's site a user
    would hit by geography) — the routing layer may land it elsewhere.
    """
    start_s: float
    end_s: float
    rid: np.ndarray         # [n] int64 globally unique (per stream)
    arrival_s: np.ndarray   # [n] float absolute seconds
    site: np.ndarray        # [n] int32 home-site affinity
    lin: np.ndarray         # [n] int64 input tokens
    lout: np.ndarray        # [n] int64 output tokens
    cls: np.ndarray         # [n] int8 paper 9-bucket class id
    kind: np.ndarray        # [n] int8 index into the stream's traces

    def __len__(self) -> int:
        return len(self.rid)


def _region_map(num_sites: int, num_regions: Optional[int]) -> np.ndarray:
    """[S] region id per site — sites round-robin across regions."""
    R = min(num_regions or min(4, num_sites), num_sites)
    return np.arange(num_sites, dtype=np.int64) % max(R, 1)


def stream_requests(
        traces: Union[WorkloadTrace, Sequence[WorkloadTrace]], *,
        num_users: int, num_sites: int, duration_s: float,
        start_s: float = 0.0, chunk_s: float = 60.0, seed: int = 0,
        requests_per_user_day: float = 1.0,
        num_regions: Optional[int] = None,
        region_of_site: Optional[np.ndarray] = None,
) -> Iterator[RequestChunk]:
    """Stream ``(arrival_s, site_affinity, lin, lout)`` requests for a
    user population scaled to ``num_users`` — without materializing the
    week in memory.

    Structure, calibrated to the same Azure-2024 shapes as
    ``make_trace``:

      * total demand: ``num_users * requests_per_user_day / 86400`` mean
        fleet rps, split across ``traces`` proportionally to each
        trace's own arrival volume;
      * diurnal/weekly shape: each trace's per-slot arrival profile
        (Fig 12 right — includes the AR(1) modulation that keeps lag-1
        autocorrelation > 0.99), evaluated at each request's local time;
      * regional structure: sites belong to regions (round-robin by
        default, or an explicit ``region_of_site``), each region's
        diurnal phase shifted by its share of the 24-hour cycle and its
        users' requests carrying that region's sites as home affinity;
      * lengths/classes: per-request lognormal draws from the trace's
        Fig-12 marginals, classified by the trace's own 33/66 edges.

    Determinism: requests are drawn in fixed ``STREAM_BLOCK_S`` blocks
    from per-``(seed, block)`` SeedSequence substreams and re-chunked to
    ``chunk_s``, so the stream is bit-identical across chunk sizes and
    insensitive to how much of the week a consumer actually pulls.
    ``rid`` is the running request index from ``start_s`` (unique per
    stream instance).
    """
    tr = [traces] if isinstance(traces, WorkloadTrace) else list(traces)
    assert tr, "need at least one trace"
    assert num_sites >= 1
    region = (np.asarray(region_of_site, np.int64)
              if region_of_site is not None
              else _region_map(num_sites, num_regions))
    R = int(region.max()) + 1
    sites_of = [np.where(region == r)[0].astype(np.int32) for r in range(R)]
    # region share of users = its share of sites; empty regions get none
    share = np.array([len(s) for s in sites_of], float)
    share = share / share.sum()
    # regional diurnal phase: spread evenly across the day (slot units)
    offset_slots = np.array([(r * SLOTS_PER_DAY) // R for r in range(R)])

    # per-trace normalized diurnal profile (mean 1) and rps split
    profs = [t.arrivals / max(float(t.arrivals.mean()), 1e-12) for t in tr]
    vol = np.array([float(t.arrivals.sum()) for t in tr])
    total_rps = num_users * requests_per_user_day / 86400.0
    kind_rps = total_rps * vol / vol.sum()

    slot_s = SLOT_MINUTES * 60.0
    end_s = start_s + duration_s
    b0 = int(np.floor(start_s / STREAM_BLOCK_S))
    b1 = int(np.ceil(end_s / STREAM_BLOCK_S))
    rid0 = 0
    pending: list[tuple] = []      # generated blocks awaiting a chunk edge
    chunk_lo = start_s

    def _emit(chunk_hi: float) -> RequestChunk:
        nonlocal pending, chunk_lo
        cols = _concat_chunks(pending)
        m = cols[1] < chunk_hi
        out = RequestChunk(start_s=chunk_lo, end_s=chunk_hi,
                           rid=cols[0][m], arrival_s=cols[1][m],
                           site=cols[2][m], lin=cols[3][m], lout=cols[4][m],
                           cls=cols[5][m], kind=cols[6][m])
        pending = [tuple(c[~m] for c in cols)]
        chunk_lo = chunk_hi
        return out

    for b in range(b0, b1):
        t_lo = max(b * STREAM_BLOCK_S, start_s)
        t_hi = min((b + 1) * STREAM_BLOCK_S, end_s)
        if t_hi <= t_lo:
            continue
        rng = np.random.default_rng(np.random.SeedSequence((seed, b)))
        cols, n = _draw_block(rng, tr, profs, kind_rps, share, offset_slots,
                              sites_of, t_lo, t_hi, slot_s, rid0)
        rid0 += n
        if n:
            pending.append(cols)
        # every chunk fully covered by generated blocks can stream out
        while chunk_lo + chunk_s <= t_hi:
            yield _emit(chunk_lo + chunk_s)
    if chunk_lo < end_s or (chunk_lo == start_s and duration_s >= 0):
        yield _emit(end_s)         # final (possibly partial) chunk


def _concat_chunks(parts: list[tuple]) -> tuple:
    if not parts:
        z = np.zeros(0)
        return (z.astype(np.int64), z, z.astype(np.int32), z.astype(np.int64),
                z.astype(np.int64), z.astype(np.int8), z.astype(np.int8))
    return tuple(np.concatenate([p[i] for p in parts])
                 for i in range(len(parts[0])))


def _draw_block(rng, traces, profs, kind_rps, share, offset_slots, sites_of,
                t_lo, t_hi, slot_s, rid0):
    """Draw one block's requests (all kinds x regions, fixed draw order)."""
    span = t_hi - t_lo
    arrs, sites, lins, louts, clss, kinds = [], [], [], [], [], []
    for k, trace in enumerate(traces):
        prof = profs[k]
        for r in range(len(share)):
            if share[r] <= 0:
                continue
            # local time: the region's diurnal phase leads by its offset
            slot = int(t_lo // slot_s + offset_slots[r]) % len(prof)
            lam = kind_rps[k] * share[r] * prof[slot] * span
            n = int(rng.poisson(lam))
            if n == 0:
                continue
            arrs.append(t_lo + rng.uniform(0.0, span, n))
            sites.append(rng.choice(sites_of[r], size=n))
            pin = LENGTH_PARAMS[trace.name]["in"]
            pout = LENGTH_PARAMS[trace.name]["out"]
            lin = _lognormal_lengths(rng, n, *pin, MAX_INPUT)
            lout = _lognormal_lengths(rng, n, *pout, MAX_OUTPUT)
            lins.append(lin)
            louts.append(lout)
            clss.append(trace.classify(lin, lout).astype(np.int8))
            kinds.append(np.full(n, k, np.int8))
    if not arrs:
        return _concat_chunks([]), 0
    arr = np.concatenate(arrs)
    order = np.argsort(arr, kind="stable")
    n = len(arr)
    cols = (rid0 + np.arange(n, dtype=np.int64),
            arr[order],
            np.concatenate(sites)[order].astype(np.int32),
            np.concatenate(lins)[order],
            np.concatenate(louts)[order],
            np.concatenate(clss)[order],
            np.concatenate(kinds)[order])
    return cols, n
