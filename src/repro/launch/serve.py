"""Serving driver: Heron cross-site router over per-site serving engines.

Ties the whole stack together for a *real* (CPU-scale) run:

  * a reduced model is served by one ServingEngine per wind site;
  * wind power traces gate each site's capacity (slots scale with the
    site's available power fraction — the engine-level proxy for the
    instance brownouts the fluid simulator models at fleet scale);
  * HeronRouter plans per slot and the Request Scheduler's WRR weights
    dispatch actual requests into the engines.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --requests 64 --sites 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec
from repro.core.router import HeronRouter
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.models.api import build
from repro.power.model import TPU_V5E
from repro.serving.engine import Request, ServingEngine


def serve_demo(*, arch: str = "llama3.2-1b", num_requests: int = 32,
               num_sites: int = 2, max_batch: int = 4, max_seq: int = 128,
               seed: int = 0, verbose: bool = True,
               admit_mode: str = "batched",
               admit_token_budget: int | None = None) -> dict:
    cfg = smoke_config(arch)
    model = build(cfg)
    params = model.init_params(jax.random.key(seed))
    engines = [ServingEngine(model, params, max_batch=max_batch,
                             max_seq=max_seq, seed=seed + i,
                             admit_mode=admit_mode,
                             admit_token_budget=admit_token_budget)
               for i in range(num_sites)]

    # Heron planning layer (fleet-scale numbers; the engines are the
    # CPU-scale stand-ins for the per-site GPU clusters)
    trace = make_trace("conversation", base_rps=1.0, seed=seed)
    table = build_table(smoke_config(arch), trace, TPU_V5E,
                        load_grid=(0.25, 1.0, 4.0), freq_grid=(0.75, 1.04))
    fleet = make_default_fleet(seed=seed)
    sites = [SiteSpec(s.name, num_gpus=64) for s in fleet.sites[:num_sites]]
    router = HeronRouter(table=table, sites=sites)
    power_w = np.array([s.series_mw[0] for s in fleet.sites[:num_sites]]) * 1e6
    load = trace.class_arrivals()[:, 0] / (15 * 60)
    plan = router.step_slot(power_w, load)
    weights = plan.wrr_weights()

    # site weight per class -> aggregate site dispatch weights
    agg = np.zeros(num_sites)
    for c in range(9):
        for s, _, w in weights.get(c, []):
            agg[s] += w
    if agg.sum() <= 0:
        agg[:] = 1.0
    agg = agg / agg.sum()

    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    for i in range(num_requests):
        site = int(rng.choice(num_sites, p=agg))
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 24))).astype(np.int32)
        engines[site].submit(Request(rid=i, prompt=prompt,
                                     max_new_tokens=int(rng.integers(2, 10)),
                                     arrival_s=time.perf_counter()))
    metrics = [e.run() for e in engines]
    dt = time.perf_counter() - t0

    done = sum(m.summary()["num_completed"] for m in metrics)
    out = {"completed": done, "submitted": num_requests,
           "wall_seconds": round(dt, 2),
           "per_site": [m.summary() for m in metrics],
           "wrr_weights": agg.tolist(),
           "planned_power_w": plan.total_power()}
    if verbose:
        print(f"[serve] {done}/{num_requests} requests served across "
              f"{num_sites} sites in {dt:.1f}s; WRR weights {np.round(agg, 3)}")
        for i, m in enumerate(metrics):
            s = m.summary()
            print(f"  site {i} ({sites[i].name}): {s['num_completed']} done, "
                  f"TTFT mean {s['mean_ttft']*1e3:.0f} / "
                  f"p99 {s['p99_ttft']*1e3:.0f} ms, "
                  f"mean E2E {s['mean_e2e']*1e3:.0f} ms, "
                  f"{s['prefill_calls']} admission dispatches")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--sites", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--admit-mode", choices=("batched", "serial"),
                    default="batched",
                    help="batched admission pipeline vs serial reference")
    ap.add_argument("--admit-budget", type=int, default=None,
                    help="max prompt tokens admitted per engine step")
    args = ap.parse_args(argv)
    out = serve_demo(arch=args.arch, num_requests=args.requests,
                     num_sites=args.sites, max_batch=args.max_batch,
                     admit_mode=args.admit_mode,
                     admit_token_budget=args.admit_budget)
    return 0 if out["completed"] == out["submitted"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
