"""Co-sim launcher: streamed million-user trace replay on live engines.

CLI front-end over ``sim.e2e.simulate_fleet_serving`` — the layer where
the streamed Azure-shaped request population (``data.workload``) drives
one live ``ServingEngine`` per site under a fleet ``RoutingPolicy``'s
plan (power truth plane -> admission budgets + brownout), with scenario
disturbances hitting the live engines. Prints the SLO-attributed
served-token goodput summary and optionally writes the full
``E2EResult`` JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.cosim \\
      --policy heron --scenario grid_trip --ticks 120 \\
      [--users 150000] [--sites 4] [--arch llama3.2-1b] \\
      [--depth 0.7] [--seed 0] [--out artifacts/cosim.json]

``--scenario none`` runs a healthy fleet (capacity/queueing baseline);
``site_failure`` kills the target site for the middle third of the run;
``grid_trip`` sheds ``--depth`` of its power instead (a partial trip is
a brownout, not a kill). Any registered policy name works; see
``repro.sim.policy.list_policies``.
"""
from __future__ import annotations

import argparse
import json
import os


def _build_scenario(kind: str, site: int, ticks: int, depth: float,
                    seed: int):
    from repro.sim.scenarios import GridTrip, ScenarioEngine, SiteFailure
    q = ticks // 3
    if kind == "none":
        return ScenarioEngine(seed=seed)
    if kind == "site_failure":
        return ScenarioEngine([SiteFailure(site=site, start=q, duration=q)],
                              seed=seed)
    if kind == "grid_trip":
        return ScenarioEngine([GridTrip(site=site, start=q, duration=q,
                                        depth=depth, detect_ticks=2)],
                              seed=seed)
    raise SystemExit(f"unknown scenario {kind!r} "
                     "(choose none|site_failure|grid_trip)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="million-user co-sim: streamed trace replay on live "
                    "per-site serving engines")
    ap.add_argument("--policy", default="heron")
    ap.add_argument("--scenario", default="grid_trip",
                    choices=["none", "site_failure", "grid_trip"])
    ap.add_argument("--site", type=int, default=1,
                    help="scenario target site")
    ap.add_argument("--depth", type=float, default=0.7,
                    help="grid trip power-loss fraction (1.0 = dark)")
    ap.add_argument("--ticks", type=int, default=120)
    ap.add_argument("--users", type=int, default=150_000)
    ap.add_argument("--sites", type=int, default=4)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-load-scale", type=float, default=30.0)
    ap.add_argument("--out", default="",
                    help="write full E2EResult JSON here")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs import smoke_config
    from repro.data.workload import make_trace
    from repro.models.api import build
    from repro.serving.engine import ServingEngine
    from repro.sim.e2e import simulate_fleet_serving
    from repro.sim.policy import make_policy
    from repro.sim.testbed import paper_grid

    g = paper_grid("coding", multiplier=60.0)
    S = args.sites
    cfg = smoke_config(args.arch)
    model = build(cfg)
    params = model.init_params(jax.random.key(args.seed))

    # right-size per-site decode slots to the site's power share (see
    # benchmarks/bench_e2e.py for the rationale)
    pshare = g.power_mw[:S, 200:212].mean(axis=1)
    pshare = pshare / pshare.sum()
    batches = np.maximum(2, np.round(16 * pshare)).astype(int)

    def make_engine(site, clock):
        return ServingEngine(model, params, max_batch=int(batches[site]),
                             max_seq=64, seed=site, clock=clock)

    policy = make_policy(args.policy, g.table, g.sites[:S], time_limit=20)
    scenario = _build_scenario(args.scenario, args.site, args.ticks,
                               args.depth, args.seed)
    res = simulate_fleet_serving(
        policy, g.table, g.sites[:S], g.power_mw[:S], make_engine,
        traces=[make_trace("coding"), make_trace("conversation")],
        num_users=args.users, ticks=args.ticks,
        plan_load_scale=args.plan_load_scale, scenario=scenario,
        seed=args.seed, name=f"cosim_{args.policy}_{args.scenario}")

    d = res.to_json()
    print(f"{d['name']}: offered {d['offered_requests']} reqs "
          f"({d['offered_tokens']} tok), completed {d['completed']}, "
          f"slo-goodput {d['slo_goodput_fraction']:.3f} "
          f"(raw {d['goodput_fraction']:.3f}), "
          f"p99 ttft {d['p99_ttft']:.0f} / tbt {d['p99_tbt']:.2f} ticks, "
          f"dup {d['duplicated_tokens']}, "
          f"preempt {d['preemptions']} resume {d['resumes']}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(d, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
