import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import (jax locks the device
count on first init). For every assigned cell this script:

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the right step (train_step / prefill / serve_step) against
     ShapeDtypeStruct inputs with full in/out shardings — no allocation,
  3. ``.compile()``s it (GSPMD partitioning must succeed — sharding
     mismatches / unsupported collectives surface here),
  4. records ``memory_analysis`` (fits-per-device proof),
     ``cost_analysis`` (FLOPs / bytes) and the collective-bytes total
     parsed from the optimized HLO — the §Roofline inputs.

Artifacts land in artifacts/dryrun/<cell>.json; EXPERIMENTS.md §Dry-run
and benchmarks/bench_roofline.py read them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k [--multi-pod] [--all] [--fsdp] [--out artifacts/dryrun]
"""
import argparse
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cells, get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.param_sharding import (batch_specs, cache_specs_tree,
                                              param_specs, to_shardings)
from repro.distributed.sharding import ParallelConfig, axis_rules, make_rules
from repro.launch.mesh import make_parallel
from repro.models.api import build
from repro.sim.record import write_record
from repro.training import AdamW, make_train_step

# ----------------------------------------------------------------- HLO parse
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
_SHAPE_RE = re.compile(r"\b(bf16|f32|f16|f64|s32|s8|u8|u32|s64|u16|s16|pred)"
                       r"\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "s64": 8, "u16": 2, "s16": 2, "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO.

    Returns {op_kind: bytes, ..., 'total': bytes}. Sizes are per-device
    (post-SPMD shapes); *-start ops are counted once (-done is shapeless).
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line:
            continue
        kind = m.group(1)
        # operand shapes: everything after the op name's '(' — use the
        # argument list region to avoid counting the (tuple) result shape.
        paren = line.find("(", m.end())
        region = line[paren:line.find(")", paren) + 1] if paren != -1 else line
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(region))
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ----------------------------------------------------------------- lowering
def _specs_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               fsdp: bool | None = None, num_microbatches: int = 4,
               seq_shard_cache: bool = True, expert_tp_over_data: bool = True,
               remat: bool = True, donate: bool = True,
               flash_threshold: int | None = None,
               kv_cache_dtype: str | None = None,
               moe_expert_axis: str = "model",
               ssd_chunk: int | None = None):
    """Lower one (arch, shape, mesh) cell. Returns (lowered, meta)."""
    if flash_threshold is not None:
        from repro.models import layers as Lyr
        Lyr.set_flash_threshold(flash_threshold)
    if ssd_chunk is not None:
        from repro.models import mamba2 as M2
        M2.set_ssd_chunk(ssd_chunk)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    parallel = make_parallel(multi_pod=multi_pod,
                             seq_shard_cache=seq_shard_cache,
                             expert_tp_over_data=expert_tp_over_data,
                             moe_expert_axis=moe_expert_axis,
                             remat=remat)
    mesh = parallel.mesh
    model = build(cfg, parallel)
    kind = shape.kind
    use_fsdp = (kind == "train") if fsdp is None else fsdp

    rules = make_rules(cfg, parallel, kind)
    dp = parallel.data_size()
    if kind == "decode" and shape.global_batch % dp != 0:
        # long-context (B=1): batch cannot shard — spread the cache
        # sequence over model+data axes instead (mesh-wide flash-decoding)
        rules["cache_seq"] = rules["cache_seq_long"]
        rules["batch"] = None

    p_shapes = model.param_specs()
    p_spec = param_specs(cfg, parallel, p_shapes, fsdp=use_fsdp)
    p_shard = to_shardings(mesh, p_spec)
    in_specs = model.input_specs(shape)
    bspec_fn = batch_specs(cfg, parallel, shape)
    in_shard = {k: NamedSharding(mesh, bspec_fn(v.shape))
                for k, v in in_specs.items()}

    with mesh, axis_rules(rules):
        if kind == "train":
            opt = AdamW()
            step = make_train_step(model.loss_fn, opt,
                                   num_microbatches=num_microbatches,
                                   grad_spec=p_spec)
            o_shapes = jax.eval_shape(opt.init, p_shapes)
            # optimizer state mirrors param sharding (mu/nu per leaf)
            o_spec = type(o_shapes)(step=P(),
                                    mu=param_specs(cfg, parallel,
                                                   o_shapes.mu, fsdp=use_fsdp),
                                    nu=param_specs(cfg, parallel,
                                                   o_shapes.nu, fsdp=use_fsdp))
            o_shard = to_shardings(mesh, o_spec)
            fn = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, in_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(p_shapes, o_shapes, in_specs)
        elif kind == "prefill":
            fn = jax.jit(model.prefill_fn,
                         in_shardings=(p_shard, in_shard),
                         out_shardings=None)
            lowered = fn.lower(p_shapes, in_specs)
        else:  # decode / serve_step
            c_shapes = model.cache_specs(shape, kv_dtype=kv_cache_dtype)
            c_spec = cache_specs_tree(cfg, parallel, c_shapes, shape)
            c_shard = to_shardings(mesh, c_spec)
            fn = jax.jit(model.decode_fn,
                         in_shardings=(p_shard, in_shard, c_shard),
                         out_shardings=(None, c_shard),
                         donate_argnums=(2,) if donate else ())
            lowered = fn.lower(p_shapes, in_specs, c_shapes)

    meta = {"arch": arch, "shape": shape_name, "kind": kind,
            "multi_pod": multi_pod, "fsdp": use_fsdp,
            "mesh": dict(zip(mesh.axis_names,
                             [int(s) for s in mesh.devices.shape])),
            "num_microbatches": num_microbatches if kind == "train" else None,
            "flash_threshold": flash_threshold,
            "kv_cache_dtype": kv_cache_dtype}
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             **kw) -> dict:
    """Lower + compile one cell; return the roofline-input report."""
    t0 = time.perf_counter()
    lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod, **kw)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    report = dict(meta)
    report["ok"] = True
    report["seconds_lower"] = round(t_lower, 2)
    report["seconds_compile"] = round(t_compile, 2)
    try:
        ma = compiled.memory_analysis()
        report["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:                      # CPU backend may not support
        report["memory_analysis"] = {"error": str(e)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        report["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
    except Exception as e:
        report["cost_analysis"] = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        report["collectives"] = collective_bytes(hlo)
        report["hlo_bytes"] = len(hlo)
        # trip-count-aware re-analysis: XLA's cost_analysis counts while
        # bodies once; this walks the call graph with loop trip counts
        # (repro.analysis.hlo) — the numbers §Roofline actually uses.
        from repro.analysis.hlo import analyze as hlo_analyze
        report["hlo_cost"] = hlo_analyze(hlo).as_dict()
    except Exception as e:
        report["collectives"] = {"error": str(e)}
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--no-seq-shard-cache", dest="seq_shard_cache",
                    action="store_false")
    ap.add_argument("--no-expert-tp", dest="expert_tp", action="store_false")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--flash-threshold", type=int, default=None,
                    help="one-shot->chunked attention switch (§Perf H1)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache for decode cells (§Perf H3)")
    ap.add_argument("--moe-expert-axis", choices=("model", "data"),
                    default="model", help="2-level EP layout (§Perf H8)")
    ap.add_argument("--ssd-chunk", type=int, default=None,
                    help="Mamba2/SSD chunk length (§Perf H9)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    todo = []
    if args.all:
        todo = [(a, s) for a, s, skip in cells() if not skip]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            name = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
            if args.tag:
                name += f"__{args.tag}"
            path = os.path.join(args.out, name + ".json")
            try:
                rep = run_cell(arch, shape, multi_pod=mp, fsdp=args.fsdp,
                               num_microbatches=args.microbatches,
                               seq_shard_cache=args.seq_shard_cache,
                               expert_tp_over_data=args.expert_tp,
                               flash_threshold=args.flash_threshold,
                               kv_cache_dtype="int8" if args.kv_int8 else None,
                               moe_expert_axis=args.moe_expert_axis,
                               ssd_chunk=args.ssd_chunk)
                coll = rep.get("collectives", {}).get("total", 0)
                print(f"[dryrun] OK  {name}: "
                      f"compile={rep['seconds_compile']}s "
                      f"flops={rep['cost_analysis'].get('flops', 0):.3e} "
                      f"coll={coll/1e6:.1f}MB")
            except Exception as e:
                failures += 1
                rep = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "ok": False, "error": str(e),
                       "traceback": traceback.format_exc()}
                print(f"[dryrun] FAIL {name}: {e}")
            write_record(path, rep)   # same artifacts contract as the sims
            jax.clear_caches()        # keep the 64-cell sweep's RSS bounded
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
