"""Production meshes (assignment §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see 1 device.
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_parallel(*, multi_pod: bool = False, **overrides) -> ParallelConfig:
    """ParallelConfig over the production mesh. ``pod`` is a pure-DP axis."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    return ParallelConfig(mesh=mesh, data_axes=data_axes, **overrides)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for tests on --xla_force_host_platform_device_count=4+."""
    return jax.make_mesh(shape, axes)
