"""End-to-end training driver: data → train_step → checkpoint → restart.

Runs REAL steps on whatever devices exist (CPU here: use a reduced config;
TPU fleet: the full config under the production mesh). The same loop is
what examples/train_100m.py drives for a few hundred steps.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --reduce --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.configs import ARCHS, get_config, smoke_config
from repro.configs.base import reduced
from repro.data.pipeline import SyntheticLM
from repro.models.api import build
from repro.training import AdamW, default_schedule, make_train_step


def train_loop(*, arch: str, steps: int, global_batch: int, seq_len: int,
               reduce_cfg: bool = True, lr: float = 3e-3,
               num_microbatches: int = 1, compress_grads: bool = False,
               ckpt_dir: str | None = None, ckpt_every: int = 50,
               resume: bool = False, log_every: int = 10,
               d_model: int | None = None, num_layers: int | None = None,
               seed: int = 0) -> dict:
    cfg = get_config(arch)
    if reduce_cfg:
        over = {}
        if d_model:
            over.update(d_model=d_model, head_dim=None,
                        d_ff=int(d_model * 8 // 3 // 64 * 64) or 128)
        if num_layers:
            over["num_layers"] = num_layers
        cfg = reduced(cfg, **over) if over else smoke_config(arch)
    model = build(cfg)
    opt = AdamW(lr=lr)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq_len,
                       global_batch=global_batch, seed=seed)
    step_fn = jax.jit(make_train_step(
        model.loss_fn, opt, num_microbatches=num_microbatches,
        compress_grads=compress_grads,
        schedule=default_schedule(steps, warmup=max(steps // 20, 1))))

    params = model.init_params(jax.random.key(seed))
    state = opt.init(params)
    start = 0
    store = CheckpointStore(ckpt_dir) if ckpt_dir else None
    if store and resume and store.latest_step() is not None:
        restored, extra = store.restore({"params": params, "opt": state})
        params, state = restored["params"], restored["opt"]
        start = int(extra.get("data_step", 0))
        print(f"[train] resumed from step {start}")

    losses = []
    t0 = time.perf_counter()
    for i in range(start, steps):
        params, state, m = step_fn(params, state, data.batch(i))
        losses.append(float(m["loss"]))
        if log_every and (i + 1) % log_every == 0:
            dt = time.perf_counter() - t0
            print(f"[train] step {i+1}/{steps} loss={losses[-1]:.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({dt/ max(len(losses),1):.2f}s/step)")
        if store and (i + 1) % ckpt_every == 0:
            store.save_async(i + 1, {"params": params, "opt": state},
                             extra={"data_step": i + 1,
                                    "loss": losses[-1]})
    if store:
        store.wait()
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "params": n_params, "steps_run": len(losses)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU fleet; do not use on CPU)")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override reduced width (e.g. 512 for ~100M)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)
    out = train_loop(arch=args.arch, steps=args.steps,
                     global_batch=args.batch, seq_len=args.seq,
                     reduce_cfg=not args.full, lr=args.lr,
                     num_microbatches=args.microbatches,
                     compress_grads=args.compress_grads,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     resume=args.resume,
                     d_model=args.d_model, num_layers=args.layers)
    print(f"[train] done: {out['steps_run']} steps, "
          f"{out['params']/1e6:.1f}M params, "
          f"loss {out['first_loss']:.4f} -> {out['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
