"""Architecture registry: the 10 assigned architectures + reduced smoke configs."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced
from repro.configs.phi35_moe_42b import CONFIG as phi35_moe_42b
from repro.configs.deepseek_v2_236b import CONFIG as deepseek_v2_236b
from repro.configs.rwkv6_1b6 import CONFIG as rwkv6_1b6
from repro.configs.llama3_8b import CONFIG as llama3_8b
from repro.configs.llama32_1b import CONFIG as llama32_1b
from repro.configs.qwen3_14b import CONFIG as qwen3_14b
from repro.configs.deepseek_7b import CONFIG as deepseek_7b
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium
from repro.configs.paligemma_3b import CONFIG as paligemma_3b
from repro.configs.zamba2_7b import CONFIG as zamba2_7b
from repro.configs.llama31_70b import CONFIG as llama31_70b

# The paper's own profiling/serving model (not in the assigned 40 cells).
PAPER_MODEL: ModelConfig = llama31_70b

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        phi35_moe_42b,
        deepseek_v2_236b,
        rwkv6_1b6,
        llama3_8b,
        llama32_1b,
        qwen3_14b,
        deepseek_7b,
        seamless_m4t_medium,
        paligemma_3b,
        zamba2_7b,
    ]
}

# long_500k requires sub-quadratic attention: only SSM/hybrid archs run it
# (skip note: DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = {"rwkv6-1.6b", "zamba2-7b"}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))


def cells(include_skipped: bool = False):
    """All assigned (arch × shape) dry-run cells, honouring long_500k skips."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            skipped = shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            if skipped and not include_skipped:
                continue
            out.append((arch, shape, skipped))
    return out


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "LONG_CONTEXT_ARCHS",
    "get_config", "get_shape", "smoke_config", "reduced", "cells", "PAPER_MODEL",
]
