"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s. Configs are pure data — model
construction lives in ``repro.models``, sharding in ``repro.distributed``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    # trunk
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None  # per-expert hidden (defaults to d_ff)
    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # SSM / hybrid
    attn_free: bool = False          # RWKV6: no attention at all
    ssm_state: int = 0               # Mamba2 state size
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    shared_attn_every: int = 0       # Zamba2: shared attn block cadence
    shared_attn_lora_rank: int = 0   # per-invocation LoRA on shared block
    # encoder-decoder
    encoder_layers: int = 0
    # modality frontend stubs ([audio]/[vlm]): precomputed embeddings
    frontend: Optional[str] = None   # 'audio_stub' | 'siglip_stub'
    num_prefix_embeddings: int = 0   # frames / patches provided by input_specs
    # misc
    qk_norm: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    source: str = ""                 # provenance tag from the assignment

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def resolved_moe_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    # -- parameter accounting (used by roofline's useful-FLOPs ratio and the
    # power/latency lookup-table generator) --
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if self.attn_free:
            # RWKV6 time-mix: r,k,v,g,o projections + decay/bonus params
            return 5 * d * d + 2 * d
        if self.use_mla:
            p = d * self.kv_lora_rank                                   # W_DKV
            p += self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)  # W_UK, W_UV
            p += d * self.qk_rope_head_dim                              # shared rope key
            if self.q_lora_rank:
                p += d * self.q_lora_rank
                p += self.q_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            else:
                p += d * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
            p += self.num_heads * self.v_head_dim * d                   # W_O
            return p
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _ffn_params_dense(self) -> int:
        return 3 * self.d_model * self.d_ff  # SwiGLU

    def _ffn_params_expert(self) -> int:
        return 3 * self.d_model * self.resolved_moe_d_ff

    def param_count(self) -> int:
        """Total parameters (embeddings included once; tied heads counted once)."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = self._attn_params()
        if self.family == "ssm":      # RWKV: channel-mix ffn
            per_layer += 2 * d * self.d_ff + d * d
        elif self.family == "hybrid":
            # mamba2 block params
            d_in = self.ssm_expand * d
            per_layer = 2 * d * d_in + d_in * d + d_in * (2 * self.ssm_state + 8)
        elif self.is_moe:
            per_layer += self.num_experts * self._ffn_params_expert()
            per_layer += self.num_shared_experts * self._ffn_params_expert()
            per_layer += d * self.num_experts  # router
        else:
            per_layer += self._ffn_params_dense()
        total = emb + self.num_layers * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            # one shared attention+ffn block (+ tiny LoRA per invocation)
            shared = self._attn_params() + self._ffn_params_dense()
            n_inv = self.num_layers // self.shared_attn_every
            total += shared + n_inv * self.shared_attn_lora_rank * 4 * d
        if self.family == "encdec":
            # encoder stack + cross-attention in decoder
            enc = self.encoder_layers * (self._attn_params() + self._ffn_params_dense())
            cross = self.num_layers * self._attn_params()
            total += enc + cross
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (== param_count for dense)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = self._attn_params()
        per_layer += (self.experts_per_token + self.num_shared_experts) * self._ffn_params_expert()
        per_layer += d * self.num_experts
        return int(emb + self.num_layers * per_layer)

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes per generated/cached token (decode memory term)."""
        if self.attn_free:
            return 0  # recurrent state, O(1) in sequence
        if self.use_mla:
            per = self.kv_lora_rank + self.qk_rope_head_dim
            return self.num_layers * per * bytes_per_el
        hd = self.resolved_head_dim
        if self.family == "hybrid":
            n_attn = self.num_layers // max(1, self.shared_attn_every)
            return n_attn * 2 * self.num_kv_heads * hd * bytes_per_el
        n_layers = self.num_layers + (self.num_layers if self.family == "encdec" else 0)
        return n_layers * 2 * self.num_kv_heads * hd * bytes_per_el

    def matmul_param_count(self) -> int:
        """Active params that actually cost matmul FLOPs per token.

        The input embedding table is a gather (0 FLOPs); only the lm_head
        projection costs. Tied embeddings count once already (the single
        table IS the lm_head), so nothing is subtracted.
        """
        n = self.active_param_count()
        if not self.tie_embeddings:
            n -= self.vocab_size * self.d_model
        return int(n)

    def flops_per_token(self, seq_len: int, phase: str = "train") -> float:
        """Model FLOPs per token: 6·N_matmul·(1) + attention context term."""
        n = self.matmul_param_count()
        mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[phase]
        base = mult * n
        if not self.attn_free:
            n_attn = self.num_layers
            if self.family == "hybrid" and self.shared_attn_every:
                n_attn = self.num_layers // self.shared_attn_every
            if self.use_mla and phase == "decode":
                # absorbed decode attends over the latent: scores against
                # (kv_lora + rope) dims, values against kv_lora dims
                per_pos = self.num_heads * (2 * self.kv_lora_rank
                                            + self.qk_rope_head_dim)
            elif self.use_mla:
                # expanded train/prefill form: per-head qk and v dims
                per_pos = self.num_heads * (self.qk_nope_head_dim
                                            + self.qk_rope_head_dim
                                            + self.v_head_dim)
            else:
                per_pos = 2 * self.num_heads * self.resolved_head_dim
            # qk^T + av; causal halves the average context in prefill/train
            ctx = seq_len / 2 if phase in ("train", "prefill") else seq_len
            base += mult * n_attn * per_pos * ctx
        return base


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


# The four assigned LM shapes. ``decode_*`` / ``long_*`` lower ``serve_step``
# (one new token against a seq_len KV cache), not ``train_step``.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads else 4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.is_moe:
        base.update(num_experts=4, experts_per_token=min(2, cfg.experts_per_token),
                    num_shared_experts=min(1, cfg.num_shared_experts), moe_d_ff=64)
    if cfg.use_mla:
        base.update(kv_lora_rank=32, q_lora_rank=48, qk_rope_head_dim=8,
                    qk_nope_head_dim=16, v_head_dim=16, head_dim=None)
    if cfg.family in ("ssm", "hybrid"):
        base.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "hybrid":
        base.update(num_layers=4, shared_attn_every=2, shared_attn_lora_rank=4)
    if cfg.family == "encdec":
        base.update(encoder_layers=2)
    if cfg.num_prefix_embeddings:
        base.update(num_prefix_embeddings=8)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
