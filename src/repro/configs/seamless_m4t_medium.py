"""seamless-m4t-medium — encoder-decoder, audio frontend STUB.

The modality frontend provides precomputed frame embeddings via
``input_specs()`` (assignment rule for [audio] archs).

[arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,             # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    frontend="audio_stub",
    num_prefix_embeddings=160,  # precomputed audio frames fed to the encoder
    rope_theta=10000.0,
    source="arXiv:2308.11596",
)
