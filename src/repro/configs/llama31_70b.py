"""llama3.1-70b — the PAPER'S OWN profiling/serving model (§5.1).

Not part of the 40 assigned dry-run cells; this is the model the paper
profiles on H100 DGX + vLLM, so the Heron §5 experiments (goodput,
tradeoff, stickiness, elasticity) build their lookup tables against it.

[arXiv:2407.21783; meta-llama/Llama-3.1-70B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    source="arXiv:2407.21783",
)
