"""paligemma-3b — gemma decoder backbone, SigLIP frontend STUB (MQA kv=1).

The vision frontend provides precomputed patch embeddings via
``input_specs()`` (assignment rule for [vlm] archs).

[arXiv:2407.07726; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    frontend="siglip_stub",
    num_prefix_embeddings=256,  # 16x16 patches from the (stubbed) SigLIP tower
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2407.07726",
)
