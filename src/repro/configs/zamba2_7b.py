"""zamba2-7b — Mamba2 backbone + shared attention block (hybrid).

81 Mamba2 layers; one *shared* attention(+FFN) block is invoked every 6
layers with a per-invocation LoRA delta.

[arXiv:2411.15242; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    shared_attn_every=6,
    shared_attn_lora_rank=128,
    rope_theta=10000.0,
    source="arXiv:2411.15242",
)
