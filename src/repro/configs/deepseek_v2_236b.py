"""deepseek-v2-236b — MLA (kv_lora=512) + 2 shared / 160 routed top-6 MoE.

[arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: latent cache shared by all heads
    d_ff=1536,                 # per-expert hidden per the assignment
    vocab_size=102400,
    num_experts=160,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1536,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    rope_theta=10000.0,
    source="arXiv:2405.04434",
)
