"""Power & workload predictors (paper §2.3).

The paper's systems insight is that both wind generation and request
arrival have lag-1 autocorrelation ≥ 0.99 at 15-min granularity, so simple
time-series predictors are near-oracle and Heron can plan ahead. The
paper's own AI prediction framework is explicitly *orthogonal* work and is
treated as an oracle; we ship the same interface with three backends:

  ``oracle``       — returns the true next-slot value (paper's evaluation
                     setting for both planners);
  ``persistence``  — x̂_{t+1} = x_t (what autocorr 0.99 justifies);
  ``ar2``          — damped-trend AR: x̂ = x_t + β (x_t − x_{t−1}).

Predictors are *safe-sided* for power when ``margin`` > 0: the planner
plans against (1 − margin)·x̂ so residual mispredictions surface as spare
headroom, not request drops (Planner-S absorbs the rest, §5.3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import numpy as np

Kind = Literal["oracle", "persistence", "ar2"]


@dataclass
class SeriesPredictor:
    series: np.ndarray              # [T] ground truth
    kind: Kind = "oracle"
    margin: float = 0.0             # safe-side derating (power only)
    beta: float = 0.6               # damped-trend coefficient for ar2

    def predict(self, t: int) -> float:
        """Forecast for slot ``t`` made at the end of slot ``t-1``."""
        s = self.series
        if self.kind == "oracle" or t == 0:
            val = float(s[min(t, len(s) - 1)])
        elif self.kind == "persistence" or t == 1:
            val = float(s[t - 1])
        else:
            val = float(s[t - 1] + self.beta * (s[t - 1] - s[t - 2]))
        lo = float(s.min()) if len(s) else 0.0
        return max(lo, val * (1.0 - self.margin))

    def errors(self) -> np.ndarray:
        """Relative one-step-ahead errors over the whole series."""
        preds = np.array([self.predict(t) for t in range(1, len(self.series))])
        truth = self.series[1:]
        return np.abs(preds - truth) / np.maximum(np.abs(truth), 1e-9)


def autocorrelation(x: np.ndarray, lag: int = 1) -> float:
    x = np.asarray(x, float)
    a, b = x[:-lag], x[lag:]
    a = a - a.mean()
    b = b - b.mean()
    return float((a * b).mean() / (a.std() * b.std() + 1e-12))


def autocorr_by_granularity(x: np.ndarray, windows: list[int]) -> dict[int, float]:
    """Fig 7: aggregate to W-slot windows, report lag-1 autocorrelation."""
    out = {}
    for w in windows:
        n = (len(x) // w) * w
        agg = x[:n].reshape(-1, w).sum(axis=1)
        out[w] = autocorrelation(agg, 1)
    return out
