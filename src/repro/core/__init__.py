"""Heron — the paper's primary contribution (cross-site router)."""
from repro.core.lookup import LookupTable, build_table
from repro.core.planner_l import Plan, SiteSpec, plan_l
from repro.core.planner_s import plan_s
from repro.core.planning import (ColumnPool, ConstraintBuilder, GpuBudget,
                                 plan_objective)
from repro.core.router import HeronRouter
from repro.core.scheduler import Configurator, RequestScheduler

__all__ = ["LookupTable", "build_table", "Plan", "SiteSpec", "plan_l",
           "plan_s", "HeronRouter", "Configurator", "RequestScheduler",
           "ColumnPool", "ConstraintBuilder", "GpuBudget", "plan_objective"]
