"""Shared problem-construction layer for the Heron planners.

Both planners (Figs. 10/11), both baselines, and the decomposed fleet
solver enumerate the same object: *columns* — (site, lookup Row) pairs
whose integer multiplicity is the decision variable. Before this layer
existed, each consumer re-derived per-column cost/power/load/class/TP
arrays with its own Python loop; now they all draw from one columnar
pool and assemble their sparse constraint blocks through one builder.

  * ``TableSOA``       — struct-of-arrays over a ``LookupTable``'s rows,
    cached on the table instance (rows are immutable), plus a
    (cls, tp) → row-index map used to expand GPU budgets into columns.
  * ``ColumnPool``     — struct-of-arrays over (site, Row) columns:
    cost/power/load/cls/tp/freq/e2e plus the (s, c, t) group index that
    constraints (4)-(7) and the Configurator aggregate over.
  * ``ConstraintBuilder`` — accumulates ≤ / ≥ constraint blocks as
    vectorized COO triplets and emits the CSR matrices ``solve_milp``
    consumes. Blocks are appended in declaration order, so a builder-
    assembled problem is bit-identical to the historical hand-rolled
    loops (same (row, col, value) multiset → same canonical CSR).
  * ``GpuBudget``      — the columnar form of Planner-L's GPU_{s,c,t}
    grant. ``plan_s``, the router, and the fine simulator pass this
    around instead of re-materialising {(s,c,t): gpus} dicts per solve.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np
from scipy import sparse

from repro.core.lookup import LookupTable, Row

# (s, c, t) group keys: site-major integer encoding shared by every
# consumer that aggregates over (site, class, TP) groups — the pool's
# group index, GPU-budget aggregation, Configurator diffs, and the
# planners' constraint alignment. cls < _CLS_BASE and tp < _TP_BASE by
# construction (9 request classes; TP degrees are small powers of two).
_TP_BASE = 64
_CLS_BASE = 9


def sct_key(site: np.ndarray, cls: np.ndarray, tp) -> np.ndarray:
    """Encode (site, cls, tp) triples as sortable int64 keys."""
    tp = np.asarray(tp)
    if len(tp) and (tp.max() >= _TP_BASE or np.asarray(cls).max() >= _CLS_BASE):
        raise ValueError("sct_key: tp/cls out of encodable range")
    return (np.asarray(site).astype(np.int64) * (_CLS_BASE * _TP_BASE)
            + np.asarray(cls).astype(np.int64) * _TP_BASE
            + tp.astype(np.int64))


def sct_unkey(key: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Decode ``sct_key`` values back to (site, cls, tp) arrays."""
    key = np.asarray(key, dtype=np.int64)
    return (key // (_CLS_BASE * _TP_BASE),
            (key // _TP_BASE) % _CLS_BASE,
            key % _TP_BASE)


# ------------------------------------------------------------------
# table struct-of-arrays (cached per LookupTable)
# ------------------------------------------------------------------
class TableSOA:
    """Columnar view of a lookup table's rows + (cls, tp) index."""

    __slots__ = ("rows", "cls", "tp", "freq", "load", "power", "e2e",
                 "by_cls_tp")

    def __init__(self, table: LookupTable):
        rows = table.rows
        n = len(rows)
        self.rows = np.empty(n, dtype=object)
        self.cls = np.empty(n, dtype=np.intp)
        self.tp = np.empty(n, dtype=np.intp)
        self.freq = np.empty(n, dtype=float)
        self.load = np.empty(n, dtype=float)
        self.power = np.empty(n, dtype=float)
        self.e2e = np.empty(n, dtype=float)
        for i, r in enumerate(rows):
            self.rows[i] = r
            self.cls[i] = r.cls
            self.tp[i] = r.tp
            self.freq[i] = r.freq
            self.load[i] = r.load
            self.power[i] = r.power
            self.e2e[i] = r.e2e
        # (cls, tp) -> row indices, preserving table order (valid_rows order)
        self.by_cls_tp: dict[tuple[int, int], np.ndarray] = {}
        for i in range(n):
            self.by_cls_tp.setdefault(
                (int(self.cls[i]), int(self.tp[i])), []).append(i)
        self.by_cls_tp = {k: np.asarray(v, dtype=np.intp)
                          for k, v in self.by_cls_tp.items()}


def table_soa(table: LookupTable) -> TableSOA:
    """Cached columnar view of ``table`` (rows are immutable)."""
    soa = getattr(table, "_soa", None)
    if soa is None:
        soa = TableSOA(table)
        table._soa = soa
    return soa


# ------------------------------------------------------------------
# column pool
# ------------------------------------------------------------------
class ColumnPool:
    """Struct-of-arrays over the (site, Row) columns of one problem.

    ``row_idx`` indexes into the owning table's rows so ``columns()``
    can materialise the legacy list[(site, Row)] without a per-column
    attribute walk. ``sct`` lazily builds the (s, c, t) group index that
    the one-(f,l)-per-group and reconfiguration constraints range over;
    groups are ordered by sorted (s, c, t) key — exactly the historical
    ``sorted({...})`` enumeration.
    """

    __slots__ = ("table", "site", "row_idx", "cls", "tp", "freq", "load",
                 "power", "e2e", "num_sites", "_sct", "_columns",
                 "_cls_idx")

    def __init__(self, table: LookupTable, site: np.ndarray,
                 row_idx: np.ndarray, num_sites: int):
        soa = table_soa(table)
        self.table = table
        self.site = np.asarray(site, dtype=np.intp)
        self.row_idx = np.asarray(row_idx, dtype=np.intp)
        self.cls = soa.cls[self.row_idx]
        self.tp = soa.tp[self.row_idx]
        self.freq = soa.freq[self.row_idx]
        self.load = soa.load[self.row_idx]
        self.power = soa.power[self.row_idx]
        self.e2e = soa.e2e[self.row_idx]
        self.num_sites = int(num_sites)
        self._sct = None
        self._columns = None
        self._cls_idx = None

    def __len__(self) -> int:
        return self.site.shape[0]

    @classmethod
    def dense(cls, table: LookupTable, num_sites: int) -> "ColumnPool":
        """Every row at every site — Planner-L's search space."""
        R = len(table.rows)
        site = np.repeat(np.arange(num_sites, dtype=np.intp), R)
        row_idx = np.tile(np.arange(R, dtype=np.intp), num_sites)
        return cls(table, site, row_idx, num_sites)

    @classmethod
    def for_budget(cls, table: LookupTable, budget: "GpuBudget",
                   num_sites: int,
                   frozen: Optional[set] = None) -> "ColumnPool":
        """Planner-S's search space: rows matching granted (s, c, t)s."""
        soa = table_soa(table)
        frozen = frozen or set()
        sites_out, rows_out = [], []
        for s, c, t, g in zip(budget.site, budget.cls, budget.tp,
                              budget.gpus):
            if g <= 0 or (int(s), int(c), int(t)) in frozen:
                continue
            idx = soa.by_cls_tp.get((int(c), int(t)))
            if idx is None:
                continue
            rows_out.append(idx)
            sites_out.append(np.full(len(idx), s, dtype=np.intp))
        if not rows_out:
            return cls(table, np.empty(0, np.intp), np.empty(0, np.intp),
                       num_sites)
        return cls(table, np.concatenate(sites_out),
                   np.concatenate(rows_out), num_sites)

    def cost(self, objective: str,
             site_rate: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-column objective coefficients.

        ``"latency"`` -> E2E; ``"power"`` -> watts. The grid objectives
        ``"cost"`` ($/MWh) and ``"carbon"`` (gCO2/kWh) are power scaled
        by a per-site rate signal: ``site_rate`` is a relative [S]
        vector (mean ~1.0, e.g. price factors from the knowledge plane)
        gathered per column, so expensive/dirty sites price higher and
        the planner shifts load off them. Without ``site_rate`` they
        degrade to plain power (uniform rates change nothing).
        """
        if objective == "latency":
            return self.e2e
        if site_rate is not None and objective in ("cost", "carbon"):
            return self.power * np.asarray(site_rate, float)[self.site]
        return self.power

    def columns(self) -> list[tuple[int, Row]]:
        """Legacy list[(site, Row)] view (what ``Plan`` stores).

        Cached: every Plan built over this pool shares one list (treated
        as read-only everywhere), so per-slot re-plans at 10k sites stop
        paying an 860k-tuple materialisation per solve.
        """
        if self._columns is None:
            rows = table_soa(self.table).rows[self.row_idx]
            self._columns = list(zip(self.site.tolist(), rows.tolist()))
        return self._columns

    def column_arrays(self) -> tuple:
        """The (site, cls, tp, load, power, e2e) tuple ``Plan`` caches."""
        return (self.site, self.cls, self.tp.astype(float), self.load,
                self.power, self.e2e)

    def cls_index(self, c: int) -> np.ndarray:
        """Ascending column indices of class ``c`` (cached).

        The greedy fleet moves scan one class at a time; pre-splitting
        the pool turns their per-step fleet-wide masks into masks over
        one class's columns (~1/9 of the pool) without changing the
        candidate order.
        """
        if self._cls_idx is None:
            self._cls_idx = [np.nonzero(self.cls == k)[0] for k in range(9)]
        return self._cls_idx[c]

    def sct(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(codes [n], g_site, g_cls, g_tp) — (s, c, t) group index.

        Group g spans the columns with ``codes == g``; groups are sorted
        by (site, cls, tp) so constraint row order matches the
        historical ``sorted({(s, cls, tp)})`` enumeration bit-for-bit.
        """
        if self._sct is None:
            uniq, codes = np.unique(sct_key(self.site, self.cls, self.tp),
                                    return_inverse=True)
            g_site, g_cls, g_tp = (a.astype(np.intp)
                                   for a in sct_unkey(uniq))
            self._sct = (codes.astype(np.intp), g_site, g_cls, g_tp)
        return self._sct


# ------------------------------------------------------------------
# constraint builder
# ------------------------------------------------------------------
class _Block:
    __slots__ = ("rows", "cols", "data", "rhs", "nrows")

    def __init__(self):
        self.rows, self.cols, self.data, self.rhs = [], [], [], []
        self.nrows = 0

    def add(self, rows, cols, data, rhs):
        rows = np.asarray(rows, dtype=np.intp)
        rhs = np.atleast_1d(np.asarray(rhs, dtype=float))
        self.rows.append(rows + self.nrows)
        self.cols.append(np.asarray(cols, dtype=np.intp))
        self.data.append(np.asarray(data, dtype=float))
        self.rhs.append(rhs)
        self.nrows += len(rhs)

    def build(self, nv: int):
        if not self.rhs:
            return None, None
        A = sparse.csr_matrix(
            (np.concatenate(self.data),
             (np.concatenate(self.rows), np.concatenate(self.cols))),
            shape=(self.nrows, nv))
        return A, np.concatenate(self.rhs)


class ConstraintBuilder:
    """Vectorized COO accumulation of A_ub x ≤ b_ub and A_lb x ≥ b_lb.

    ``ub``/``lb`` append one *block* each call: ``rows`` are block-local
    row ids in [0, len(rhs)), offset automatically by the rows already
    emitted on that side. Duplicate (row, col) entries sum, exactly like
    the historical triplet lists.
    """

    def __init__(self, nv: int):
        self.nv = nv
        self._ub = _Block()
        self._lb = _Block()

    def ub(self, rows, cols, data, rhs) -> None:
        self._ub.add(rows, cols, data, rhs)

    def lb(self, rows, cols, data, rhs) -> None:
        self._lb.add(rows, cols, data, rhs)

    def build(self):
        A_ub, b_ub = self._ub.build(self.nv)
        A_lb, b_lb = self._lb.build(self.nv)
        return A_ub, b_ub, A_lb, b_lb


# ------------------------------------------------------------------
# columnar GPU budget (Planner-L -> Planner-S hand-off)
# ------------------------------------------------------------------
@dataclass(frozen=True)
class GpuBudget:
    """GPU_{s,c,t} in struct-of-arrays form, sorted by (site, cls, tp).

    The sort order is load-bearing — ``plan_s`` aligns constraint rows
    to budget entries with ``searchsorted`` — so construction re-sorts
    defensively if handed unsorted arrays.
    """

    site: np.ndarray            # [G] intp
    cls: np.ndarray             # [G] intp
    tp: np.ndarray              # [G] intp
    gpus: np.ndarray            # [G] int

    def __post_init__(self):
        key = sct_key(self.site, self.cls, self.tp)
        if len(key) and (np.diff(key) <= 0).any():
            order = np.argsort(key, kind="stable")
            for name in ("site", "cls", "tp", "gpus"):
                object.__setattr__(self, name, getattr(self, name)[order])

    @classmethod
    def from_plan(cls, plan) -> "GpuBudget":
        """Aggregate a plan's active columns — vectorized, no dict loop."""
        site, cls_, tp, _, _, _ = plan.column_arrays()
        counts = np.asarray(plan.counts)
        active = counts > 0
        if not active.any():
            z = np.empty(0, np.intp)
            return cls(z, z, z, np.empty(0, int))
        uniq, inv = np.unique(sct_key(site[active], cls_[active],
                                      tp[active].astype(np.intp)),
                              return_inverse=True)
        gpus = np.bincount(inv, weights=counts[active]
                           * tp[active]).astype(int)
        g_site, g_cls, g_tp = (a.astype(np.intp) for a in sct_unkey(uniq))
        return cls(g_site, g_cls, g_tp, gpus)

    @classmethod
    def coerce(cls, budget) -> "GpuBudget":
        """Accept a legacy {(s, c, t): gpus} dict or pass through."""
        if isinstance(budget, cls):
            return budget
        keys = sorted(budget)
        site = np.array([k[0] for k in keys], dtype=np.intp)
        cls_ = np.array([k[1] for k in keys], dtype=np.intp)
        tp = np.array([k[2] for k in keys], dtype=np.intp)
        gpus = np.array([budget[k] for k in keys], dtype=int)
        return cls(site, cls_, tp, gpus)

    def as_dict(self) -> dict[tuple[int, int, int], int]:
        return {(int(s), int(c), int(t)): int(g)
                for s, c, t, g in zip(self.site, self.cls, self.tp,
                                      self.gpus) if g > 0}

    def __len__(self) -> int:
        return len(self.gpus)


# ------------------------------------------------------------------
# greedy fleet-inventory moves (shared by the decomposed Planner-L
# solve and Planner-S's warm-start projection)
# ------------------------------------------------------------------
class FleetState:
    """Mutable fleet inventory for greedy cover / trim / swap moves.

    Tracks integer column counts plus the derived quantities greedy
    moves need: GPU headroom per *capacity group* (per site for
    Planner-L, per granted (s,c,t) budget group for Planner-S —
    ``gpu_key`` maps each column to its group), power headroom per
    site, per-class capacity, and the active operating point of each
    (s, c, t) group (the one-(f,l) rule: a live group only grows at its
    current point; pass ``enforce_sct=False`` for Fig. 11 problems,
    which have no such constraint).

    Drain accounting (R_L)
    ----------------------
    When ``old_group`` (previous live instance counts per (s, c, t)
    group, aligned to ``pool.sct()`` order) and ``r_limit`` are given,
    the state also tracks the fleet drain total
    ``Σ_g max(0, old_g − count_g)`` — the quantity the Fig. 10
    reconfiguration bound (6,7) caps. ``trim`` and the swap polish
    consult ``drain_headroom``/``removal_drain`` before removing live
    capacity, and ``project_drains`` restores feasibility when the
    incoming counts (independent per-site solutions) overshoot R_L.
    """

    def __init__(self, counts: np.ndarray, pool: ColumnPool,
                 cost: np.ndarray, gpu_cap: np.ndarray,
                 gpu_key: np.ndarray, power_w: np.ndarray,
                 enforce_sct: bool = True,
                 old_group: Optional[np.ndarray] = None,
                 r_limit: float = np.inf,
                 restore_best: Optional[np.ndarray] = None):
        self._gbest = restore_best
        self.counts = counts
        self.pool = pool
        self.cost = cost
        self._gpu_cap = np.asarray(gpu_cap, float)
        self._power_w = np.asarray(power_w, float)
        self.gpu_key = np.asarray(gpu_key, dtype=np.intp)
        self.enforce_sct = enforce_sct
        self.codes = pool.sct()[0]
        G = int(self.codes.max()) + 1 if len(self.codes) else 0
        self.group_row = np.full(G, -1, dtype=np.intp)
        act = np.nonzero(counts > 0)[0]
        self.group_row[self.codes[act]] = act
        self.gpu_left = (np.asarray(gpu_cap, float)
                         - np.bincount(self.gpu_key, weights=counts * pool.tp,
                                       minlength=len(gpu_cap)))
        self.pw_left = (np.asarray(power_w, float)
                        - np.bincount(pool.site, weights=counts * pool.power,
                                      minlength=pool.num_sites))
        self.cap = np.bincount(pool.cls, weights=counts * pool.load,
                               minlength=9)
        self.r_limit = float(r_limit)
        self._log: Optional[list] = None
        if old_group is None:
            self.old_group = None
            self.fleet_drains = 0.0
        else:
            self.old_group = np.asarray(old_group, float)
            self.group_count = np.bincount(self.codes, weights=counts,
                                           minlength=G).astype(float)
            self.drains = np.maximum(self.old_group - self.group_count, 0.0)
            self.fleet_drains = float(self.drains.sum())

    def _shift_group(self, g: int, delta: float) -> None:
        if self.old_group is None:
            return
        self.group_count[g] += delta
        d = max(0.0, self.old_group[g] - self.group_count[g])
        self.fleet_drains += d - self.drains[g]
        self.drains[g] = d

    def rebuild(self) -> None:
        """Recompute all derived state after an external counts rollback."""
        self.__init__(self.counts, self.pool, self.cost, self._gpu_cap,
                      self.gpu_key, self._power_w, self.enforce_sct,
                      self.old_group, self.r_limit, self._gbest)

    def log_begin(self) -> None:
        """Start recording add/remove ops for a cheap ``log_rollback``.

        The rollback replays the inverse ops, so it undoes the counts
        and headroom deltas in O(ops touched) instead of the O(fleet)
        ``counts.copy()`` + ``rebuild()`` pair. Float headrooms come
        back via ``x + a - a``, which can drift a ULP from the
        canonical bincount — deterministic, but not the byte-for-byte
        state ``rebuild()`` recomputes, so exact-replay paths
        (``plan_l`` / session cold mode) must keep using ``rebuild``.
        """
        self._log = []

    def log_commit(self) -> None:
        self._log = None

    def log_rollback(self) -> None:
        ops, self._log = self._log, None
        for j, k in reversed(ops):
            (self.remove if k > 0 else self.add)(j, abs(k))

    def drain_headroom(self) -> float:
        return self.r_limit - self.fleet_drains

    def removal_drain(self, j: int, k: int) -> float:
        """By how much removing ``k`` of column ``j`` grows fleet drains."""
        if self.old_group is None:
            return 0.0
        g = self.codes[j]
        return (max(0.0, self.old_group[g] - (self.group_count[g] - k))
                - self.drains[g])

    def add(self, j: int, k: int) -> None:
        p = self.pool
        if self._log is not None:
            self._log.append((j, k))
        self.counts[j] += k
        self.gpu_left[self.gpu_key[j]] -= k * p.tp[j]
        self.pw_left[p.site[j]] -= k * p.power[j]
        self.cap[p.cls[j]] += k * p.load[j]
        self.group_row[self.codes[j]] = j
        self._shift_group(self.codes[j], k)

    def remove(self, j: int, k: int) -> None:
        p = self.pool
        if self._log is not None:
            self._log.append((j, -k))
        self.counts[j] -= k
        self.gpu_left[self.gpu_key[j]] += k * p.tp[j]
        self.pw_left[p.site[j]] += k * p.power[j]
        self.cap[p.cls[j]] -= k * p.load[j]
        if self.counts[j] <= 0:
            self.group_row[self.codes[j]] = -1
        self._shift_group(self.codes[j], -k)

    def cover(self, c: int, deficit: float,
              budget: float = np.inf) -> Optional[float]:
        """Greedily add class-``c`` capacity until ``deficit`` is met.

        Each step scores every candidate by what covering the whole
        remaining deficit with it *alone* would cost, then commits only
        the non-overshooting floor part (>= 1 instance) — so bulk goes
        to the best rps-per-cost column while cheaper mixes for the
        final partial chunk stay reachable. Respects GPU/power headroom
        and (when ``enforce_sct``) the one-(f,l) rule. Stops early once
        the added cost exceeds ``budget`` (the swap pass's abort
        signal). Returns the cost added, or None if the deficit could
        not be fully covered — moves performed so far stay applied.
        """
        p = self.pool
        spent = 0.0
        idx_c = p.cls_index(c)
        while deficit > 1e-9:
            if spent > budget:
                return None
            ok = ((self.gpu_left[self.gpu_key[idx_c]] >= p.tp[idx_c])
                  & (self.pw_left[p.site[idx_c]] >= p.power[idx_c] - 1e-9))
            if self.enforce_sct:
                g_act = self.group_row[self.codes[idx_c]]
                ok &= (g_act < 0) | (g_act == idx_c)
            cand = idx_c[ok]
            if len(cand) == 0:
                return None
            k_room = np.minimum(
                (self.gpu_left[self.gpu_key[cand]]
                 // p.tp[cand]).astype(int),
                (self.pw_left[p.site[cand]] / p.power[cand]
                 + 1e-9).astype(int))
            fin = np.ceil(deficit / p.load[cand])
            i = int(np.argmin(fin * self.cost[cand]))
            j = int(cand[i])
            k = int(min(k_room[i],
                        max(1.0, np.floor(deficit / p.load[j]))))
            if k <= 0:
                return None
            self.add(j, k)
            spent += k * self.cost[j]
            deficit -= k * p.load[j]
        return spent

    def cover_all(self, load: np.ndarray) -> None:
        """Cover every class's shortfall vs ``load`` (best effort)."""
        for c in range(9):
            short = load[c] - self.cap[c]
            if short > 1e-9:
                self.cover(c, short)

    def shed_overdraw(self) -> None:
        """Shed instances at sites drawing beyond their power cap.

        Removal order is power-per-rps (free the most power per rps of
        capacity lost), so a follow-up ``cover_all`` can re-provision
        the lost load at power-feasible rows — the greedy equivalent of
        downclocking under a power drop, which a plain
        heaviest-contributor shed cannot express.
        """
        p = self.pool
        ppr = p.power / np.maximum(p.load, 1e-12)
        for s in np.nonzero(self.pw_left < -1e-9)[0]:
            idx = np.nonzero((p.site == s) & (self.counts > 0))[0]
            for j in idx[np.argsort(-ppr[idx], kind="stable")]:
                if self.pw_left[s] >= -1e-9:
                    break
                k = min(int(self.counts[j]),
                        int(np.ceil(-self.pw_left[s] / p.power[j])))
                if k > 0:
                    self.remove(j, k)

    def trim(self, load: np.ndarray) -> None:
        """Remove surplus instances, most-expensive-per-rps first.

        The drain-aware sibling of ``trim_surplus``: a removal that
        would push the fleet drain total past ``r_limit`` is capped to
        the column's no-drain slack (count above the group's old live
        count) plus the remaining drain headroom.
        """
        p = self.pool
        ratio = self.cost / np.maximum(p.load, 1e-12)
        for c in range(9):
            if self.cap[c] - load[c] <= 1e-12:
                continue
            idx_c = p.cls_index(c)
            idx = idx_c[self.counts[idx_c] > 0]
            idx = idx[np.argsort(-ratio[idx], kind="stable")]
            for j in idx:
                surplus = self.cap[c] - load[c]
                if surplus <= 1e-12:
                    break
                k = min(int(self.counts[j]), int(surplus / p.load[j]))
                if k > 0 and self.old_group is not None:
                    g = self.codes[j]
                    free = max(0.0, self.group_count[g] - self.old_group[g])
                    # drain-free slack stays removable even when the
                    # incoming counts already overshoot the budget
                    # (negative headroom must not swallow it)
                    k = min(k, int(free + max(0.0, self.drain_headroom())
                                   + 1e-9))
                if k > 0:
                    self.remove(j, k)

    def _group_best(self, score: Optional[np.ndarray] = None) -> np.ndarray:
        """Per group: index of its min-``score`` column (first on ties).

        Default score is cost per rps — the right metric for picking a
        group's operating point when the restored capacity should keep
        serving load (a per-instance-cheapest choice would park groups
        at their lightest load point and strand their GPUs).

        The default-score result is a pure function of (pool, cost), so
        it is cached per state (and a caller holding a precomputed copy
        can hand it in as ``restore_best`` to skip the fleet-wide
        argsort entirely — same bytes either way).
        """
        if score is None:
            if self._gbest is not None:
                return self._gbest
            score = self.cost / np.maximum(self.pool.load, 1e-12)
            G = len(self.group_row)
            order = np.argsort(score, kind="stable")[::-1]
            best = np.full(G, -1, dtype=np.intp)
            best[self.codes[order]] = order      # last write = min score
            self._gbest = best
            return best
        G = len(self.group_row)
        order = np.argsort(score, kind="stable")[::-1]
        best = np.full(G, -1, dtype=np.intp)
        best[self.codes[order]] = order          # last write = min score
        return best

    def project_drains(self) -> bool:
        """Restore live capacity until fleet drains fit ``r_limit``.

        Independent per-site solutions can jointly overshoot the fleet
        drain budget (λ_R prices drains but does not hard-cap them).
        This projection greedily re-adds instances to drained (s, c, t)
        groups — at the group's active operating point when it has one,
        else its cheapest row — cheapest-cost first; when no drained
        group has GPU/power headroom it evicts the most expensive
        no-drain instance at a drained group's site to make room. The
        all-old-live point is drain-free and feasible (old capacity is
        power-scaled before drains are counted), so this terminates
        inside the budget in all but pathological fractional-scaling
        corners; returns whether the budget is met.

        The common (eviction-free) case runs as a single cost-ascending
        walk over the drained groups instead of the historical
        one-add-per-fleet-scan loop — bit-identical, because before any
        eviction a group's restore row (its active point, else its
        cheapest) and that row's cost never change while it stays
        drained, and adds only *consume* headroom: the scan loop would
        keep re-picking the same cheapest group until it is restored or
        out of room, which is exactly the walk. Groups that run out of
        room mid-walk are exactly the ones the scan would drop from its
        candidate set, so on walk exhaustion the state matches the scan
        at its first no-fit iteration and the eviction loop takes over.
        """
        if self.old_group is None or self.fleet_drains <= self.r_limit + 1e-9:
            return True
        cheapest = self._group_best()
        if self._project_walk(cheapest):
            return True
        return self._project_evict(cheapest)

    def _project_walk(self, cheapest: np.ndarray) -> bool:
        """Eviction-free restore walk; False = blocked, needs evictions."""
        p = self.pool
        gs = np.nonzero(self.drains > 1e-9)[0]
        js = np.where(self.group_row[gs] >= 0, self.group_row[gs],
                      cheapest[gs])
        ok = js >= 0
        gs, js = gs[ok], js[ok]
        order = np.lexsort((np.arange(len(gs)), self.cost[js]))
        blocked = False
        for i in order:
            g, j = int(gs[i]), int(js[i])
            while self.drains[g] > 1e-9:
                room = min(
                    self.gpu_left[self.gpu_key[j]] // max(p.tp[j], 1),
                    np.floor(self.pw_left[p.site[j]]
                             / max(p.power[j], 1e-12) + 1e-9))
                if room < 1:
                    blocked = True
                    break
                k = int(min(room, np.ceil(self.drains[g] - 1e-9),
                            self.fleet_drains - self.r_limit + 1))
                self.add(j, max(1, k))
                if self.fleet_drains <= self.r_limit + 1e-9:
                    return True
        return not blocked or self.fleet_drains <= self.r_limit + 1e-9

    def _project_evict(self, cheapest: np.ndarray) -> bool:
        """The historical scan loop — reached only when restores need
        room freed by evicting no-drain instances at drained sites."""
        p = self.pool
        _, g_site, _, _ = p.sct()
        for _ in range(100_000):
            if self.fleet_drains <= self.r_limit + 1e-9:
                return True
            gs = np.nonzero(self.drains > 1e-9)[0]
            if len(gs) == 0:
                return True
            # restore column per drained group: active row, else cheapest
            js = np.where(self.group_row[gs] >= 0, self.group_row[gs],
                          cheapest[gs])
            ok = js >= 0
            js, grp = js[ok], gs[ok]
            room = np.minimum(
                self.gpu_left[self.gpu_key[js]] // np.maximum(p.tp[js], 1),
                np.floor(self.pw_left[p.site[js]]
                         / np.maximum(p.power[js], 1e-12) + 1e-9))
            fit = room >= 1
            if fit.any():
                cand, cgrp = js[fit], grp[fit]
                i = int(np.argmin(self.cost[cand]))
                j, g = int(cand[i]), int(cgrp[i])
                k = int(min(room[fit][i],
                            np.ceil(self.drains[g] - 1e-9),
                            self.fleet_drains - self.r_limit + 1))
                self.add(j, max(1, k))
                continue
            # no headroom: evict the most expensive no-drain instance at
            # a drained group's site, then retry the restore
            evicted = False
            for g in gs[np.argsort(-self.drains[gs], kind="stable")]:
                s = g_site[g]
                cand = np.nonzero((p.site == s) & (self.counts > 0))[0]
                cand = cand[[self.removal_drain(int(j), 1) <= 1e-9
                             for j in cand]]
                if len(cand):
                    self.remove(int(cand[np.argmax(self.cost[cand])]), 1)
                    evicted = True
                    break
            if not evicted:
                return False            # stuck — best effort (documented)
        return self.fleet_drains <= self.r_limit + 1e-9


def trim_surplus(counts: np.ndarray, pool: ColumnPool,
                 cost: np.ndarray, load: np.ndarray) -> None:
    """Remove surplus instances, most-expensive-per-rps first (in place)."""
    cap = np.bincount(pool.cls, weights=counts * pool.load, minlength=9)
    ratio = cost / np.maximum(pool.load, 1e-12)
    for c in range(9):
        surplus = cap[c] - load[c]
        if surplus <= 1e-12:
            continue
        idx_c = pool.cls_index(c)
        idx = idx_c[counts[idx_c] > 0]
        idx = idx[np.argsort(-ratio[idx], kind="stable")]
        for j in idx:
            if surplus <= 1e-12:
                break
            k = min(int(counts[j]), int(surplus / pool.load[j]))
            if k > 0:
                counts[j] -= k
                surplus -= k * pool.load[j]


def plan_objective(plan, drop_penalty: float,
                   objective: Optional[str] = None) -> float:
    """The ILP objective value a plan achieves: cost·x + penalty·slack."""
    _, _, _, _, power, e2e = plan.column_arrays()
    cost = e2e if (objective or plan.objective) == "latency" else power
    return float((plan.counts * cost).sum()
                 + drop_penalty * plan.unserved.sum())
