"""Baseline schedulers (paper §5.2 (c) and (d)).

(c) WRR + DynamoLLM: a weighted-round-robin router splits the workload
    across sites ∝ provisioned compute; each site runs a DynamoLLM-style
    scheduler that picks per-class (TP, f, load) minimizing power/energy,
    assuming a traditional DC — i.e. *power-variability agnostic* (it
    plans as if the site always has its full provisioned power).

(d) Greedy min-latency: assigns TP_max + highest frequency, capping each
    GPU instance's load at the per-class knee point of the latency-vs-load
    curve (the paper's fix for the naive lowest-load variant that strands
    ~33% of requests on capacity limits).

Both baselines produce the same ``Plan`` shape as the Heron planners, so
the simulator scores everyone identically: when the *actual* available
power at a site is below the plan's draw, whole instances brown out
(greedy highest-power-first shedding) and their load is dropped — exactly
the C1 failure mode of Fig. 8.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.lookup import LookupTable, Row
from repro.core.planner_l import Plan, SiteSpec, plan_l
from repro.core.planning import ColumnPool
from repro.core.scheduler import DispatchResult, RequestScheduler


def wrr_split(sites: list[SiteSpec], load_per_class: np.ndarray) -> list[np.ndarray]:
    """Split the global per-class load across sites ∝ provisioned GPUs."""
    w = np.array([s.num_gpus for s in sites], float)
    w = w / w.sum()
    return [load_per_class * wi for wi in w]


def dynamollm_site_plan(table: LookupTable, site: SiteSpec,
                        site_load: np.ndarray, time_limit: float = 30.0) -> Plan:
    """Site-local min-power assignment with *assumed-infinite* power.

    Pinned to the monolithic solve: the baseline is a fixed external
    reference (single-site ILPs are cheap), so its plans must not move
    when the Heron-side decomposition heuristics evolve.
    """
    inf_power = np.array([1e15])
    return plan_l(table, [site], inf_power, site_load, objective="power",
                  time_limit=time_limit, method="monolithic")


def baseline_wrr_dynamollm(table: LookupTable, sites: list[SiteSpec],
                           load_per_class: np.ndarray,
                           time_limit: float = 30.0) -> Plan:
    """Baseline (c): per-site DynamoLLM under a compute-proportional WRR.

    Each site's ILP runs over the same dense single-site column pool (the
    full lookup table), so the fleet plan is just the dense S-site pool
    with each site's solved counts scattered into its slice — no
    per-object merge loop.
    """
    splits = wrr_split(sites, load_per_class)
    S = len(sites)
    R = len(table.rows)
    pool = ColumnPool.dense(table, S)
    counts = np.zeros(S * R, dtype=int)
    unserved = np.zeros(9)
    for s, (site, sl) in enumerate(zip(sites, splits)):
        p = dynamollm_site_plan(table, site, sl, time_limit)
        counts[s * R:(s + 1) * R] = p.counts
        unserved += p.unserved
    return Plan(columns=pool.columns(), counts=counts,
                unserved=unserved, objective="power", status="baseline",
                solve_seconds=0.0, num_sites=S,
                _cols=pool.column_arrays(), _pool=pool)


def knee_points(table: LookupTable) -> dict[int, Row]:
    """Per class: the TP_max/f_max row at the knee of e2e-vs-load.

    Knee = the largest load whose marginal latency increase per doubling
    stays below 25% of the base latency (paper: "the latency increase
    before such a point is small").
    """
    out: dict[int, Row] = {}
    tp_max = max(table.hw.tp_degrees)
    f_max = table.hw.f_max
    for c in range(9):
        rows = [r for r in table.valid_rows(c)
                if r.tp == tp_max and abs(r.freq - f_max) < 1e-9]
        rows.sort(key=lambda r: r.load)
        if not rows:
            continue
        base = rows[0].e2e
        knee = rows[0]
        for r in rows[1:]:
            if r.e2e <= 1.25 * base:
                knee = r
            else:
                break
        out[c] = knee
    return out


def baseline_greedy_min_latency(table: LookupTable, sites: list[SiteSpec],
                                load_per_class: np.ndarray) -> Plan:
    """Baseline (d): TP_max + f_max instances at knee-point loads, WRR.

    Vectorized over sites: each class round is one array pass (ceil,
    min-with-headroom, headroom update), and the plan is built as a
    (site x knee-class) column pool — the historical per-site/per-class
    construction loop closed into 9 vector steps.
    """
    knees = knee_points(table)
    S = len(sites)
    splits = np.stack(wrr_split(sites, load_per_class))       # [S, 9]
    gpus_left = np.array([s.num_gpus for s in sites], dtype=int)
    kcls = sorted(knees)
    fit = np.zeros((S, len(kcls)), dtype=int)
    unserved = np.zeros(9)
    for k, c in enumerate(kcls):
        r = knees[c]
        sl = splits[:, c]
        need = np.where(sl > 0, np.ceil(sl / r.load), 0).astype(int)
        fit[:, k] = np.minimum(need, gpus_left // r.tp)
        gpus_left -= fit[:, k] * r.tp
        unserved[c] += float(((need - fit[:, k]) * r.load).sum())
    for c in range(9):
        if c not in knees:
            unserved[c] += float(np.maximum(splits[:, c], 0.0).sum())
    row_of = {id(r): i for i, r in enumerate(table.rows)}
    knee_idx = np.array([row_of[id(knees[c])] for c in kcls], dtype=np.intp)
    pool = ColumnPool(table, np.repeat(np.arange(S, dtype=np.intp), len(kcls)),
                      np.tile(knee_idx, S), S)
    return Plan(columns=pool.columns(), counts=fit.ravel(),
                unserved=unserved, objective="latency", status="baseline",
                solve_seconds=0.0, num_sites=S,
                _cols=pool.column_arrays(), _pool=pool)


# ------------------------------------------------------------------
# RoutingPolicy wrappers (see repro.sim.policy)
# ------------------------------------------------------------------
@dataclass
class _BaselinePolicy:
    """Shared lifecycle for the power-variability-agnostic baselines.

    They re-plan every slot from per-class load alone, never re-solve
    inside a slot (``plan_fine`` returns the standing plan), and ignore
    health feedback and scenario control events — the agnosticism the
    paper's §5.2 comparison is about. Dispatch runs through a plain WRR
    Request Scheduler (no packing, matching the week simulator's
    historical scoring of every policy).
    """
    table: LookupTable
    sites: list[SiteSpec]
    packing: bool = False
    _plan: Optional[Plan] = field(default=None, repr=False)
    _dispatcher: RequestScheduler = field(init=False, repr=False)

    def __post_init__(self):
        self._dispatcher = RequestScheduler(len(self.sites),
                                            packing=self.packing)

    def plan_fine(self, now: float, power_w: np.ndarray,
                  observed_load: np.ndarray) -> Plan:
        assert self._plan is not None, "plan_slot first"
        return self._plan

    def route(self, groups, arrivals: np.ndarray) -> DispatchResult:
        return self._dispatcher.dispatch(groups, arrivals)

    def observe(self, latency: np.ndarray, mask=None) -> None:
        pass                    # no health integration (by design)

    def on_event(self, event) -> None:
        pass                    # no control-plane integration (by design)


@dataclass
class WrrDynamoLLMPolicy(_BaselinePolicy):
    """Baseline (c) as a RoutingPolicy: WRR split + per-site DynamoLLM."""
    time_limit: float = 20.0
    name: str = "wrr_dynamollm"

    def plan_slot(self, pred_power_w: np.ndarray,
                  pred_load: np.ndarray) -> Plan:
        self._plan = baseline_wrr_dynamollm(self.table, self.sites, pred_load,
                                            time_limit=self.time_limit)
        return self._plan


@dataclass
class GreedyMinLatencyPolicy(_BaselinePolicy):
    """Baseline (d) as a RoutingPolicy: knee-point greedy min-latency."""
    name: str = "greedy_min_latency"

    def plan_slot(self, pred_power_w: np.ndarray,
                  pred_load: np.ndarray) -> Plan:
        self._plan = baseline_greedy_min_latency(self.table, self.sites,
                                                 pred_load)
        return self._plan


def shed_counts_batch(plan: Plan, actual_power_w: np.ndarray) -> np.ndarray:
    """Vectorized brownout shedding over a batch of power realizations.

    ``actual_power_w``: [S, B] available watts per site for B scenarios
    (e.g. the seconds between two Planner-S re-solves, where the plan —
    and hence the shed geometry — is constant). Returns the surviving
    instance counts, shape [n_columns, B].

    Semantics match ``apply_power_reality_reference`` exactly: per site,
    groups are shed whole-instance, worst power-per-served-rps first
    (stable ties), until the site's draw fits its budget. The greedy
    instance-by-instance loop closes to a cumsum: with groups in shed
    order, group j sheds ``clip(ceil((need - cum_before_j)/power_j),
    0, count_j)`` instances, where ``need = draw - budget``.
    """
    site, cls_, _, load, power, _ = plan.column_arrays()
    counts = plan.counts.astype(float)
    B = actual_power_w.shape[1]
    out = np.repeat(counts[:, None], B, axis=1)
    ratio = power / np.maximum(load, 1e-9)
    for s in range(plan.num_sites):
        cols = np.nonzero(site == s)[0]
        if cols.size == 0:
            continue
        order = cols[np.argsort(-ratio[cols], kind="stable")]
        grp_pow = counts[order] * power[order]
        cum = np.cumsum(grp_pow)
        need = cum[-1] - actual_power_w[s]                   # [B]
        over = need > 0
        if not over.any():
            continue
        before = cum - grp_pow                               # draw shed by prior groups
        shed = np.ceil((need[None, over] - before[:, None])
                       / np.maximum(power[order], 1e-12)[:, None])
        shed = np.clip(shed, 0.0, counts[order, None])
        out[order[:, None], np.nonzero(over)[0][None, :]] = (
            counts[order, None] - shed)
    return out


def apply_power_reality(plan: Plan, actual_power_w: np.ndarray) -> Plan:
    """Brown out instances where the plan draws more than reality provides.

    Variability-agnostic baselines routinely overshoot during droughts; we
    shed whole instance groups (highest power-per-rps first — the site
    keeps its most power-efficient capacity alive, which is the DynamoLLM-
    friendly assumption) until the site fits its actual power. Vectorized
    via ``shed_counts_batch``; the original loop survives as
    ``apply_power_reality_reference`` for equivalence testing.
    """
    counts = shed_counts_batch(plan, actual_power_w[:, None])[:, 0]
    _, cls_, _, load, _, _ = plan.column_arrays()
    extra_unserved = np.bincount(cls_, weights=(plan.counts - counts) * load,
                                 minlength=9)
    real = Plan(columns=plan.columns, counts=counts.astype(int),
                unserved=plan.unserved + extra_unserved,
                objective=plan.objective, status=plan.status + "+reality",
                solve_seconds=plan.solve_seconds, num_sites=plan.num_sites)
    real._cols = plan.column_arrays()      # same columns -> share the cache
    return real


def apply_power_reality_reference(plan: Plan,
                                  actual_power_w: np.ndarray) -> Plan:
    """Original per-instance shedding loop (equivalence oracle)."""
    S = plan.num_sites
    counts = plan.counts.copy()
    extra_unserved = np.zeros(9)
    for s in range(S):
        idx = [i for i, (site, r) in enumerate(plan.columns)
               if site == s and counts[i] > 0]
        draw = sum(counts[i] * plan.columns[i][1].power for i in idx)
        budget = actual_power_w[s]
        if draw <= budget:
            continue
        # shed order: worst power-per-served-rps first
        idx.sort(key=lambda i: plan.columns[i][1].power
                 / max(plan.columns[i][1].load, 1e-9), reverse=True)
        for i in idx:
            r = plan.columns[i][1]
            while counts[i] > 0 and draw > budget:
                counts[i] -= 1
                draw -= r.power
                extra_unserved[r.cls] += r.load
            if draw <= budget:
                break
    return Plan(columns=plan.columns, counts=counts,
                unserved=plan.unserved + extra_unserved,
                objective=plan.objective, status=plan.status + "+reality",
                solve_seconds=plan.solve_seconds, num_sites=S)
