"""Planner-S — the seconds-scale frequency/load re-planner (paper Fig. 11).

Planner-S keeps Planner-L's TP assignments (re-sharding is expensive) and
re-solves only the frequency and load dimension against *near-real-time*
power and workload, inside the GPU budget GPU_{s,c,t} that Planner-L
granted. Two effects (paper §5.3):

  * power drops below the 15-min prediction → downclock / shed load
    instead of dropping requests (elasticity);
  * power rises above it → upclock for better TTFT/TBT than planned.

The Fig. 11 ILP has no single-(f,l) constraint (no Y variables) — Planner-S
may split a config across frequencies; it is therefore much smaller and
runs in milliseconds-to-seconds even at 64 sites.

The problem is assembled over a ``ColumnPool`` restricted to the granted
(s, c, t) groups (see ``repro.core.planning``), and the budget itself
travels as a columnar ``GpuBudget`` (legacy dicts are coerced). Repeated
re-solves inside a slot pass ``warm=<previous plan>``: the previous
counts are mapped onto the current columns and handed to
``solve_milp``'s warm path, which accepts them after repair when they
sit within 1% of the fresh LP bound — the common case when power/load
moved a few percent between seconds (status ``"warm"``).
"""
from __future__ import annotations

from typing import Optional, Union

import numpy as np

from repro.core.lookup import LookupTable, Row
from repro.core.milp import solve_milp
from repro.core.planner_l import DROP_PENALTY, Objective, Plan, SiteSpec
from repro.core.planning import (ColumnPool, ConstraintBuilder, FleetState,
                                 GpuBudget, sct_key, trim_surplus)


def _warm_vector(warm: Plan, cols: list[tuple[int, Row]], pool: ColumnPool,
                 cost: np.ndarray, g_gpus: np.ndarray, codes: np.ndarray,
                 power_w: np.ndarray,
                 load_per_class: np.ndarray) -> np.ndarray:
    """Project a previous plan onto the current problem's column layout.

    Mapping the old counts alone is not enough: a feasible-but-stale
    point parks every load increase in the (heavily penalised) slack
    variables and keeps surplus instances on load decrease, so it would
    always fail ``solve_milp``'s LP-bound acceptance gap. The projection
    therefore also *optimizes at the margin* — trim surplus capacity
    (most expensive per rps first), then cover per-class shortfall with
    cheapest-completion columns inside the GPU-budget and power
    headroom (Fig. 11 has no one-(f,l) rule, so groups may mix points).
    Residual shortfall becomes slack.
    """
    n = len(pool)
    x0 = np.zeros(n + 9)
    wp = getattr(warm, "_pool", None)
    if wp is not None and wp.table is pool.table and len(wp):
        # vectorized join on (site, table-row) keys — the hot path when
        # chaining plan_s results (both plans carry their column pool)
        R = len(pool.table.rows)
        wkey = wp.site * R + wp.row_idx
        order = np.argsort(wkey, kind="stable")
        ckey = pool.site * R + pool.row_idx
        pos = np.clip(np.searchsorted(wkey[order], ckey), 0, len(order) - 1)
        hit = wkey[order][pos] == ckey
        x0[:n][hit] = np.asarray(warm.counts, float)[order][pos[hit]]
    else:
        prev = {(s, r): int(x)
                for (s, r), x in zip(warm.columns, warm.counts) if x > 0}
        if prev:
            x0[:n] = [prev.get(col, 0) for col in cols]
    load = np.maximum(np.asarray(load_per_class, float), 0.0)
    xc = x0[:n]
    trim_surplus(xc, pool, cost, load)
    st = FleetState(xc, pool, cost, g_gpus, codes, power_w,
                    enforce_sct=False)
    st.shed_overdraw()          # power dropped: free the worst W/rps
    st.cover_all(load)          # ... and re-cover at feasible rows
    x0[n:] = np.maximum(load - st.cap, 0.0)
    return x0


def plan_s(table: LookupTable, sites: list[SiteSpec], power_w: np.ndarray,
           load_per_class: np.ndarray,
           gpu_budget: Union[GpuBudget, dict],
           *, objective: Objective = "latency",
           frozen_sct: Optional[set] = None,
           time_limit: float = 10.0,
           warm: Optional[Plan] = None,
           site_rate: Optional[np.ndarray] = None) -> Plan:
    """Solve the Fig. 11 ILP.

    ``gpu_budget``: GPU_{s,c,t} from Planner-L's last plan — a columnar
    ``GpuBudget`` (``Plan.gpu_budget_pool()``) or a legacy dict.
    ``frozen_sct``: (s,c,t) groups with pending TP reconfigurations — the
    Configurator excludes them from placement (paper §4, Configurator).
    ``warm``: a previous Planner-S plan over the same budget; its counts
    seed the solve (see module docstring).
    ``site_rate``: per-site [S] price/carbon signal for the grid
    objectives ("cost"/"carbon") — see ``ColumnPool.cost``.
    """
    S = len(sites)
    budget = GpuBudget.coerce(gpu_budget)
    pool = ColumnPool.for_budget(table, budget, S, frozen_sct)
    n = len(pool)
    if n == 0:
        return Plan(columns=[], counts=np.zeros(0, int),
                    unserved=np.maximum(load_per_class, 0.0),
                    objective=objective, status="empty", solve_seconds=0.0,
                    num_sites=S)

    nv = n + 9
    iZ = np.arange(n)
    iSl = n + np.arange(9)
    c_vec = np.zeros(nv)
    c_vec[iZ] = pool.cost(objective, site_rate)
    c_vec[iSl] = DROP_PENALTY

    b = ConstraintBuilder(nv)
    # (1) per-site power cap at near-real-time power
    b.ub(pool.site, iZ, pool.power, np.asarray(power_w, float))
    # (3) per-(s,c,t) GPU budget from Planner-L — one row per granted
    # group that actually has columns, in sorted (s,c,t) order
    codes, g_site, g_cls, g_tp = pool.sct()
    g_key = sct_key(g_site, g_cls, g_tp)
    bud_key = sct_key(budget.site, budget.cls, budget.tp)
    g_gpus = budget.gpus[np.searchsorted(bud_key, g_key)].astype(float)
    b.ub(codes, iZ, pool.tp.astype(float), g_gpus)
    # (2) capacity with slack
    b.lb(np.concatenate([pool.cls, np.arange(9)]),
         np.concatenate([iZ, iSl]),
         np.concatenate([pool.load, np.ones(9)]),
         np.asarray(load_per_class, float))
    A_ub, b_ub, A_lb, b_lb = b.build()

    integrality = np.zeros(nv)
    integrality[iZ] = 1
    upper = np.full(nv, np.inf)
    upper[iZ] = (g_gpus[codes].astype(int)
                 // np.maximum(pool.tp, 1)).astype(float)
    upper[iSl] = np.maximum(load_per_class, 0.0)

    cols = pool.columns()
    x0 = (_warm_vector(warm, cols, pool, pool.cost(objective, site_rate),
                       g_gpus,
                       codes, np.asarray(power_w, float), load_per_class)
          if warm is not None else None)
    # two-part warm acceptance: slack terms tested separately from
    # completion cost, with a one-instance allowance *at the granularity
    # of the columns the LP actually leaves fractional* in slack-
    # saturated droughts (see core.milp docstring) — a pool-wide
    # load.max() allowance over-admitted drops whenever the pool merely
    # contained a large-instance group
    split = np.zeros(nv, bool)
    split[iSl] = True
    slack_unit = np.zeros(nv)
    slack_unit[iZ] = DROP_PENALTY * pool.load
    # the penalty test runs per class: each class's slack is measured
    # against its own fractional frontier, so a mixed pool does not
    # inherit the allowance of whichever class has the largest instances
    cls_vec = np.concatenate([pool.cls, np.arange(9)])
    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit, warm=x0, warm_split=split,
                     warm_slack_unit=slack_unit, warm_class=cls_vec)
    return Plan(columns=cols, counts=np.round(res.x[iZ]).astype(int),
                unserved=np.maximum(res.x[iSl], 0.0), objective=objective,
                status=res.status, solve_seconds=res.solve_seconds,
                num_sites=S, _cols=pool.column_arrays(), _pool=pool)
