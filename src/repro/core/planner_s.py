"""Planner-S — the seconds-scale frequency/load re-planner (paper Fig. 11).

Planner-S keeps Planner-L's TP assignments (re-sharding is expensive) and
re-solves only the frequency and load dimension against *near-real-time*
power and workload, inside the GPU budget GPU_{s,c,t} that Planner-L
granted. Two effects (paper §5.3):

  * power drops below the 15-min prediction → downclock / shed load
    instead of dropping requests (elasticity);
  * power rises above it → upclock for better TTFT/TBT than planned.

The Fig. 11 ILP has no single-(f,l) constraint (no Y variables) — Planner-S
may split a config across frequencies; it is therefore much smaller and
runs in milliseconds-to-seconds even at 64 sites.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
from scipy import sparse

from repro.core.lookup import LookupTable, Row
from repro.core.milp import solve_milp
from repro.core.planner_l import DROP_PENALTY, Objective, Plan, SiteSpec


def plan_s(table: LookupTable, sites: list[SiteSpec], power_w: np.ndarray,
           load_per_class: np.ndarray, gpu_budget: dict[tuple[int, int, int], int],
           *, objective: Objective = "latency",
           frozen_sct: Optional[set] = None,
           time_limit: float = 10.0) -> Plan:
    """Solve the Fig. 11 ILP.

    ``gpu_budget``: {(site, class, tp): gpus} from Planner-L's last plan.
    ``frozen_sct``: (s,c,t) groups with pending TP reconfigurations — the
    Configurator excludes them from placement (paper §4, Configurator).
    """
    S = len(sites)
    frozen = frozen_sct or set()
    # columns: only (s, row) whose (s, cls, tp) has a budget and is not frozen
    cols: list[tuple[int, Row]] = []
    for (s, cls, tp), gpus in gpu_budget.items():
        if gpus <= 0 or (s, cls, tp) in frozen:
            continue
        for r in table.valid_rows(cls):
            if r.tp == tp:
                cols.append((s, r))
    n = len(cols)
    if n == 0:
        return Plan(columns=[], counts=np.zeros(0, int),
                    unserved=np.maximum(load_per_class, 0.0),
                    objective=objective, status="empty", solve_seconds=0.0,
                    num_sites=S)

    col_cost = np.array([r.e2e if objective == "latency" else r.power
                         for _, r in cols])
    col_power = np.array([r.power for _, r in cols])
    col_load = np.array([r.load for _, r in cols])
    col_cls = np.array([r.cls for _, r in cols])
    col_site = np.array([s for s, _ in cols])
    col_tp = np.array([r.tp for _, r in cols])

    nv = n + 9
    iZ = np.arange(n)
    iSl = n + np.arange(9)
    c_vec = np.zeros(nv)
    c_vec[iZ] = col_cost
    c_vec[iSl] = DROP_PENALTY

    rows_ub, cols_ub, data_ub, b_ub = [], [], [], []

    def add_ub(terms, rhs):
        i = len(b_ub)
        for j, v in terms:
            rows_ub.append(i)
            cols_ub.append(j)
            data_ub.append(v)
        b_ub.append(rhs)

    # (1) per-site power cap at near-real-time power
    for s in range(S):
        mask = np.where(col_site == s)[0]
        add_ub([(iZ[j], float(col_power[j])) for j in mask], float(power_w[s]))
    # (3) per-(s,c,t) GPU budget from Planner-L
    keys = sorted(gpu_budget)
    for (s, cls, tp) in keys:
        mask = np.where((col_site == s) & (col_cls == cls) & (col_tp == tp))[0]
        if len(mask):
            add_ub([(iZ[j], float(col_tp[j])) for j in mask],
                   float(gpu_budget[(s, cls, tp)]))
    A_ub = sparse.csr_matrix((data_ub, (rows_ub, cols_ub)),
                             shape=(len(b_ub), nv))
    b_ub = np.array(b_ub)

    # (2) capacity with slack
    rows_lb, cols_lb, data_lb, b_lb = [], [], [], []
    for cidx in range(9):
        mask = np.where(col_cls == cidx)[0]
        i = len(b_lb)
        for j in mask:
            rows_lb.append(i)
            cols_lb.append(iZ[j])
            data_lb.append(float(col_load[j]))
        rows_lb.append(i)
        cols_lb.append(iSl[cidx])
        data_lb.append(1.0)
        b_lb.append(float(load_per_class[cidx]))
    A_lb = sparse.csr_matrix((data_lb, (rows_lb, cols_lb)),
                             shape=(len(b_lb), nv))
    b_lb = np.array(b_lb)

    integrality = np.zeros(nv)
    integrality[iZ] = 1
    upper = np.full(nv, np.inf)
    upper[iZ] = np.array([gpu_budget[(s, r.cls, r.tp)] // r.tp
                          for s, r in cols], float)
    upper[iSl] = np.maximum(load_per_class, 0.0)

    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    return Plan(columns=cols, counts=np.round(res.x[iZ]).astype(int),
                unserved=np.maximum(res.x[iSl], 0.0), objective=objective,
                status=res.status, solve_seconds=res.solve_seconds,
                num_sites=S)
