"""Planner-L — the 15-min lookahead ILP (paper Fig. 10).

Given per-site power/GPU budgets, predicted per-class peak load, and the
profiling lookup table, choose integer instance counts X_{c,f,t,s,l}
minimizing aggregate E2E latency (or power) subject to:

  (1) per-site GPU cap           (2) per-site power cap
  (3) per-class serving capacity (4,5) one (f,l) per (s,c,t) via binary Y
  (6,7) bounded reconfigurations vs the previous plan

Deviations from the literal Fig. 10 (documented in DESIGN.md):
  * Reconfiguration counting is at (s,c,t) granularity — *TP* changes,
    which is the stated intent ("Planner-L bounds TP reconfigurations") —
    and counts *drains* of live instances only: bring-up of fresh
    instances on idle GPUs is hidden by DynamoLLM-style background weight
    transfer (the paper adopts exactly this optimisation, K3), and
    capacity that already lost its power needs no drain. Without this,
    the diurnal load ramp itself would exhaust R_L — an artifact the
    paper's wording ("TP changes") clearly does not intend.
  * A per-class slack variable (heavily penalised) keeps the ILP feasible
    under extreme power droughts; slack == predicted request drops. The
    paper handles the same situation operationally ("min-latency converges
    to min-power in extreme resource-constrained cases").

Solve paths
-----------
The Fig. 10 ILP couples sites only through the per-class serving-capacity
constraint (3) — everything else ((1), (2), (4), (5)) is block-diagonal
per site. The monolithic HiGHS solve exploits none of that structure and
hits a wall around ~16 heterogeneous sites (~10 s/slot); the paper's own
premise (cross-farm complementarity) and follow-up systems (XWind-style
cross-site routing over dozens-to-hundreds of micro-DCs) live exactly in
the regime the monolith cannot reach. ``plan_l`` therefore has two paths:

  * ``method="monolithic"`` — the original single HiGHS branch-and-cut
    over the full column pool. Used below ``DECOMPOSE_THRESHOLD`` sites
    (default: always, for the paper's 4-site grid) so small-fleet results
    stay bit-comparable with earlier revisions.
  * ``method="decomposed"`` — Lagrangian price decomposition on (3):
    an LP relaxation of the aggregate problem yields per-class capacity
    prices (its duals) and fractional per-site capacity quotas (its
    solution); each site then solves a small independent ILP covering
    its quota at minimum cost, with declined quota priced at the fleet
    marginal λ_c; a surplus-trim and a greedy cheapest-column repair
    close the integrality gap, and a short subgradient loop re-prices
    classes that remain short. Sites the LP left idle are skipped
    outright — only the fleet's cheapest sites pay a MILP. This is
    a deliberate deviation from the literal Fig. 10 — the global R_L
    drain budget (6,7) couples sites and is *not* enforced across
    subproblems (each site still sees a drain-free objective); fleets
    that need the exact stickiness bound use the monolithic path. In
    exchange, 256-site fleets plan in seconds instead of tens of
    minutes, with objectives within ~1% of the monolith wherever the
    monolith can finish (tests/test_planning.py).

``method="auto"`` (the default) picks monolithic at or below
``DECOMPOSE_THRESHOLD`` sites and decomposed above it.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Literal, Optional

import numpy as np

from repro.core.lookup import LookupTable, Row
from repro.core.milp import solve_milp
from repro.core.planning import (ColumnPool, ConstraintBuilder, FleetState,
                                 GpuBudget, sct_key, sct_unkey, table_soa,
                                 trim_surplus)

DROP_PENALTY = 1e6          # per unserved rps — dominates any latency gain
DECOMPOSE_THRESHOLD = 24    # sites; above this, "auto" uses the decomposition
Objective = Literal["latency", "power"]
Method = Literal["auto", "monolithic", "decomposed"]


@dataclass(frozen=True)
class SiteSpec:
    name: str
    num_gpus: int


@dataclass
class Plan:
    """Solved assignment for one slot.

    Derived views (``gpu_used``/``power_used``/``capacity``/``mean_e2e``)
    are vectorized over cached per-column arrays (``column_arrays``) —
    shared zero-copy with the ``ColumnPool`` the planner solved over when
    available, built lazily otherwise — so they stay O(columns) numpy
    bincounts even when called every simulated second. ``group_table``
    returns the cached columnar dispatch table consumed by the Request
    Scheduler's fast path; ``gpu_budget_pool`` the columnar GPU_{s,c,t}
    grant consumed by Planner-S.
    """
    columns: list[tuple[int, Row]]          # (site, row) per column
    counts: np.ndarray                      # instances per column (int)
    unserved: np.ndarray                    # [9] rps that cannot be served
    objective: Objective
    status: str
    solve_seconds: float
    num_sites: int
    _cols: Optional[tuple] = field(default=None, repr=False, compare=False)
    _gtable: object = field(default=None, repr=False, compare=False)
    _pool: object = field(default=None, repr=False, compare=False)
    _bpool: object = field(default=None, repr=False, compare=False)

    def column_arrays(self) -> tuple:
        """(site, cls, tp, load, power, e2e) parallel arrays, cached."""
        if self._cols is None:
            if self._pool is not None:
                self._cols = self._pool.column_arrays()
            else:
                n = len(self.columns)
                site = np.empty(n, dtype=np.intp)
                cls_ = np.empty(n, dtype=np.intp)
                tp = np.empty(n, dtype=float)
                load = np.empty(n, dtype=float)
                power = np.empty(n, dtype=float)
                e2e = np.empty(n, dtype=float)
                for i, (s, r) in enumerate(self.columns):
                    site[i] = s
                    cls_[i] = r.cls
                    tp[i] = r.tp
                    load[i] = r.load
                    power[i] = r.power
                    e2e[i] = r.e2e
                self._cols = (site, cls_, tp, load, power, e2e)
        return self._cols

    def group_table(self):
        """Cached columnar view of the active groups (fast dispatch)."""
        if self._gtable is None:
            from repro.core.scheduler import GroupTable
            self._gtable = GroupTable.from_plan(self)
        return self._gtable

    # ---- derived views (vectorized) ----
    def gpu_used(self) -> np.ndarray:
        site, _, tp, _, _, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * tp,
                           minlength=self.num_sites)

    def power_used(self) -> np.ndarray:
        site, _, _, _, power, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * power,
                           minlength=self.num_sites)

    def capacity(self) -> np.ndarray:
        """[9] provisioned serving capacity in rps per class."""
        _, cls_, _, load, _, _ = self.column_arrays()
        return np.bincount(cls_, weights=self.counts * load, minlength=9)

    def mean_e2e(self, load_per_class: Optional[np.ndarray] = None) -> float:
        """Provisioned-capacity-weighted mean E2E latency.

        ``load_per_class`` is accepted for API compatibility but unused:
        the weighting is by provisioned rps (counts x row load), which is
        what the planner objective optimizes and what the comparisons in
        tests/benchmarks have always measured.
        """
        _, _, _, load, _, e2e = self.column_arrays()
        w = self.counts * load
        return float((w * e2e).sum()) / max(float(w.sum()), 1e-9)

    def total_power(self) -> float:
        return float(self.power_used().sum())

    def active(self) -> list[tuple[int, Row, int]]:
        return [(s, r, int(x)) for (s, r), x in zip(self.columns, self.counts)
                if x > 0]

    def gpu_budget_pool(self) -> GpuBudget:
        """GPU_{s,c,t} as a columnar pool — what Planner-S consumes.

        Cached like ``group_table``: the router re-reads it every
        simulated second between Planner-L solves.
        """
        if self._bpool is None:
            self._bpool = GpuBudget.from_plan(self)
        return self._bpool

    def gpu_budget(self) -> dict[tuple[int, int, int], int]:
        """GPU_{s,c,t} as a legacy dict (see ``gpu_budget_pool``)."""
        return self.gpu_budget_pool().as_dict()

    def wrr_weights(self) -> dict[int, list[tuple[int, Row, float]]]:
        """Per class: [(site, row, weight)] with weight ∝ provisioned rps."""
        cap = self.capacity()
        _, cls_, _, load, _, _ = self.column_arrays()
        counts = np.asarray(self.counts)
        active = np.nonzero((counts > 0) & (cap[cls_] > 0))[0]
        w = counts[active] * load[active] / cap[cls_[active]]
        out: dict[int, list[tuple[int, Row, float]]] = {c: [] for c in range(9)}
        for j, wj in zip(active.tolist(), w.tolist()):
            s, r = self.columns[j]
            out[r.cls].append((s, r, wj))
        return out

    def agg_by_sct(self) -> dict[tuple[int, int, int], int]:
        """Instance counts per (s, c, t) group — vectorized aggregation."""
        site, cls_, tp, _, _, _ = self.column_arrays()
        counts = np.asarray(self.counts)
        active = counts > 0
        if not active.any():
            return {}
        uniq, inv = np.unique(sct_key(site[active], cls_[active],
                                      tp[active].astype(np.intp)),
                              return_inverse=True)
        agg = np.bincount(inv, weights=counts[active]).astype(int)
        g_site, g_cls, g_tp = sct_unkey(uniq)
        return {(int(s), int(c), int(t)): int(a)
                for s, c, t, a in zip(g_site, g_cls, g_tp, agg)}


def build_columns(table: LookupTable, num_sites: int):
    """Legacy helper: the dense (site, Row) enumeration as a list."""
    return ColumnPool.dense(table, num_sites).columns()


# ------------------------------------------------------------------
# monolithic path (Fig. 10 verbatim)
# ------------------------------------------------------------------
def _solve_monolithic(pool: ColumnPool, sites: list[SiteSpec],
                      power_w: np.ndarray, load_per_class: np.ndarray,
                      objective: Objective, old: Optional[Plan],
                      r_frac: float, time_limit: float) -> Plan:
    S = len(sites)
    n = len(pool)
    col_cost = pool.cost(objective)
    codes, g_site, g_cls, g_tp = pool.sct()
    G = len(g_site)

    use_reconfig = old is not None
    # variable layout: [X (n) | Y (n) | slack (9) | R (G)]
    nv = n + n + 9 + (G if use_reconfig else 0)
    iX = np.arange(n)
    iY = n + np.arange(n)
    iSl = 2 * n + np.arange(9)
    iR = 2 * n + 9 + np.arange(G) if use_reconfig else None

    c_vec = np.zeros(nv)
    c_vec[iX] = col_cost
    c_vec[iSl] = DROP_PENALTY

    gpus = np.array([s.num_gpus for s in sites], float)
    N_total = float(gpus.sum())
    b = ConstraintBuilder(nv)
    # (1) per-site GPU cap ; (2) per-site power cap (interleaved rows)
    rhs12 = np.empty(2 * S)
    rhs12[0::2] = gpus
    rhs12[1::2] = np.asarray(power_w, float)
    b.ub(np.concatenate([2 * pool.site, 2 * pool.site + 1]),
         np.concatenate([iX, iX]),
         np.concatenate([pool.tp.astype(float), pool.power]), rhs12)
    # (4) one (f,l) per (s,c,t):  sum_{f,l} Y <= 1
    b.ub(codes, iY, np.ones(n), np.ones(G))
    # (5) X <= N_total * Y
    b.ub(np.concatenate([np.arange(n), np.arange(n)]),
         np.concatenate([iX, iY]),
         np.concatenate([np.ones(n), np.full(n, -N_total)]), np.zeros(n))
    # (6,7) reconfiguration bound: drains of *live* previous capacity only.
    # Old capacity at a site is first scaled by how much of the old plan's
    # power draw the new slot's power still supports — capacity whose power
    # died needs no drain (the instances are dark regardless).
    if use_reconfig:
        old_agg = _live_old_agg(old, power_w, pool)
        total_old = max(1.0, old_agg.sum())
        r_limit = max(1.0, r_frac * total_old)
        # drain count: R >= old_live - sum X   (growth is free)
        b.ub(np.concatenate([codes, np.arange(G)]),
             np.concatenate([iX, iR]),
             np.concatenate([-np.ones(n), -np.ones(G)]), -old_agg)
        b.ub(np.zeros(G, dtype=np.intp), iR, np.ones(G), [r_limit])
    # (3) capacity: sum X*load + slack_c >= Load_c
    b.lb(np.concatenate([pool.cls, np.arange(9)]),
         np.concatenate([iX, iSl]),
         np.concatenate([pool.load, np.ones(9)]),
         np.asarray(load_per_class, float))
    A_ub, b_ub, A_lb, b_lb = b.build()

    integrality = np.zeros(nv)
    integrality[iX] = 1
    integrality[iY] = 1
    upper = np.full(nv, np.inf)
    upper[iX] = (gpus[pool.site].astype(int)
                 // np.maximum(pool.tp, 1)).astype(float)
    upper[iY] = 1.0
    upper[iSl] = np.maximum(load_per_class, 0.0)

    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    x = res.x
    return Plan(columns=pool.columns(), counts=np.round(x[iX]).astype(int),
                unserved=np.maximum(x[iSl], 0.0), objective=objective,
                status=res.status, solve_seconds=res.solve_seconds,
                num_sites=S, _cols=pool.column_arrays(), _pool=pool)


def _live_old_agg(old: Plan, power_w: np.ndarray,
                  pool: ColumnPool) -> np.ndarray:
    """Old live instance counts per current (s,c,t) group, power-scaled."""
    _, g_site, g_cls, g_tp = pool.sct()
    g_key = sct_key(g_site, g_cls, g_tp)
    old_site, old_cls, old_tp, _, _, _ = old.column_arrays()
    old_power = old.power_used()
    scale = np.ones(old.num_sites)
    pos = old_power > 0
    scale[pos] = np.minimum(1.0, np.asarray(power_w, float)[:old.num_sites][pos]
                            / old_power[pos])
    old_key = sct_key(old_site, old_cls, old_tp.astype(np.intp))
    pos_idx = np.searchsorted(g_key, old_key)
    pos_idx = np.clip(pos_idx, 0, len(g_key) - 1)
    match = g_key[pos_idx] == old_key
    agg = np.zeros(len(g_key))
    np.add.at(agg, pos_idx[match],
              (np.asarray(old.counts, float) * scale[old_site])[match])
    return agg


# ------------------------------------------------------------------
# decomposed path (Lagrangian prices + per-site ILPs)
# ------------------------------------------------------------------
def _lp_master(pool: ColumnPool, gpus: np.ndarray, power_w: np.ndarray,
               load: np.ndarray,
               cost: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """LP relaxation of the aggregate problem: capacity prices + quotas.

    The LP drops integrality and the one-(f,l) constraint — it is the
    natural Lagrangian master: its capacity duals price one rps of each
    class at the margin, and its (fractional) solution says how much
    capacity of each class each site should provision. Returns
    (prices [9], x_lp [n]).
    """
    from scipy.optimize import linprog

    n = len(pool)
    nv = n + 9
    c_vec = np.concatenate([cost, np.full(9, DROP_PENALTY)])
    b = ConstraintBuilder(nv)
    b.ub(pool.site, np.arange(n), pool.tp.astype(float), gpus)
    b.ub(pool.site, np.arange(n), pool.power, np.asarray(power_w, float))
    # capacity as <=:  -(sum load x + slack) <= -Load_c
    b.ub(np.concatenate([pool.cls, np.arange(9)]),
         np.concatenate([np.arange(n), n + np.arange(9)]),
         np.concatenate([-pool.load, -np.ones(9)]),
         -np.asarray(load, float))
    A_ub, b_ub, _, _ = b.build()
    S = len(gpus)
    res = linprog(c_vec, A_ub=A_ub, b_ub=b_ub, method="highs")
    if not res.success:
        return np.zeros(9), np.zeros(n)
    prices = np.maximum(-res.ineqlin.marginals[2 * S: 2 * S + 9], 0.0)
    return prices, np.maximum(res.x[:n], 0.0)


def _site_subproblem(soa, cost_rows: np.ndarray, prices: np.ndarray,
                     quota: np.ndarray, gpus_s: float, power_s: float,
                     time_limit: float) -> np.ndarray:
    """Per-site ILP: meet the site's LP capacity quota at minimum cost.

    min Σ cost_j x_j + Σ_c λ_c u_c
    s.t. GPU cap, power cap, one (f,l) per (c,t),
         Σ_j load_j x_j + u_c >= quota_c.

    Unserved quota ``u_c`` is priced at the fleet marginal λ_c — the
    site covers its share only where local serving beats buying the
    capacity back at the fleet margin; what it declines flows to the
    global repair step. Returns integer counts over all table rows.
    """
    m = len(soa.cls)
    tp = soa.tp.astype(float)
    # (cls, tp) groups via the shared validated encoding (site fixed at 0)
    key = sct_key(np.zeros(m, dtype=np.intp), soa.cls, soa.tp)
    uniq, codes = np.unique(key, return_inverse=True)
    G = len(uniq)
    # variable layout: [X (m) | Y (m) | u (9)]
    nv = 2 * m + 9
    iX = np.arange(m)
    iY = m + np.arange(m)
    iU = 2 * m + np.arange(9)
    cap_j = np.maximum(gpus_s // np.maximum(soa.tp, 1), 0).astype(float)

    c_vec = np.zeros(nv)
    c_vec[iX] = cost_rows
    c_vec[iU] = prices
    b = ConstraintBuilder(nv)
    b.ub(np.zeros(m, np.intp), iX, tp, [gpus_s])
    b.ub(np.zeros(m, np.intp), iX, soa.power, [power_s])
    b.ub(codes, iY, np.ones(m), np.ones(G))
    b.ub(np.concatenate([np.arange(m), np.arange(m)]),
         np.concatenate([iX, iY]),
         np.concatenate([np.ones(m), -cap_j]), np.zeros(m))
    b.lb(np.concatenate([soa.cls, np.arange(9)]),
         np.concatenate([iX, iU]),
         np.concatenate([soa.load, np.ones(9)]), quota)
    A_ub, b_ub, A_lb, b_lb = b.build()
    integrality = np.zeros(nv)
    integrality[iX] = 1
    integrality[iY] = 1
    upper = np.concatenate([cap_j, np.ones(m), np.maximum(quota, 0.0)])
    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    return np.round(res.x[iX]).astype(int)


def _greedy_repair(counts: np.ndarray, pool: ColumnPool, cost: np.ndarray,
                   load: np.ndarray, gpus: np.ndarray,
                   power_w: np.ndarray) -> None:
    """Serve residual shortfall with cheapest-completion columns (in place)."""
    FleetState(counts, pool, cost, gpus, pool.site, power_w).cover_all(load)


def _swap_improve(counts: np.ndarray, pool: ColumnPool, cost: np.ndarray,
                  load: np.ndarray, gpus: np.ndarray, power_w: np.ndarray,
                  deadline: float, max_rounds: int = 8) -> None:
    """Cross-site 1-swap polish (in place).

    The per-site quota ILPs cannot mix load points inside one (s, c, t)
    group (constraint 4), so a site handed a 5-rps quota may round up to
    2x4-rps where the monolith would mix 4+1 across sites. Each round
    tries, per class, to evict one instance of the most expensive active
    column and re-cover the lost capacity with the fleet's cheapest
    columns; the swap commits only when it strictly lowers cost. This is
    exactly the cross-site granularity trade the monolithic ILP performs
    and the decomposition's last percent of optimality gap.
    """
    st = FleetState(counts, pool, cost, gpus, pool.site, power_w)
    for _ in range(max_rounds):
        improved = False
        for c in range(9):
            act = np.nonzero((pool.cls == c) & (counts > 0))[0]
            if len(act) == 0:
                continue
            j = act[np.argmax(cost[act])]
            saved = cost[j]
            before = counts.copy()
            st.remove(j, 1)
            deficit = load[c] - st.cap[c]
            added = (st.cover(c, deficit, budget=saved - 1e-9)
                     if deficit > 1e-9 else 0.0)
            if added is not None and added < saved - 1e-9:
                improved = True
            else:
                counts[:] = before
                st.__init__(counts, pool, cost, gpus, pool.site, power_w)
            if time.perf_counter() > deadline:
                return
        if not improved:
            return


def _solve_decomposed(pool: ColumnPool, sites: list[SiteSpec],
                      power_w: np.ndarray, load_per_class: np.ndarray,
                      objective: Objective, time_limit: float) -> Plan:
    t0 = time.perf_counter()
    S = len(sites)
    table = pool.table
    soa = table_soa(table)
    R = len(table.rows)
    gpus = np.array([s.num_gpus for s in sites], float)
    power = np.asarray(power_w, float)
    load = np.maximum(np.asarray(load_per_class, float), 0.0)
    cost = pool.cost(objective)
    row_cost = soa.e2e if objective == "latency" else soa.power

    prices, x_lp = _lp_master(pool, gpus, power, load, cost)
    # per-site per-class capacity quotas from the fractional LP optimum
    quotas = np.zeros((S, 9))
    np.add.at(quotas, (pool.site, pool.cls), x_lp * pool.load)
    counts = np.zeros(S * R, dtype=int)
    sub_tl = max(0.05, min(2.0, time_limit / max(1, S)))
    for s in range(S):
        if quotas[s].max() <= 1e-9:
            continue
        if time.perf_counter() - t0 > time_limit:
            break
        counts[s * R:(s + 1) * R] = _site_subproblem(
            soa, row_cost, prices, quotas[s], gpus[s], power[s], sub_tl)
    # Sites rationally *decline* quota priced exactly at the LP margin
    # (integer serving rounds up, declining does not), so the marginal
    # capacity of each class intentionally lands in the global repair
    # below — a ratio-greedy cover that is near-LP-optimal at the margin.
    # Do not re-price and re-solve on shortfall: forcing a declined
    # quota back onto its site makes a GPU-starved site serve at a worse
    # TP instead of exporting the load (observed as a 5% objective gap).

    fcounts = counts.astype(float)
    trim_surplus(fcounts, pool, cost, load)
    _greedy_repair(fcounts, pool, cost, load, gpus, power)
    _swap_improve(fcounts, pool, cost, load, gpus, power,
                  deadline=t0 + time_limit)
    counts = np.round(fcounts).astype(int)
    cap = np.bincount(pool.cls, weights=counts * pool.load, minlength=9)
    unserved = np.maximum(load - cap, 0.0)
    unserved[unserved <= 1e-9] = 0.0
    return Plan(columns=pool.columns(), counts=counts, unserved=unserved,
                objective=objective, status="decomposed",
                solve_seconds=time.perf_counter() - t0, num_sites=S,
                _cols=pool.column_arrays(), _pool=pool)


def plan_l(table: LookupTable, sites: list[SiteSpec], power_w: np.ndarray,
           load_per_class: np.ndarray, *, objective: Objective = "latency",
           old: Optional[Plan] = None, r_frac: float = 0.03,
           time_limit: float = 60.0, method: Method = "auto",
           decompose_threshold: int = DECOMPOSE_THRESHOLD) -> Plan:
    """Solve the Fig. 10 ILP for one 15-min slot.

    ``method`` selects the solve path (see module docstring): "auto"
    uses the monolithic HiGHS solve at or below ``decompose_threshold``
    sites (bit-comparable with the paper grid) and the Lagrangian
    per-site decomposition above it. The decomposed path does not
    enforce the cross-site R_L drain budget — ``old``/``r_frac`` only
    bind on the monolithic path (deviation documented in the module
    docstring).
    """
    S = len(sites)
    pool = ColumnPool.dense(table, S)
    if method == "auto":
        method = "decomposed" if S > decompose_threshold else "monolithic"
    if method == "decomposed":
        if old is not None:
            warnings.warn(
                "plan_l: the decomposed path does not enforce the R_L "
                "reconfiguration bound; old/r_frac are ignored "
                "(use method='monolithic' for exact stickiness)",
                RuntimeWarning, stacklevel=2)
        return _solve_decomposed(pool, sites, power_w, load_per_class,
                                 objective, time_limit)
    return _solve_monolithic(pool, sites, power_w, load_per_class, objective,
                             old, r_frac, time_limit)
