"""Planner-L — the 15-min lookahead ILP (paper Fig. 10).

Given per-site power/GPU budgets, predicted per-class peak load, and the
profiling lookup table, choose integer instance counts X_{c,f,t,s,l}
minimizing aggregate E2E latency (or power) subject to:

  (1) per-site GPU cap           (2) per-site power cap
  (3) per-class serving capacity (4,5) one (f,l) per (s,c,t) via binary Y
  (6,7) bounded reconfigurations vs the previous plan

Deviations from the literal Fig. 10 (documented in DESIGN.md):
  * Reconfiguration counting is at (s,c,t) granularity — *TP* changes,
    which is the stated intent ("Planner-L bounds TP reconfigurations") —
    and counts *drains* of live instances only: bring-up of fresh
    instances on idle GPUs is hidden by DynamoLLM-style background weight
    transfer (the paper adopts exactly this optimisation, K3), and
    capacity that already lost its power needs no drain. Without this,
    the diurnal load ramp itself would exhaust R_L — an artifact the
    paper's wording ("TP changes") clearly does not intend.
  * A per-class slack variable (heavily penalised) keeps the ILP feasible
    under extreme power droughts; slack == predicted request drops. The
    paper handles the same situation operationally ("min-latency converges
    to min-power in extreme resource-constrained cases").

Solve paths
-----------
The Fig. 10 ILP couples sites only through the per-class serving-capacity
constraint (3) and the fleet drain budget (6,7) — everything else ((1),
(2), (4), (5)) is block-diagonal per site. The monolithic HiGHS solve
exploits none of that structure and hits a wall around ~16 heterogeneous
sites (~10 s/slot); the paper's own premise (cross-farm complementarity)
and follow-up systems (XWind-style cross-site routing over dozens-to-
hundreds of micro-DCs) live exactly in the regime the monolith cannot
reach. ``plan_l`` therefore has two paths:

  * ``method="decomposed"`` (the default at every fleet size) —
    Lagrangian price decomposition on the coupling constraints. An LP
    relaxation of the aggregate problem — including the fleet drain
    budget — yields per-class capacity prices λ_c, a per-drain price
    λ_R (the budget row's dual), and fractional per-site capacity
    quotas. Each site then solves a small independent ILP covering its
    quota at minimum cost, with declined quota priced at the fleet
    marginal λ_c and drains of its live (s, c, t) groups priced at λ_R.
    Sites whose LP restriction rounds cleanly (residual shortfall
    within one-instance granularity) skip branch-and-cut outright —
    most do; the hard remainder are independent ILPs run in a
    ``ProcessPoolExecutor`` (``workers=``; contiguous chunks, results
    reassembled in site order — bit-identical to the sequential loop).
    A drain-aware surplus-trim, greedy cheapest-column repair, and a
    projection step that restores live capacity when the independent
    site solutions jointly overshoot R_L close the feasibility and
    integrality gaps; a drain-guarded cross-site 1-swap polish closes
    most of the rest. Fleet drains stay ≤ R_L on every slot
    (tests/test_planning.py) with objectives within ~1% of the monolith
    wherever the monolith can finish.
  * ``method="monolithic"`` — the original single HiGHS branch-and-cut
    over the full column pool, kept as the exact reference for parity
    tests and small-fleet A/B runs.

``method="auto"`` (the default) is an alias for ``decomposed``: the
two-regime site-count split is gone now that the decomposition enforces
the full Fig. 10 constraint set, R_L included.
"""
from __future__ import annotations

import os
import time
import warnings
from dataclasses import dataclass, field
from typing import Literal, Optional

import numpy as np

from repro.core.lookup import LookupTable, Row
from repro.core.milp import solve_milp
from repro.core.planning import (ColumnPool, ConstraintBuilder, FleetState,
                                 GpuBudget, sct_key, sct_unkey, table_soa)

DROP_PENALTY = 1e6          # per unserved rps — dominates any latency gain
Objective = Literal["latency", "power"]
Method = Literal["auto", "monolithic", "decomposed"]


@dataclass(frozen=True)
class SiteSpec:
    name: str
    num_gpus: int


@dataclass
class Plan:
    """Solved assignment for one slot.

    Derived views (``gpu_used``/``power_used``/``capacity``/``mean_e2e``)
    are vectorized over cached per-column arrays (``column_arrays``) —
    shared zero-copy with the ``ColumnPool`` the planner solved over when
    available, built lazily otherwise — so they stay O(columns) numpy
    bincounts even when called every simulated second. ``group_table``
    returns the cached columnar dispatch table consumed by the Request
    Scheduler's fast path; ``gpu_budget_pool`` the columnar GPU_{s,c,t}
    grant consumed by Planner-S.
    """
    columns: list[tuple[int, Row]]          # (site, row) per column
    counts: np.ndarray                      # instances per column (int)
    unserved: np.ndarray                    # [9] rps that cannot be served
    objective: Objective
    status: str
    solve_seconds: float
    num_sites: int
    _cols: Optional[tuple] = field(default=None, repr=False, compare=False)
    _gtable: object = field(default=None, repr=False, compare=False)
    _pool: object = field(default=None, repr=False, compare=False)
    _bpool: object = field(default=None, repr=False, compare=False)

    def column_arrays(self) -> tuple:
        """(site, cls, tp, load, power, e2e) parallel arrays, cached."""
        if self._cols is None:
            if self._pool is not None:
                self._cols = self._pool.column_arrays()
            else:
                n = len(self.columns)
                site = np.empty(n, dtype=np.intp)
                cls_ = np.empty(n, dtype=np.intp)
                tp = np.empty(n, dtype=float)
                load = np.empty(n, dtype=float)
                power = np.empty(n, dtype=float)
                e2e = np.empty(n, dtype=float)
                for i, (s, r) in enumerate(self.columns):
                    site[i] = s
                    cls_[i] = r.cls
                    tp[i] = r.tp
                    load[i] = r.load
                    power[i] = r.power
                    e2e[i] = r.e2e
                self._cols = (site, cls_, tp, load, power, e2e)
        return self._cols

    def group_table(self):
        """Cached columnar view of the active groups (fast dispatch)."""
        if self._gtable is None:
            from repro.core.scheduler import GroupTable
            self._gtable = GroupTable.from_plan(self)
        return self._gtable

    # ---- derived views (vectorized) ----
    def gpu_used(self) -> np.ndarray:
        site, _, tp, _, _, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * tp,
                           minlength=self.num_sites)

    def power_used(self) -> np.ndarray:
        site, _, _, _, power, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * power,
                           minlength=self.num_sites)

    def capacity(self) -> np.ndarray:
        """[9] provisioned serving capacity in rps per class."""
        _, cls_, _, load, _, _ = self.column_arrays()
        return np.bincount(cls_, weights=self.counts * load, minlength=9)

    def mean_e2e(self, load_per_class: Optional[np.ndarray] = None) -> float:
        """Provisioned-capacity-weighted mean E2E latency.

        ``load_per_class`` is accepted for API compatibility but unused:
        the weighting is by provisioned rps (counts x row load), which is
        what the planner objective optimizes and what the comparisons in
        tests/benchmarks have always measured.
        """
        _, _, _, load, _, e2e = self.column_arrays()
        w = self.counts * load
        return float((w * e2e).sum()) / max(float(w.sum()), 1e-9)

    def total_power(self) -> float:
        return float(self.power_used().sum())

    def active(self) -> list[tuple[int, Row, int]]:
        return [(s, r, int(x)) for (s, r), x in zip(self.columns, self.counts)
                if x > 0]

    def gpu_budget_pool(self) -> GpuBudget:
        """GPU_{s,c,t} as a columnar pool — what Planner-S consumes.

        Cached like ``group_table``: the router re-reads it every
        simulated second between Planner-L solves.
        """
        if self._bpool is None:
            self._bpool = GpuBudget.from_plan(self)
        return self._bpool

    def gpu_budget(self) -> dict[tuple[int, int, int], int]:
        """GPU_{s,c,t} as a legacy dict (see ``gpu_budget_pool``)."""
        return self.gpu_budget_pool().as_dict()

    def wrr_weights(self) -> dict[int, list[tuple[int, Row, float]]]:
        """Per class: [(site, row, weight)] with weight ∝ provisioned rps."""
        cap = self.capacity()
        _, cls_, _, load, _, _ = self.column_arrays()
        counts = np.asarray(self.counts)
        active = np.nonzero((counts > 0) & (cap[cls_] > 0))[0]
        w = counts[active] * load[active] / cap[cls_[active]]
        out: dict[int, list[tuple[int, Row, float]]] = {c: [] for c in range(9)}
        for j, wj in zip(active.tolist(), w.tolist()):
            s, r = self.columns[j]
            out[r.cls].append((s, r, wj))
        return out

    def agg_by_sct(self) -> dict[tuple[int, int, int], int]:
        """Instance counts per (s, c, t) group — vectorized aggregation."""
        site, cls_, tp, _, _, _ = self.column_arrays()
        counts = np.asarray(self.counts)
        active = counts > 0
        if not active.any():
            return {}
        uniq, inv = np.unique(sct_key(site[active], cls_[active],
                                      tp[active].astype(np.intp)),
                              return_inverse=True)
        agg = np.bincount(inv, weights=counts[active]).astype(int)
        g_site, g_cls, g_tp = sct_unkey(uniq)
        return {(int(s), int(c), int(t)): int(a)
                for s, c, t, a in zip(g_site, g_cls, g_tp, agg)}


def build_columns(table: LookupTable, num_sites: int):
    """Legacy helper: the dense (site, Row) enumeration as a list."""
    return ColumnPool.dense(table, num_sites).columns()


# ------------------------------------------------------------------
# monolithic path (Fig. 10 verbatim)
# ------------------------------------------------------------------
def _solve_monolithic(pool: ColumnPool, sites: list[SiteSpec],
                      power_w: np.ndarray, load_per_class: np.ndarray,
                      objective: Objective, old: Optional[Plan],
                      r_frac: float, time_limit: float) -> Plan:
    S = len(sites)
    n = len(pool)
    col_cost = pool.cost(objective)
    codes, g_site, g_cls, g_tp = pool.sct()
    G = len(g_site)

    use_reconfig = old is not None
    # variable layout: [X (n) | Y (n) | slack (9) | R (G)]
    nv = n + n + 9 + (G if use_reconfig else 0)
    iX = np.arange(n)
    iY = n + np.arange(n)
    iSl = 2 * n + np.arange(9)
    iR = 2 * n + 9 + np.arange(G) if use_reconfig else None

    c_vec = np.zeros(nv)
    c_vec[iX] = col_cost
    c_vec[iSl] = DROP_PENALTY

    gpus = np.array([s.num_gpus for s in sites], float)
    N_total = float(gpus.sum())
    b = ConstraintBuilder(nv)
    # (1) per-site GPU cap ; (2) per-site power cap (interleaved rows)
    rhs12 = np.empty(2 * S)
    rhs12[0::2] = gpus
    rhs12[1::2] = np.asarray(power_w, float)
    b.ub(np.concatenate([2 * pool.site, 2 * pool.site + 1]),
         np.concatenate([iX, iX]),
         np.concatenate([pool.tp.astype(float), pool.power]), rhs12)
    # (4) one (f,l) per (s,c,t):  sum_{f,l} Y <= 1
    b.ub(codes, iY, np.ones(n), np.ones(G))
    # (5) X <= N_total * Y
    b.ub(np.concatenate([np.arange(n), np.arange(n)]),
         np.concatenate([iX, iY]),
         np.concatenate([np.ones(n), np.full(n, -N_total)]), np.zeros(n))
    # (6,7) reconfiguration bound: drains of *live* previous capacity only.
    # Old capacity at a site is first scaled by how much of the old plan's
    # power draw the new slot's power still supports — capacity whose power
    # died needs no drain (the instances are dark regardless).
    if use_reconfig:
        old_agg = _live_old_agg(old, power_w, pool)
        r_limit = _drain_budget(old_agg, r_frac)
        # drain count: R >= old_live - sum X   (growth is free)
        b.ub(np.concatenate([codes, np.arange(G)]),
             np.concatenate([iX, iR]),
             np.concatenate([-np.ones(n), -np.ones(G)]), -old_agg)
        b.ub(np.zeros(G, dtype=np.intp), iR, np.ones(G), [r_limit])
    # (3) capacity: sum X*load + slack_c >= Load_c
    b.lb(np.concatenate([pool.cls, np.arange(9)]),
         np.concatenate([iX, iSl]),
         np.concatenate([pool.load, np.ones(9)]),
         np.asarray(load_per_class, float))
    A_ub, b_ub, A_lb, b_lb = b.build()

    integrality = np.zeros(nv)
    integrality[iX] = 1
    integrality[iY] = 1
    upper = np.full(nv, np.inf)
    upper[iX] = (gpus[pool.site].astype(int)
                 // np.maximum(pool.tp, 1)).astype(float)
    upper[iY] = 1.0
    upper[iSl] = np.maximum(load_per_class, 0.0)

    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    x = res.x
    return Plan(columns=pool.columns(), counts=np.round(x[iX]).astype(int),
                unserved=np.maximum(x[iSl], 0.0), objective=objective,
                status=res.status, solve_seconds=res.solve_seconds,
                num_sites=S, _cols=pool.column_arrays(), _pool=pool)


def _drain_budget(old_agg: np.ndarray, r_frac: float) -> float:
    """R_L in instances: r_frac of the (power-scaled) live fleet, ≥ 1."""
    return max(1.0, r_frac * max(1.0, float(old_agg.sum())))


def _live_scale(old: Plan, power_w: np.ndarray) -> np.ndarray:
    """Per-site survival fraction of the old plan's power draw."""
    old_power = old.power_used()
    scale = np.ones(old.num_sites)
    pos = old_power > 0
    scale[pos] = np.minimum(
        1.0, np.asarray(power_w, float)[:old.num_sites][pos] / old_power[pos])
    return scale


def fleet_drains(old: Plan, new: Plan, power_w: np.ndarray) -> float:
    """Σ_g max(0, live_old_g − new_g) — the drain total R_L bounds.

    Counts drains of *live* previous capacity at (s, c, t) granularity,
    with old capacity power-scaled exactly as the planners scale it
    (capacity whose power died needs no drain). Public so tests and
    benchmarks can audit any plan pair against the budget.
    """
    pool = getattr(new, "_pool", None)
    if pool is not None and len(pool):
        old_agg = _live_old_agg(old, np.asarray(power_w, float), pool)
        new_g = np.bincount(pool.sct()[0],
                            weights=np.asarray(new.counts, float),
                            minlength=len(old_agg))
        return float(np.maximum(old_agg - new_g, 0.0).sum())
    scale = _live_scale(old, power_w)
    new_agg = new.agg_by_sct()
    return float(sum(max(0.0, v * scale[k[0]] - new_agg.get(k, 0))
                     for k, v in old.agg_by_sct().items()))


def drain_limit(old: Plan, power_w: np.ndarray, r_frac: float) -> float:
    """The R_L budget the planner enforces for this (old, power) slot."""
    scale = _live_scale(old, power_w)
    site = old.column_arrays()[0]
    total = float((np.asarray(old.counts, float) * scale[site]).sum())
    return max(1.0, r_frac * max(1.0, total))


def _live_old_agg(old: Plan, power_w: np.ndarray,
                  pool: ColumnPool) -> np.ndarray:
    """Old live instance counts per current (s,c,t) group, power-scaled."""
    _, g_site, g_cls, g_tp = pool.sct()
    g_key = sct_key(g_site, g_cls, g_tp)
    old_site, old_cls, old_tp, _, _, _ = old.column_arrays()
    scale = _live_scale(old, power_w)
    old_key = sct_key(old_site, old_cls, old_tp.astype(np.intp))
    pos_idx = np.searchsorted(g_key, old_key)
    pos_idx = np.clip(pos_idx, 0, len(g_key) - 1)
    match = g_key[pos_idx] == old_key
    agg = np.zeros(len(g_key))
    np.add.at(agg, pos_idx[match],
              (np.asarray(old.counts, float) * scale[old_site])[match])
    return agg


# ------------------------------------------------------------------
# decomposed path (Lagrangian prices + per-site ILPs)
# ------------------------------------------------------------------
def _lp_master(pool: ColumnPool, gpus: np.ndarray, power_w: np.ndarray,
               load: np.ndarray, cost: np.ndarray,
               old_agg: Optional[np.ndarray] = None,
               r_limit: float = np.inf
               ) -> tuple[np.ndarray, float, np.ndarray]:
    """LP relaxation of the aggregate problem: prices + quotas.

    The LP drops integrality and the one-(f,l) constraint — it is the
    natural Lagrangian master: its capacity duals price one rps of each
    class at the margin, its (fractional) solution says how much
    capacity of each class each site should provision, and — when an
    old plan is present — the dual of its fleet drain-budget row prices
    one drained live instance at the margin (λ_R). Returns
    (prices [9], λ_R, x_lp [n]).
    """
    from scipy.optimize import linprog

    n = len(pool)
    if old_agg is not None:
        codes = pool.sct()[0]
        G = len(pool.sct()[1])
        dgrp = np.nonzero(old_agg > 1e-9)[0]
    else:
        dgrp = np.empty(0, dtype=np.intp)
    Gd = len(dgrp)
    nv = n + 9 + Gd
    c_vec = np.concatenate([cost, np.full(9, DROP_PENALTY), np.zeros(Gd)])
    b = ConstraintBuilder(nv)
    b.ub(pool.site, np.arange(n), pool.tp.astype(float), gpus)
    b.ub(pool.site, np.arange(n), pool.power, np.asarray(power_w, float))
    # capacity as <=:  -(sum load x + slack) <= -Load_c
    b.ub(np.concatenate([pool.cls, np.arange(9)]),
         np.concatenate([np.arange(n), n + np.arange(9)]),
         np.concatenate([-pool.load, -np.ones(9)]),
         -np.asarray(load, float))
    if Gd:
        # drain link per live group:  -Σ_{j∈g} x_j - d_g <= -old_g
        gmap = np.full(G, -1, dtype=np.intp)
        gmap[dgrp] = np.arange(Gd)
        loc = gmap[codes]
        msk = loc >= 0
        b.ub(np.concatenate([loc[msk], np.arange(Gd)]),
             np.concatenate([np.arange(n)[msk], n + 9 + np.arange(Gd)]),
             np.concatenate([-np.ones(int(msk.sum())), -np.ones(Gd)]),
             -old_agg[dgrp])
        # fleet drain budget:  Σ_g d_g <= R_L   (dual → λ_R)
        b.ub(np.zeros(Gd, dtype=np.intp), n + 9 + np.arange(Gd),
             np.ones(Gd), [float(r_limit)])
    A_ub, b_ub, _, _ = b.build()
    S = len(gpus)
    res = linprog(c_vec, A_ub=A_ub, b_ub=b_ub, method="highs")
    if not res.success:
        return np.zeros(9), 0.0, np.zeros(n)
    marg = res.ineqlin.marginals
    prices = np.maximum(-marg[2 * S: 2 * S + 9], 0.0)
    lam_r = float(max(-marg[-1], 0.0)) if Gd else 0.0
    return prices, lam_r, np.maximum(res.x[:n], 0.0)


def _site_subproblem(shared: tuple, sub: tuple) -> np.ndarray:
    """Per-site ILP: meet the site's LP capacity quota at minimum cost.

    min Σ cost_j x_j + Σ_c λ_c u_c + λ_R Σ_g d_g
    s.t. GPU cap, power cap, one (f,l) per (c,t),
         Σ_j load_j x_j + u_c >= quota_c,
         Σ_{j∈g} x_j + d_g >= old_g          (live groups only).

    Unserved quota ``u_c`` is priced at the fleet marginal λ_c — the
    site covers its share only where local serving beats buying the
    capacity back at the fleet margin; what it declines flows to the
    global repair step. Drains ``d_g`` of the site's live previous
    capacity are priced at the fleet drain marginal λ_R, so a site only
    walks away from running instances when the re-placement win beats
    the fleet's going drain price; the hard R_L cap itself is restored
    globally by ``FleetState.project_drains``.

    When ``x0`` (the master LP's restriction to this site) is given,
    the solve is warm-started by rounding: the restriction is projected
    onto one (f, l) per group and floored — always feasible (caps only
    shrink, declined quota is priced slack) — and *accepted outright*
    when every class's residual shortfall sits within one-instance
    rounding granularity, because that residue is exactly what the
    integer program could not serve either (it would round up where the
    fleet margin says decline) and the global repair re-covers it at
    the same greedy margin. Sites whose restriction splits across
    operating points — where branch-and-cut genuinely reorganizes —
    fall through to the ILP. Most sites take the fast path, which is
    what makes fleet-scale drain-priced re-plans cheap.

    ``shared``/``sub`` are plain array tuples (not objects) so site
    problems pickle cheaply into worker processes; results depend only
    on their contents, which keeps pooled and sequential solves
    bit-identical. Returns integer counts over all table rows.
    """
    x = _site_round_accept(shared, sub)
    return x if x is not None else _site_ilp(shared, sub)


def _site_round_accept(shared: tuple, sub: tuple) -> Optional[np.ndarray]:
    """The rounding fast path of ``_site_subproblem`` (numpy only)."""
    cls, tp, load_r, power_r, cost_rows, prices, time_limit = shared
    quota, gpus_s, power_s, old_g, lam, x0 = sub
    if x0 is None:
        return None
    m = len(cls)
    key = sct_key(np.zeros(m, dtype=np.intp), cls, tp)
    codes = np.unique(key, return_inverse=True)[1]
    cap_j = np.maximum(gpus_s // np.maximum(tp, 1), 0).astype(float)
    xs = np.minimum(np.asarray(x0, float), cap_j)
    # one (f,l) per (c,t): keep each group's largest-capacity row
    order = np.lexsort((np.arange(m), -xs * load_r, codes))
    first = np.ones(m, bool)
    first[1:] = codes[order][1:] != codes[order][:-1]
    keep = np.zeros(m, bool)
    keep[order[first]] = True
    xk = np.where(keep, np.floor(xs + 1e-9), 0.0)
    covered = np.bincount(cls, weights=xk * load_r, minlength=9)
    shortfall = np.maximum(quota, 0.0) - covered
    gran = np.zeros(9)                      # per-class one-instance load
    np.maximum.at(gran, cls, load_r)
    if (shortfall <= gran + 1e-9).all():
        return xk.astype(int)
    return None


def _site_ilp(shared: tuple, sub: tuple) -> np.ndarray:
    """The branch-and-cut body of ``_site_subproblem``."""
    cls, tp, load_r, power_r, cost_rows, prices, time_limit = shared
    quota, gpus_s, power_s, old_g, lam, x0 = sub
    m = len(cls)
    tpf = tp.astype(float)
    # (cls, tp) groups via the shared validated encoding (site fixed at 0)
    key = sct_key(np.zeros(m, dtype=np.intp), cls, tp)
    uniq, codes = np.unique(key, return_inverse=True)
    G = len(uniq)
    cap_j = np.maximum(gpus_s // np.maximum(tp, 1), 0).astype(float)
    drain = (old_g is not None and lam > 1e-12
             and float(np.sum(old_g)) > 1e-9)
    dgrp = np.nonzero(old_g > 1e-9)[0] if drain else np.empty(0, np.intp)
    Gd = len(dgrp)
    # variable layout: [X (m) | Y (m) | u (9) | d (Gd)]
    nv = 2 * m + 9 + Gd
    iX = np.arange(m)
    iY = m + np.arange(m)
    iU = 2 * m + np.arange(9)
    iD = 2 * m + 9 + np.arange(Gd)

    c_vec = np.zeros(nv)
    c_vec[iX] = cost_rows
    c_vec[iU] = prices
    if Gd:
        c_vec[iD] = lam
    b = ConstraintBuilder(nv)
    b.ub(np.zeros(m, np.intp), iX, tpf, [gpus_s])
    b.ub(np.zeros(m, np.intp), iX, power_r, [power_s])
    b.ub(codes, iY, np.ones(m), np.ones(G))
    b.ub(np.concatenate([np.arange(m), np.arange(m)]),
         np.concatenate([iX, iY]),
         np.concatenate([np.ones(m), -cap_j]), np.zeros(m))
    b.lb(np.concatenate([cls, np.arange(9)]),
         np.concatenate([iX, iU]),
         np.concatenate([load_r, np.ones(9)]), quota)
    if Gd:
        gmap = np.full(G, -1, dtype=np.intp)
        gmap[dgrp] = np.arange(Gd)
        loc = gmap[codes]
        msk = loc >= 0
        b.lb(np.concatenate([loc[msk], np.arange(Gd)]),
             np.concatenate([iX[msk], iD]),
             np.ones(int(msk.sum()) + Gd), old_g[dgrp])
    A_ub, b_ub, A_lb, b_lb = b.build()
    integrality = np.zeros(nv)
    integrality[iX] = 1
    integrality[iY] = 1
    upper = np.concatenate([cap_j, np.ones(m), np.maximum(quota, 0.0),
                            old_g[dgrp] if Gd else np.empty(0)])
    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    return np.round(res.x[iX]).astype(int)


def _solve_site_chunk(payload: tuple) -> list:
    shared, subs = payload
    return [_site_ilp(shared, sub) for sub in subs]


def _resolve_workers(workers: Optional[int], n_hard: int) -> int:
    if workers is not None:
        return max(1, int(workers))
    if n_hard < 24:                   # pool spin-up beats small ILP batches
        return 1
    return min(os.cpu_count() or 1, 8)


def _solve_sites(shared: tuple, subs: list, workers: Optional[int]) -> list:
    """Solve the independent site problems, pooling the hard ones.

    The rounding fast path runs inline for every site first (pure
    numpy, sub-millisecond); only the sites whose LP restriction did
    not round — the ones that pay a real branch-and-cut — go to the
    ``ProcessPoolExecutor``, in contiguous chunks reassembled in site
    order. Each solve depends only on its (shared, sub) arrays, so any
    worker count (including the sequential fallback) returns
    bit-identical plans — provided the site ILPs finish inside their
    per-site time limit (a branch-and-cut truncated mid-search is
    wall-clock dependent like any time-limited solve; the ILPs here are
    tiny and the budget is split deterministically over the hard batch,
    so limits bind only under extreme contention). The pool engages
    exactly when there is enough ILP work to amortise its spin-up.
    """
    out: list = [_site_round_accept(shared, sub) for sub in subs]
    hard = [i for i, x in enumerate(out) if x is None]
    # split the solve's time budget over the ILPs that actually run —
    # a deterministic bound (no wall-clock break mid-loop, which would
    # make pooled and sequential runs diverge under time pressure)
    sub_tl = max(0.05, min(2.0, shared[-1] / max(1, len(hard))))
    shared = shared[:-1] + (sub_tl,)
    w = _resolve_workers(workers, len(hard))
    if w <= 1 or len(hard) < 2:
        for i in hard:
            out[i] = _site_ilp(shared, subs[i])
        return out
    from concurrent.futures import ProcessPoolExecutor
    chunk = max(1, -(-len(hard) // (w * 4)))
    payloads = [(shared, [subs[i] for i in hard[k:k + chunk]])
                for k in range(0, len(hard), chunk)]
    with ProcessPoolExecutor(max_workers=w) as ex:
        solved = [x for xs in ex.map(_solve_site_chunk, payloads)
                  for x in xs]
    for i, x in zip(hard, solved):
        out[i] = x
    return out


def _drain_exchange(st: FleetState, load: np.ndarray, deadline: float,
                    max_moves: int = 400) -> None:
    """Re-choose *which* live groups spend the drain budget (in place).

    The projection restores drained capacity cheapest-first, which fixes
    feasibility but not the monolith's other degree of freedom: with the
    budget binding, the optimal plan drains the most *expensive* live
    surplus and keeps the cheap. Each move evicts one live instance
    whose class capacity is surplus (creating one drain) and restores
    one instance of the currently-cheapest drained group (retiring one
    drain) — net drains ≈ 0, cost strictly down; moves that would leave
    the budget violated or a class short are undone.
    """
    p = st.pool
    if st.old_group is None:
        return
    cheapest = st._group_best()
    blocked: set = set()                    # restore groups with no room
    for _ in range(max_moves):
        if time.perf_counter() > deadline:
            return
        gs = np.nonzero(st.drains > 1e-9)[0]
        gs = gs[[int(g) not in blocked for g in gs]]
        if len(gs) == 0:
            return
        js = np.where(st.group_row[gs] >= 0, st.group_row[gs], cheapest[gs])
        ok = js >= 0
        js, gr = js[ok], gs[ok]
        if len(js) == 0:
            return
        i = int(np.argmin(st.cost[js]))
        j_r, g_r = int(js[i]), int(gr[i])
        # evictable: live-old instances whose class stays covered
        ev = ((st.counts > 0)
              & (st.cap[p.cls] - p.load >= load[p.cls] - 1e-9)
              & (st.cost > st.cost[j_r] + 1e-9))
        cand = np.nonzero(ev)[0]
        g = st.codes[cand]                  # vectorized removal_drain(j, 1)
        dgain = (np.maximum(st.old_group[g] - (st.group_count[g] - 1), 0.0)
                 - st.drains[g])
        cand = cand[dgain > 1e-9]
        if len(cand) == 0:
            return
        j_e = int(cand[np.argmax(st.cost[cand])])
        st.remove(j_e, 1)
        room = (st.gpu_left[st.gpu_key[j_r]] >= p.tp[j_r]
                and st.pw_left[p.site[j_r]] >= p.power[j_r] - 1e-9)
        if room:
            st.add(j_r, 1)
        if not room or st.fleet_drains > st.r_limit + 1e-9:
            if room:
                st.remove(j_r, 1)
            st.add(j_e, 1)
            # this restore group cannot take the exchange — skip it and
            # keep trying the other drained groups
            blocked.add(g_r)


def _swap_improve(st: FleetState, load: np.ndarray, deadline: float,
                  max_rounds: int = 8) -> None:
    """Cross-site 1-swap polish (in place on ``st``).

    The per-site quota ILPs cannot mix load points inside one (s, c, t)
    group (constraint 4), so a site handed a 5-rps quota may round up to
    2x4-rps where the monolith would mix 4+1 across sites. Each round
    tries, per class, to evict one instance of the most expensive active
    column and re-cover the lost capacity with the fleet's cheapest
    columns; the swap commits only when it strictly lowers cost, and an
    eviction that would spend drain budget the fleet no longer has is
    skipped outright.
    """
    pool, counts, cost = st.pool, st.counts, st.cost
    for _ in range(max_rounds):
        improved = False
        for c in range(9):
            act = np.nonzero((pool.cls == c) & (counts > 0))[0]
            if len(act) == 0:
                continue
            j = int(act[np.argmax(cost[act])])
            if st.removal_drain(j, 1) > st.drain_headroom() + 1e-9:
                continue
            saved = cost[j]
            before = counts.copy()
            st.remove(j, 1)
            deficit = load[c] - st.cap[c]
            added = (st.cover(c, deficit, budget=saved - 1e-9)
                     if deficit > 1e-9 else 0.0)
            if added is not None and added < saved - 1e-9:
                improved = True
            else:
                counts[:] = before
                st.rebuild()
            if time.perf_counter() > deadline:
                return
        if not improved:
            return


def _solve_decomposed(pool: ColumnPool, sites: list[SiteSpec],
                      power_w: np.ndarray, load_per_class: np.ndarray,
                      objective: Objective, time_limit: float,
                      old: Optional[Plan] = None, r_frac: float = 0.03,
                      workers: Optional[int] = None,
                      site_warm: bool = True) -> Plan:
    t0 = time.perf_counter()
    S = len(sites)
    table = pool.table
    soa = table_soa(table)
    R = len(table.rows)
    gpus = np.array([s.num_gpus for s in sites], float)
    power = np.asarray(power_w, float)
    load = np.maximum(np.asarray(load_per_class, float), 0.0)
    cost = pool.cost(objective)
    row_cost = soa.e2e if objective == "latency" else soa.power

    if old is not None:
        old_agg = _live_old_agg(old, power, pool)
        r_limit = _drain_budget(old_agg, r_frac)
    else:
        old_agg, r_limit = None, np.inf
    prices, lam_r, x_lp = _lp_master(pool, gpus, power, load, cost,
                                     old_agg, r_limit)
    # per-site per-class capacity quotas from the fractional LP optimum
    quotas = np.zeros((S, 9))
    np.add.at(quotas, (pool.site, pool.cls), x_lp * pool.load)
    g_site = pool.sct()[1]
    counts = np.zeros(S * R, dtype=int)
    shared = (soa.cls, soa.tp, soa.load, soa.power, row_cost, prices,
              time_limit)
    subs, sub_sites = [], []
    for s in range(S):
        if quotas[s].max() <= 1e-9:
            continue        # the LP left the site idle (or power-dead)
        old_s = old_agg[g_site == s] if old_agg is not None else None
        x0 = x_lp[s * R:(s + 1) * R] if site_warm else None
        subs.append((quotas[s], gpus[s], power[s], old_s, lam_r, x0))
        sub_sites.append(s)
    for s, x in zip(sub_sites, _solve_sites(shared, subs, workers)):
        counts[s * R:(s + 1) * R] = x
    # Sites rationally *decline* quota priced exactly at the LP margin
    # (integer serving rounds up, declining does not), so the marginal
    # capacity of each class intentionally lands in the global repair
    # below — a ratio-greedy cover that is near-LP-optimal at the margin.
    # Do not re-price and re-solve on shortfall: forcing a declined
    # quota back onto its site makes a GPU-starved site serve at a worse
    # TP instead of exporting the load (observed as a 5% objective gap).

    fcounts = counts.astype(float)
    st = FleetState(fcounts, pool, cost, gpus, pool.site, power,
                    old_group=old_agg, r_limit=r_limit)
    st.trim(load)               # drain-aware surplus trim
    drains_ok = st.project_drains()
    #                             hard R_L feasibility across sites —
    #                             before the cover, so restorations claim
    #                             their headroom first and the repair
    #                             places serving capacity around them
    st.cover_all(load)          # greedy cheapest-completion repair
    _drain_exchange(st, load, deadline=t0 + time_limit)
    _swap_improve(st, load, deadline=t0 + time_limit)
    counts = np.round(fcounts).astype(int)
    cap = np.bincount(pool.cls, weights=counts * pool.load, minlength=9)
    unserved = np.maximum(load - cap, 0.0)
    unserved[unserved <= 1e-9] = 0.0
    # projection is best-effort in fractional power-scaling corners
    # (restoring integer instances cannot always reach a fractional
    # old-live total) — never fail silently when the budget is missed
    status = "decomposed"
    if not drains_ok:
        status = "decomposed_overbudget"
        warnings.warn(
            f"plan_l: drain projection left fleet drains "
            f"{st.fleet_drains:.1f} above R_L={st.r_limit:.1f} "
            "(no feasible restoration); plan returned with status "
            "'decomposed_overbudget'", RuntimeWarning, stacklevel=3)
    return Plan(columns=pool.columns(), counts=counts, unserved=unserved,
                objective=objective, status=status,
                solve_seconds=time.perf_counter() - t0, num_sites=S,
                _cols=pool.column_arrays(), _pool=pool)


def plan_l(table: LookupTable, sites: list[SiteSpec], power_w: np.ndarray,
           load_per_class: np.ndarray, *, objective: Objective = "latency",
           old: Optional[Plan] = None, r_frac: float = 0.03,
           time_limit: float = 60.0, method: Method = "auto",
           workers: Optional[int] = None, site_warm: bool = True) -> Plan:
    """Solve the Fig. 10 ILP for one 15-min slot.

    ``method`` selects the solve path (see module docstring): "auto"
    (the default) is the drain-priced Lagrangian decomposition at every
    fleet size — the full constraint set, R_L included, with per-site
    ILPs solved independently; "monolithic" is the exact single-solve
    reference. ``workers`` sizes the process pool for the hard site
    ILPs on the decomposed path (None = auto: sequential for small hard
    batches, else one worker per core up to 8); any value returns
    bit-identical plans. ``site_warm`` enables the rounding fast path
    off the master LP's site restriction (disable for an
    all-branch-and-cut A/B — the PR 2-style sequential loop).
    """
    S = len(sites)
    pool = ColumnPool.dense(table, S)
    if method in ("auto", "decomposed"):
        return _solve_decomposed(pool, sites, power_w, load_per_class,
                                 objective, time_limit, old=old,
                                 r_frac=r_frac, workers=workers,
                                 site_warm=site_warm)
    return _solve_monolithic(pool, sites, power_w, load_per_class, objective,
                             old, r_frac, time_limit)
