"""Planner-L — the 15-min lookahead ILP (paper Fig. 10).

Given per-site power/GPU budgets, predicted per-class peak load, and the
profiling lookup table, choose integer instance counts X_{c,f,t,s,l}
minimizing aggregate E2E latency (or power) subject to:

  (1) per-site GPU cap           (2) per-site power cap
  (3) per-class serving capacity (4,5) one (f,l) per (s,c,t) via binary Y
  (6,7) bounded reconfigurations vs the previous plan

Deviations from the literal Fig. 10 (documented in DESIGN.md):
  * Reconfiguration counting is at (s,c,t) granularity — *TP* changes,
    which is the stated intent ("Planner-L bounds TP reconfigurations") —
    and counts *drains* of live instances only: bring-up of fresh
    instances on idle GPUs is hidden by DynamoLLM-style background weight
    transfer (the paper adopts exactly this optimisation, K3), and
    capacity that already lost its power needs no drain. Without this,
    the diurnal load ramp itself would exhaust R_L — an artifact the
    paper's wording ("TP changes") clearly does not intend.
  * A per-class slack variable (heavily penalised) keeps the ILP feasible
    under extreme power droughts; slack == predicted request drops. The
    paper handles the same situation operationally ("min-latency converges
    to min-power in extreme resource-constrained cases").

Solve paths
-----------
The Fig. 10 ILP couples sites only through the per-class serving-capacity
constraint (3) and the fleet drain budget (6,7) — everything else ((1),
(2), (4), (5)) is block-diagonal per site. The monolithic HiGHS solve
exploits none of that structure and hits a wall around ~16 heterogeneous
sites (~10 s/slot); the paper's own premise (cross-farm complementarity)
and follow-up systems (XWind-style cross-site routing over dozens-to-
hundreds of micro-DCs) live exactly in the regime the monolith cannot
reach. ``plan_l`` therefore has two paths:

  * ``method="decomposed"`` (the default at every fleet size) —
    Lagrangian price decomposition on the coupling constraints. An LP
    relaxation of the aggregate problem — including the fleet drain
    budget — yields per-class capacity prices λ_c, a per-drain price
    λ_R (the budget row's dual), and fractional per-site capacity
    quotas. Each site then solves a small independent ILP covering its
    quota at minimum cost, with declined quota priced at the fleet
    marginal λ_c and drains of its live (s, c, t) groups priced at λ_R.
    Sites whose LP restriction rounds cleanly (residual shortfall
    within one-instance granularity) skip branch-and-cut outright —
    most do; the hard remainder are independent ILPs run in a
    ``ProcessPoolExecutor`` (``workers=``; contiguous chunks, results
    reassembled in site order — bit-identical to the sequential loop).
    A drain-aware surplus-trim, greedy cheapest-column repair, and a
    projection step that restores live capacity when the independent
    site solutions jointly overshoot R_L close the feasibility and
    integrality gaps; a drain-guarded cross-site 1-swap polish closes
    most of the rest. Fleet drains stay ≤ R_L on every slot
    (tests/test_planning.py) with objectives within ~1% of the monolith
    wherever the monolith can finish.
  * ``method="monolithic"`` — the original single HiGHS branch-and-cut
    over the full column pool, kept as the exact reference for parity
    tests and small-fleet A/B runs.

``method="auto"`` (the default) is an alias for ``decomposed``: the
two-regime site-count split is gone now that the decomposition enforces
the full Fig. 10 constraint set, R_L included.

Interactive-rate re-plans at 10k sites (``PlannerLSession``)
------------------------------------------------------------
Consecutive 15-min slots differ by a handful of forecast deltas, not a
new fleet — so the stateless cold solve is the wrong unit of work for
the steady state. ``PlannerLSession`` keeps per-slot state and layers
three reuse mechanisms on the decomposition:

  * **Restricted master with warm support** — the aggregate LP is
    solved over the previous slot's support columns plus a per-site
    deduplicated capacity seed (one column per (site, class); a site's
    class-c columns share its GPU/power headroom, so seeding more of
    them only bloats the LP). Negative-reduced-cost columns are priced
    in over at most ``max_rounds=2`` rounds of ``batch=8192`` — the
    large batch captures nearly all of the omitted rounds' columns in
    one cheaper resolve (objective within ~0.5% of full convergence).
    CSR constraint assembly is cached across slots; the support handed
    to the next slot is pruned back to LP-active ∪ integer-active
    columns so restricted LPs cannot compound across a week.
  * **Incremental dirty-site re-plans** — a site is *dirty* when its
    power or its load-weighted forecast moved by more than
    ``dirty_tol`` (relative); clean sites keep the previous slot's
    accepted quota ILP solutions verbatim while a compact sub-master
    (rows and columns restricted to the dirty sites, clean capacity
    folded into the class balances) re-prices only the dirty set —
    O(dirty) work per slot. The session falls back to a full warm
    re-plan when the dirty fraction exceeds ``max_dirty_frac`` (default
    0.5), when the fleet load vector itself moved, or on the first
    slot; ``plan.meta["fallback"]`` names the reason. With every site
    dirty the incremental path is bit-identical to the full warm path
    (tests/test_planner_session.py).
  * **λ_R subgradient refinement** — when the fleet drain constraint
    is tight, per-site drain sub-budgets are seeded from the master's
    fractional drains and λ_R is refined by a few subgradient steps so
    the independent site ILPs price drains near the true fleet
    marginal instead of over/under-draining and leaning on repair.

The session's non-cold modes also relax the cross-site 1-swap polish
with a relative-gain cutoff (``swap_rel_tol``, default 1e-3): polishing
stops once a full round's improvement falls under 0.1% of plan cost.
``mode="cold"`` keeps every knob at the stateless setting and is
bit-identical to ``plan_l`` — the session is an optimization layer,
not a different planner. Measured on synthetic fleets
(BENCH_planning.json): 10240-site drain-active full re-plan < 1 s;
incremental re-plans ≥ 5x faster than full at ≤ 10% dirty with
objective ratio ≥ 0.99.
"""
from __future__ import annotations

import os
import time
import warnings
from dataclasses import dataclass, field
from typing import Literal, Optional

import numpy as np

from repro.core.lookup import LookupTable, Row
from repro.core.milp import solve_milp
from repro.core.planning import (ColumnPool, ConstraintBuilder, FleetState,
                                 GpuBudget, sct_key, sct_unkey, table_soa)

DROP_PENALTY = 1e6          # per unserved rps — dominates any latency gain
# "cost"/"carbon" price power by a per-site rate signal (electricity
# price / grid-carbon factors) — see ColumnPool.cost(site_rate=...)
Objective = Literal["latency", "power", "cost", "carbon"]
Method = Literal["auto", "monolithic", "decomposed"]


@dataclass(frozen=True)
class SiteSpec:
    name: str
    num_gpus: int


@dataclass
class Plan:
    """Solved assignment for one slot.

    Derived views (``gpu_used``/``power_used``/``capacity``/``mean_e2e``)
    are vectorized over cached per-column arrays (``column_arrays``) —
    shared zero-copy with the ``ColumnPool`` the planner solved over when
    available, built lazily otherwise — so they stay O(columns) numpy
    bincounts even when called every simulated second. ``group_table``
    returns the cached columnar dispatch table consumed by the Request
    Scheduler's fast path; ``gpu_budget_pool`` the columnar GPU_{s,c,t}
    grant consumed by Planner-S.
    """
    columns: list[tuple[int, Row]]          # (site, row) per column
    counts: np.ndarray                      # instances per column (int)
    unserved: np.ndarray                    # [9] rps that cannot be served
    objective: Objective
    status: str
    solve_seconds: float
    num_sites: int
    _cols: Optional[tuple] = field(default=None, repr=False, compare=False)
    _gtable: object = field(default=None, repr=False, compare=False)
    _pool: object = field(default=None, repr=False, compare=False)
    _bpool: object = field(default=None, repr=False, compare=False)
    #: solver diagnostics (mode, dirty-set size, master/pricing rounds,
    #: per-stage seconds) — populated by ``PlannerLSession``; excluded
    #: from equality so metered plans compare equal to unmetered ones
    meta: Optional[dict] = field(default=None, repr=False, compare=False)

    def column_arrays(self) -> tuple:
        """(site, cls, tp, load, power, e2e) parallel arrays, cached."""
        if self._cols is None:
            if self._pool is not None:
                self._cols = self._pool.column_arrays()
            else:
                n = len(self.columns)
                site = np.empty(n, dtype=np.intp)
                cls_ = np.empty(n, dtype=np.intp)
                tp = np.empty(n, dtype=float)
                load = np.empty(n, dtype=float)
                power = np.empty(n, dtype=float)
                e2e = np.empty(n, dtype=float)
                for i, (s, r) in enumerate(self.columns):
                    site[i] = s
                    cls_[i] = r.cls
                    tp[i] = r.tp
                    load[i] = r.load
                    power[i] = r.power
                    e2e[i] = r.e2e
                self._cols = (site, cls_, tp, load, power, e2e)
        return self._cols

    def group_table(self):
        """Cached columnar view of the active groups (fast dispatch)."""
        if self._gtable is None:
            from repro.core.scheduler import GroupTable
            self._gtable = GroupTable.from_plan(self)
        return self._gtable

    # ---- derived views (vectorized) ----
    def gpu_used(self) -> np.ndarray:
        site, _, tp, _, _, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * tp,
                           minlength=self.num_sites)

    def power_used(self) -> np.ndarray:
        site, _, _, _, power, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * power,
                           minlength=self.num_sites)

    def capacity(self) -> np.ndarray:
        """[9] provisioned serving capacity in rps per class."""
        _, cls_, _, load, _, _ = self.column_arrays()
        return np.bincount(cls_, weights=self.counts * load, minlength=9)

    def mean_e2e(self, load_per_class: Optional[np.ndarray] = None) -> float:
        """Provisioned-capacity-weighted mean E2E latency.

        ``load_per_class`` is accepted for API compatibility but unused:
        the weighting is by provisioned rps (counts x row load), which is
        what the planner objective optimizes and what the comparisons in
        tests/benchmarks have always measured.
        """
        _, _, _, load, _, e2e = self.column_arrays()
        w = self.counts * load
        return float((w * e2e).sum()) / max(float(w.sum()), 1e-9)

    def total_power(self) -> float:
        return float(self.power_used().sum())

    def active(self) -> list[tuple[int, Row, int]]:
        return [(s, r, int(x)) for (s, r), x in zip(self.columns, self.counts)
                if x > 0]

    def gpu_budget_pool(self) -> GpuBudget:
        """GPU_{s,c,t} as a columnar pool — what Planner-S consumes.

        Cached like ``group_table``: the router re-reads it every
        simulated second between Planner-L solves.
        """
        if self._bpool is None:
            self._bpool = GpuBudget.from_plan(self)
        return self._bpool

    def gpu_budget(self) -> dict[tuple[int, int, int], int]:
        """GPU_{s,c,t} as a legacy dict (see ``gpu_budget_pool``)."""
        return self.gpu_budget_pool().as_dict()

    def wrr_weights(self) -> dict[int, list[tuple[int, Row, float]]]:
        """Per class: [(site, row, weight)] with weight ∝ provisioned rps."""
        cap = self.capacity()
        _, cls_, _, load, _, _ = self.column_arrays()
        counts = np.asarray(self.counts)
        active = np.nonzero((counts > 0) & (cap[cls_] > 0))[0]
        w = counts[active] * load[active] / cap[cls_[active]]
        out: dict[int, list[tuple[int, Row, float]]] = {c: [] for c in range(9)}
        for j, wj in zip(active.tolist(), w.tolist()):
            s, r = self.columns[j]
            out[r.cls].append((s, r, wj))
        return out

    def agg_by_sct(self) -> dict[tuple[int, int, int], int]:
        """Instance counts per (s, c, t) group — vectorized aggregation."""
        site, cls_, tp, _, _, _ = self.column_arrays()
        counts = np.asarray(self.counts)
        active = counts > 0
        if not active.any():
            return {}
        uniq, inv = np.unique(sct_key(site[active], cls_[active],
                                      tp[active].astype(np.intp)),
                              return_inverse=True)
        agg = np.bincount(inv, weights=counts[active]).astype(int)
        g_site, g_cls, g_tp = sct_unkey(uniq)
        return {(int(s), int(c), int(t)): int(a)
                for s, c, t, a in zip(g_site, g_cls, g_tp, agg)}


def build_columns(table: LookupTable, num_sites: int):
    """Legacy helper: the dense (site, Row) enumeration as a list."""
    return ColumnPool.dense(table, num_sites).columns()


# ------------------------------------------------------------------
# monolithic path (Fig. 10 verbatim)
# ------------------------------------------------------------------
def _solve_monolithic(pool: ColumnPool, sites: list[SiteSpec],
                      power_w: np.ndarray, load_per_class: np.ndarray,
                      objective: Objective, old: Optional[Plan],
                      r_frac: float, time_limit: float,
                      site_rate: Optional[np.ndarray] = None) -> Plan:
    S = len(sites)
    n = len(pool)
    col_cost = pool.cost(objective, site_rate)
    codes, g_site, g_cls, g_tp = pool.sct()
    G = len(g_site)

    use_reconfig = old is not None
    # variable layout: [X (n) | Y (n) | slack (9) | R (G)]
    nv = n + n + 9 + (G if use_reconfig else 0)
    iX = np.arange(n)
    iY = n + np.arange(n)
    iSl = 2 * n + np.arange(9)
    iR = 2 * n + 9 + np.arange(G) if use_reconfig else None

    c_vec = np.zeros(nv)
    c_vec[iX] = col_cost
    c_vec[iSl] = DROP_PENALTY

    gpus = np.array([s.num_gpus for s in sites], float)
    N_total = float(gpus.sum())
    b = ConstraintBuilder(nv)
    # (1) per-site GPU cap ; (2) per-site power cap (interleaved rows)
    rhs12 = np.empty(2 * S)
    rhs12[0::2] = gpus
    rhs12[1::2] = np.asarray(power_w, float)
    b.ub(np.concatenate([2 * pool.site, 2 * pool.site + 1]),
         np.concatenate([iX, iX]),
         np.concatenate([pool.tp.astype(float), pool.power]), rhs12)
    # (4) one (f,l) per (s,c,t):  sum_{f,l} Y <= 1
    b.ub(codes, iY, np.ones(n), np.ones(G))
    # (5) X <= N_total * Y
    b.ub(np.concatenate([np.arange(n), np.arange(n)]),
         np.concatenate([iX, iY]),
         np.concatenate([np.ones(n), np.full(n, -N_total)]), np.zeros(n))
    # (6,7) reconfiguration bound: drains of *live* previous capacity only.
    # Old capacity at a site is first scaled by how much of the old plan's
    # power draw the new slot's power still supports — capacity whose power
    # died needs no drain (the instances are dark regardless).
    if use_reconfig:
        old_agg = _live_old_agg(old, power_w, pool)
        r_limit = _drain_budget(old_agg, r_frac)
        # drain count: R >= old_live - sum X   (growth is free)
        b.ub(np.concatenate([codes, np.arange(G)]),
             np.concatenate([iX, iR]),
             np.concatenate([-np.ones(n), -np.ones(G)]), -old_agg)
        b.ub(np.zeros(G, dtype=np.intp), iR, np.ones(G), [r_limit])
    # (3) capacity: sum X*load + slack_c >= Load_c
    b.lb(np.concatenate([pool.cls, np.arange(9)]),
         np.concatenate([iX, iSl]),
         np.concatenate([pool.load, np.ones(9)]),
         np.asarray(load_per_class, float))
    A_ub, b_ub, A_lb, b_lb = b.build()

    integrality = np.zeros(nv)
    integrality[iX] = 1
    integrality[iY] = 1
    upper = np.full(nv, np.inf)
    upper[iX] = (gpus[pool.site].astype(int)
                 // np.maximum(pool.tp, 1)).astype(float)
    upper[iY] = 1.0
    upper[iSl] = np.maximum(load_per_class, 0.0)

    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    x = res.x
    return Plan(columns=pool.columns(), counts=np.round(x[iX]).astype(int),
                unserved=np.maximum(x[iSl], 0.0), objective=objective,
                status=res.status, solve_seconds=res.solve_seconds,
                num_sites=S, _cols=pool.column_arrays(), _pool=pool)


def _drain_budget(old_agg: np.ndarray, r_frac: float) -> float:
    """R_L in instances: r_frac of the (power-scaled) live fleet, ≥ 1."""
    return max(1.0, r_frac * max(1.0, float(old_agg.sum())))


def _live_scale(old: Plan, power_w: np.ndarray) -> np.ndarray:
    """Per-site survival fraction of the old plan's power draw."""
    old_power = old.power_used()
    scale = np.ones(old.num_sites)
    pos = old_power > 0
    scale[pos] = np.minimum(
        1.0, np.asarray(power_w, float)[:old.num_sites][pos] / old_power[pos])
    return scale


def fleet_drains(old: Plan, new: Plan, power_w: np.ndarray) -> float:
    """Σ_g max(0, live_old_g − new_g) — the drain total R_L bounds.

    Counts drains of *live* previous capacity at (s, c, t) granularity,
    with old capacity power-scaled exactly as the planners scale it
    (capacity whose power died needs no drain). Public so tests and
    benchmarks can audit any plan pair against the budget.
    """
    pool = getattr(new, "_pool", None)
    if pool is not None and len(pool):
        old_agg = _live_old_agg(old, np.asarray(power_w, float), pool)
        new_g = np.bincount(pool.sct()[0],
                            weights=np.asarray(new.counts, float),
                            minlength=len(old_agg))
        return float(np.maximum(old_agg - new_g, 0.0).sum())
    scale = _live_scale(old, power_w)
    new_agg = new.agg_by_sct()
    return float(sum(max(0.0, v * scale[k[0]] - new_agg.get(k, 0))
                     for k, v in old.agg_by_sct().items()))


def drain_limit(old: Plan, power_w: np.ndarray, r_frac: float) -> float:
    """The R_L budget the planner enforces for this (old, power) slot."""
    scale = _live_scale(old, power_w)
    site = old.column_arrays()[0]
    total = float((np.asarray(old.counts, float) * scale[site]).sum())
    return max(1.0, r_frac * max(1.0, total))


def _live_old_agg(old: Plan, power_w: np.ndarray,
                  pool: ColumnPool) -> np.ndarray:
    """Old live instance counts per current (s,c,t) group, power-scaled."""
    codes, g_site, g_cls, g_tp = pool.sct()
    scale = _live_scale(old, power_w)
    if getattr(old, "_pool", None) is pool and len(pool):
        # same pool (chained session re-plans): each old column's group
        # is its own pool code — same weights, same accumulation order
        # as the searchsorted path below, so bit-identical
        return np.bincount(codes, weights=np.asarray(old.counts, float)
                           * scale[pool.site], minlength=len(g_site))
    g_key = sct_key(g_site, g_cls, g_tp)
    old_site, old_cls, old_tp, _, _, _ = old.column_arrays()
    old_key = sct_key(old_site, old_cls, old_tp.astype(np.intp))
    pos_idx = np.searchsorted(g_key, old_key)
    pos_idx = np.clip(pos_idx, 0, len(g_key) - 1)
    match = g_key[pos_idx] == old_key
    agg = np.zeros(len(g_key))
    np.add.at(agg, pos_idx[match],
              (np.asarray(old.counts, float) * scale[old_site])[match])
    return agg


# ------------------------------------------------------------------
# decomposed path (Lagrangian prices + per-site ILPs)
# ------------------------------------------------------------------
def _lp_master(pool: ColumnPool, gpus: np.ndarray, power_w: np.ndarray,
               load: np.ndarray, cost: np.ndarray,
               old_agg: Optional[np.ndarray] = None,
               r_limit: float = np.inf
               ) -> tuple[np.ndarray, float, np.ndarray]:
    """LP relaxation of the aggregate problem: prices + quotas.

    The LP drops integrality and the one-(f,l) constraint — it is the
    natural Lagrangian master: its capacity duals price one rps of each
    class at the margin, its (fractional) solution says how much
    capacity of each class each site should provision, and — when an
    old plan is present — the dual of its fleet drain-budget row prices
    one drained live instance at the margin (λ_R). Returns
    (prices [9], λ_R, x_lp [n]).
    """
    from scipy.optimize import linprog

    n = len(pool)
    if old_agg is not None:
        codes = pool.sct()[0]
        G = len(pool.sct()[1])
        dgrp = np.nonzero(old_agg > 1e-9)[0]
    else:
        dgrp = np.empty(0, dtype=np.intp)
    Gd = len(dgrp)
    nv = n + 9 + Gd
    c_vec = np.concatenate([cost, np.full(9, DROP_PENALTY), np.zeros(Gd)])
    b = ConstraintBuilder(nv)
    b.ub(pool.site, np.arange(n), pool.tp.astype(float), gpus)
    b.ub(pool.site, np.arange(n), pool.power, np.asarray(power_w, float))
    # capacity as <=:  -(sum load x + slack) <= -Load_c
    b.ub(np.concatenate([pool.cls, np.arange(9)]),
         np.concatenate([np.arange(n), n + np.arange(9)]),
         np.concatenate([-pool.load, -np.ones(9)]),
         -np.asarray(load, float))
    if Gd:
        # drain link per live group:  -Σ_{j∈g} x_j - d_g <= -old_g
        gmap = np.full(G, -1, dtype=np.intp)
        gmap[dgrp] = np.arange(Gd)
        loc = gmap[codes]
        msk = loc >= 0
        b.ub(np.concatenate([loc[msk], np.arange(Gd)]),
             np.concatenate([np.arange(n)[msk], n + 9 + np.arange(Gd)]),
             np.concatenate([-np.ones(int(msk.sum())), -np.ones(Gd)]),
             -old_agg[dgrp])
        # fleet drain budget:  Σ_g d_g <= R_L   (dual → λ_R)
        b.ub(np.zeros(Gd, dtype=np.intp), n + 9 + np.arange(Gd),
             np.ones(Gd), [float(r_limit)])
    A_ub, b_ub, _, _ = b.build()
    S = len(gpus)
    res = linprog(c_vec, A_ub=A_ub, b_ub=b_ub, method="highs")
    if not res.success:
        return np.zeros(9), 0.0, np.zeros(n)
    marg = res.ineqlin.marginals
    prices = np.maximum(-marg[2 * S: 2 * S + 9], 0.0)
    lam_r = float(max(-marg[-1], 0.0)) if Gd else 0.0
    return prices, lam_r, np.maximum(res.x[:n], 0.0)


def _site_subproblem(shared: tuple, sub: tuple) -> np.ndarray:
    """Per-site ILP: meet the site's LP capacity quota at minimum cost.

    min Σ cost_j x_j + Σ_c λ_c u_c + λ_R Σ_g d_g
    s.t. GPU cap, power cap, one (f,l) per (c,t),
         Σ_j load_j x_j + u_c >= quota_c,
         Σ_{j∈g} x_j + d_g >= old_g          (live groups only).

    Unserved quota ``u_c`` is priced at the fleet marginal λ_c — the
    site covers its share only where local serving beats buying the
    capacity back at the fleet margin; what it declines flows to the
    global repair step. Drains ``d_g`` of the site's live previous
    capacity are priced at the fleet drain marginal λ_R, so a site only
    walks away from running instances when the re-placement win beats
    the fleet's going drain price; the hard R_L cap itself is restored
    globally by ``FleetState.project_drains``.

    When ``x0`` (the master LP's restriction to this site) is given,
    the solve is warm-started by rounding: the restriction is projected
    onto one (f, l) per group and floored — always feasible (caps only
    shrink, declined quota is priced slack) — and *accepted outright*
    when every class's residual shortfall sits within one-instance
    rounding granularity, because that residue is exactly what the
    integer program could not serve either (it would round up where the
    fleet margin says decline) and the global repair re-covers it at
    the same greedy margin. Sites whose restriction splits across
    operating points — where branch-and-cut genuinely reorganizes —
    fall through to the ILP. Most sites take the fast path, which is
    what makes fleet-scale drain-priced re-plans cheap.

    ``shared``/``sub`` are plain array tuples (not objects) so site
    problems pickle cheaply into worker processes; results depend only
    on their contents, which keeps pooled and sequential solves
    bit-identical. Returns integer counts over all table rows.
    """
    x = _site_round_accept(shared, sub)
    return x if x is not None else _site_ilp(shared, sub)


def _site_round_accept(shared: tuple, sub: tuple) -> Optional[np.ndarray]:
    """The rounding fast path of ``_site_subproblem`` (numpy only)."""
    cls, tp, load_r, power_r, cost_rows, prices, time_limit = shared
    quota, gpus_s, power_s, old_g, lam, x0 = sub
    if x0 is None:
        return None
    m = len(cls)
    key = sct_key(np.zeros(m, dtype=np.intp), cls, tp)
    codes = np.unique(key, return_inverse=True)[1]
    cap_j = np.maximum(gpus_s // np.maximum(tp, 1), 0).astype(float)
    xs = np.minimum(np.asarray(x0, float), cap_j)
    # one (f,l) per (c,t): keep each group's largest-capacity row
    order = np.lexsort((np.arange(m), -xs * load_r, codes))
    first = np.ones(m, bool)
    first[1:] = codes[order][1:] != codes[order][:-1]
    keep = np.zeros(m, bool)
    keep[order[first]] = True
    xk = np.where(keep, np.floor(xs + 1e-9), 0.0)
    covered = np.bincount(cls, weights=xk * load_r, minlength=9)
    shortfall = np.maximum(quota, 0.0) - covered
    gran = np.zeros(9)                      # per-class one-instance load
    np.maximum.at(gran, cls, load_r)
    if (shortfall <= gran + 1e-9).all():
        return xk.astype(int)
    return None


def _site_ilp(shared: tuple, sub: tuple) -> np.ndarray:
    """The branch-and-cut body of ``_site_subproblem``."""
    cls, tp, load_r, power_r, cost_rows, prices, time_limit = shared
    quota, gpus_s, power_s, old_g, lam, x0 = sub
    m = len(cls)
    tpf = tp.astype(float)
    # (cls, tp) groups via the shared validated encoding (site fixed at 0)
    key = sct_key(np.zeros(m, dtype=np.intp), cls, tp)
    uniq, codes = np.unique(key, return_inverse=True)
    G = len(uniq)
    cap_j = np.maximum(gpus_s // np.maximum(tp, 1), 0).astype(float)
    drain = (old_g is not None and lam > 1e-12
             and float(np.sum(old_g)) > 1e-9)
    dgrp = np.nonzero(old_g > 1e-9)[0] if drain else np.empty(0, np.intp)
    Gd = len(dgrp)
    # variable layout: [X (m) | Y (m) | u (9) | d (Gd)]
    nv = 2 * m + 9 + Gd
    iX = np.arange(m)
    iY = m + np.arange(m)
    iU = 2 * m + np.arange(9)
    iD = 2 * m + 9 + np.arange(Gd)

    c_vec = np.zeros(nv)
    c_vec[iX] = cost_rows
    c_vec[iU] = prices
    if Gd:
        c_vec[iD] = lam
    b = ConstraintBuilder(nv)
    b.ub(np.zeros(m, np.intp), iX, tpf, [gpus_s])
    b.ub(np.zeros(m, np.intp), iX, power_r, [power_s])
    b.ub(codes, iY, np.ones(m), np.ones(G))
    b.ub(np.concatenate([np.arange(m), np.arange(m)]),
         np.concatenate([iX, iY]),
         np.concatenate([np.ones(m), -cap_j]), np.zeros(m))
    b.lb(np.concatenate([cls, np.arange(9)]),
         np.concatenate([iX, iU]),
         np.concatenate([load_r, np.ones(9)]), quota)
    if Gd:
        gmap = np.full(G, -1, dtype=np.intp)
        gmap[dgrp] = np.arange(Gd)
        loc = gmap[codes]
        msk = loc >= 0
        b.lb(np.concatenate([loc[msk], np.arange(Gd)]),
             np.concatenate([iX[msk], iD]),
             np.ones(int(msk.sum()) + Gd), old_g[dgrp])
    A_ub, b_ub, A_lb, b_lb = b.build()
    integrality = np.zeros(nv)
    integrality[iX] = 1
    integrality[iY] = 1
    upper = np.concatenate([cap_j, np.ones(m), np.maximum(quota, 0.0),
                            old_g[dgrp] if Gd else np.empty(0)])
    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    return np.round(res.x[iX]).astype(int)


def _solve_site_chunk(payload: tuple) -> list:
    shared, subs = payload
    return [_site_ilp(shared, sub) for sub in subs]


def _resolve_workers(workers: Optional[int], n_hard: int) -> int:
    if workers is not None:
        return max(1, int(workers))
    if n_hard < 24:                   # pool spin-up beats small ILP batches
        return 1
    return min(os.cpu_count() or 1, 8)


def _solve_sites(shared: tuple, subs: list, workers: Optional[int]) -> list:
    """Solve the independent site problems, pooling the hard ones.

    The rounding fast path runs inline for every site first (pure
    numpy, sub-millisecond); only the sites whose LP restriction did
    not round — the ones that pay a real branch-and-cut — go to the
    ``ProcessPoolExecutor``, in contiguous chunks reassembled in site
    order. Each solve depends only on its (shared, sub) arrays, so any
    worker count (including the sequential fallback) returns
    bit-identical plans — provided the site ILPs finish inside their
    per-site time limit (a branch-and-cut truncated mid-search is
    wall-clock dependent like any time-limited solve; the ILPs here are
    tiny and the budget is split deterministically over the hard batch,
    so limits bind only under extreme contention). The pool engages
    exactly when there is enough ILP work to amortise its spin-up.
    """
    out: list = [_site_round_accept(shared, sub) for sub in subs]
    hard = [i for i, x in enumerate(out) if x is None]
    # split the solve's time budget over the ILPs that actually run —
    # a deterministic bound (no wall-clock break mid-loop, which would
    # make pooled and sequential runs diverge under time pressure)
    sub_tl = max(0.05, min(2.0, shared[-1] / max(1, len(hard))))
    shared = shared[:-1] + (sub_tl,)
    w = _resolve_workers(workers, len(hard))
    if w <= 1 or len(hard) < 2:
        for i in hard:
            out[i] = _site_ilp(shared, subs[i])
        return out
    from concurrent.futures import ProcessPoolExecutor
    chunk = max(1, -(-len(hard) // (w * 4)))
    payloads = [(shared, [subs[i] for i in hard[k:k + chunk]])
                for k in range(0, len(hard), chunk)]
    with ProcessPoolExecutor(max_workers=w) as ex:
        solved = [x for xs in ex.map(_solve_site_chunk, payloads)
                  for x in xs]
    for i, x in zip(hard, solved):
        out[i] = x
    return out


def _drain_exchange(st: FleetState, load: np.ndarray, deadline: float,
                    max_moves: int = 400) -> None:
    """Re-choose *which* live groups spend the drain budget (in place).

    The projection restores drained capacity cheapest-first, which fixes
    feasibility but not the monolith's other degree of freedom: with the
    budget binding, the optimal plan drains the most *expensive* live
    surplus and keeps the cheap. Each move evicts one live instance
    whose class capacity is surplus (creating one drain) and restores
    one instance of the currently-cheapest drained group (retiring one
    drain) — net drains ≈ 0, cost strictly down; moves that would leave
    the budget violated or a class short are undone.
    """
    p = st.pool
    if st.old_group is None:
        return
    cheapest = st._group_best()
    blocked: set = set()                    # restore groups with no room
    for _ in range(max_moves):
        if time.perf_counter() > deadline:
            return
        gs = np.nonzero(st.drains > 1e-9)[0]
        gs = gs[[int(g) not in blocked for g in gs]]
        if len(gs) == 0:
            return
        js = np.where(st.group_row[gs] >= 0, st.group_row[gs], cheapest[gs])
        ok = js >= 0
        js, gr = js[ok], gs[ok]
        if len(js) == 0:
            return
        i = int(np.argmin(st.cost[js]))
        j_r, g_r = int(js[i]), int(gr[i])
        # evictable: live-old instances whose class stays covered —
        # one O(columns) nonzero for the active set, every other mask
        # over that (small) subset; same candidates in the same order
        act = np.nonzero(st.counts > 0)[0]
        ev = ((st.cap[p.cls[act]] - p.load[act] >= load[p.cls[act]] - 1e-9)
              & (st.cost[act] > st.cost[j_r] + 1e-9))
        cand = act[ev]
        g = st.codes[cand]                  # vectorized removal_drain(j, 1)
        dgain = (np.maximum(st.old_group[g] - (st.group_count[g] - 1), 0.0)
                 - st.drains[g])
        cand = cand[dgain > 1e-9]
        if len(cand) == 0:
            return
        j_e = int(cand[np.argmax(st.cost[cand])])
        st.remove(j_e, 1)
        room = (st.gpu_left[st.gpu_key[j_r]] >= p.tp[j_r]
                and st.pw_left[p.site[j_r]] >= p.power[j_r] - 1e-9)
        if room:
            st.add(j_r, 1)
        if not room or st.fleet_drains > st.r_limit + 1e-9:
            if room:
                st.remove(j_r, 1)
            st.add(j_e, 1)
            # this restore group cannot take the exchange — skip it and
            # keep trying the other drained groups
            blocked.add(g_r)


def _swap_improve(st: FleetState, load: np.ndarray, deadline: float,
                  max_rounds: int = 8, exact: bool = True,
                  rel_tol: float = 0.0) -> None:
    """Cross-site 1-swap polish (in place on ``st``).

    The per-site quota ILPs cannot mix load points inside one (s, c, t)
    group (constraint 4), so a site handed a 5-rps quota may round up to
    2x4-rps where the monolith would mix 4+1 across sites. Each round
    tries, per class, to evict one instance of the most expensive active
    column and re-cover the lost capacity with the fleet's cheapest
    columns; the swap commits only when it strictly lowers cost, and an
    eviction that would spend drain budget the fleet no longer has is
    skipped outright.

    ``exact=True`` rolls a rejected swap back through the historical
    ``counts.copy()`` + ``rebuild()`` pair (canonical bincount state —
    the byte-for-byte ``plan_l`` behavior the anchors pin).
    ``exact=False`` rolls back through the O(ops) op log instead: same
    decisions, ULP-level float-headroom drift possible, an order of
    magnitude cheaper at 10k sites — the session re-plan path uses it.

    ``rel_tol > 0`` stops polishing once a whole round's cost saving
    falls below ``rel_tol`` of the current plan cost — at 10k sites the
    late rounds each cost a fleet-wide ``cover`` scan per class to
    recover a vanishing fraction of the objective. The canonical
    ``plan_l`` path keeps ``rel_tol=0`` (run until no strict improvement).
    """
    pool, counts, cost = st.pool, st.counts, st.cost
    for _ in range(max_rounds):
        improved = False
        round_gain = 0.0
        for c in range(9):
            idx_c = pool.cls_index(c)
            act = idx_c[counts[idx_c] > 0]
            if len(act) == 0:
                continue
            j = int(act[np.argmax(cost[act])])
            if st.removal_drain(j, 1) > st.drain_headroom() + 1e-9:
                continue
            saved = cost[j]
            before = counts.copy() if exact else None
            if not exact:
                st.log_begin()
            st.remove(j, 1)
            deficit = load[c] - st.cap[c]
            added = (st.cover(c, deficit, budget=saved - 1e-9)
                     if deficit > 1e-9 else 0.0)
            if added is not None and added < saved - 1e-9:
                improved = True
                round_gain += saved - added
                if not exact:
                    st.log_commit()
            elif exact:
                counts[:] = before
                st.rebuild()
            else:
                st.log_rollback()
            if time.perf_counter() > deadline:
                return
        if not improved:
            return
        if rel_tol > 0.0 and round_gain < rel_tol * float(counts @ cost):
            return


def _site_group_starts(pool: ColumnPool) -> np.ndarray:
    """[S+1] start offsets of each site's (s,c,t) group range.

    ``pool.sct()`` orders groups by site-major key, so ``g_site`` is
    nondecreasing and ``old_agg[starts[s]:starts[s+1]]`` is the exact
    slice the historical ``old_agg[g_site == s]`` boolean scan produced
    — without the O(S·G) fleet-wide mask per site.
    """
    g_site = pool.sct()[1]
    return np.searchsorted(g_site, np.arange(pool.num_sites + 1))


def _round_accept_all(soa, quotas: np.ndarray, gpus: np.ndarray,
                      x_lp: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """``_site_round_accept`` for every site at once (pure numpy).

    Same arithmetic in the same order as the per-site helper — floor /
    min / per-(site, group) keep-largest with first-row tie-break
    (segment max + min-position over a static (group, row) permutation
    of the table), and per-site class coverage summed row-ascending via
    one flat bincount — so a site accepts here iff its
    ``_site_round_accept`` accepts, with bit-identical counts
    (pinned by tests/test_planning.py). ``quotas``/``gpus``/``x_lp``
    are the *solved subset's* rows (the caller pre-gathers them), so an
    incremental re-plan pays O(dirty·R), not O(fleet). Returns
    (xk [S, R], accepted [S]); rows of non-accepted sites are
    meaningless.
    """
    S = quotas.shape[0]
    R = len(soa.cls)
    key = sct_key(np.zeros(R, dtype=np.intp), soa.cls, soa.tp)
    codes = np.unique(key, return_inverse=True)[1]
    perm = np.lexsort((np.arange(R), codes))        # group-major, row asc
    starts = np.nonzero(np.r_[True, codes[perm][1:] != codes[perm][:-1]])[0]
    cap_j = np.maximum(gpus[:, None] // np.maximum(soa.tp, 1)[None, :],
                       0).astype(float)
    xs = np.minimum(x_lp.reshape(S, R), cap_j)
    w = xs * soa.load[None, :]
    wp = w[:, perm]
    gmax = np.maximum.reduceat(wp, starts, axis=1)
    reps = np.diff(np.r_[starts, R])
    pos = np.where(wp == np.repeat(gmax, reps, axis=1),
                   np.arange(R)[None, :], R)
    first = np.minimum.reduceat(pos, starts, axis=1)
    keep = np.zeros((S, R), dtype=bool)
    keep[np.arange(S)[:, None], perm[first]] = True
    xk = np.where(keep, np.floor(xs + 1e-9), 0.0)
    flat = np.repeat(np.arange(S), R) * 9 + np.tile(soa.cls, S)
    covered = np.bincount(flat, weights=(xk * soa.load[None, :]).ravel(),
                          minlength=S * 9).reshape(S, 9)
    gran = np.zeros(9)                      # per-class one-instance load
    np.maximum.at(gran, soa.cls, soa.load)
    shortfall = np.maximum(quotas, 0.0) - covered
    accepted = (shortfall <= gran[None, :] + 1e-9).all(axis=1)
    return xk, accepted


def _assign_sites(pool: ColumnPool, soa, quotas: np.ndarray,
                  gpus: np.ndarray, power: np.ndarray,
                  old_agg: Optional[np.ndarray], starts: np.ndarray,
                  lam_r: float, x_lp: Optional[np.ndarray],
                  row_cost: np.ndarray, prices: np.ndarray,
                  time_limit: float, workers: Optional[int],
                  site_warm: bool,
                  site_mask: Optional[np.ndarray] = None
                  ) -> tuple[np.ndarray, int, int]:
    """Solve the per-site quota problems; returns (counts2d, #accept, #ilp).

    Sites outside ``site_mask`` (and sites the LP left idle) come back
    as zero rows — the caller decides what to reuse for them. The
    vectorized rounding pass accepts most sites without touching
    Python; the remainder go through ``_solve_sites`` exactly as
    before (the hard count, and so the deterministic per-ILP time
    split, is unchanged — a site fails the vectorized accept iff it
    fails the per-site accept).
    """
    S = quotas.shape[0]
    R = len(soa.cls)
    active = quotas.max(axis=1) > 1e-9
    if site_mask is not None:
        active &= site_mask
    counts2d = np.zeros((S, R), dtype=int)
    acc = np.zeros(S, dtype=bool)
    if site_warm and x_lp is not None and active.any():
        # gather the active rows first: each site's accept arithmetic is
        # row-local, so the subset pass is bit-identical to the full one
        # and an incremental re-plan pays O(dirty·R) here, not O(S·R)
        act = np.nonzero(active)[0]
        xk, ok = _round_accept_all(soa, quotas[act], gpus[act],
                                   x_lp.reshape(S, R)[act].ravel())
        hit = act[ok]
        acc[hit] = True
        counts2d[hit] = xk[ok].astype(int)
    hard_sites = np.nonzero(active & ~acc)[0].tolist()
    shared = (soa.cls, soa.tp, soa.load, soa.power, row_cost, prices,
              time_limit)
    subs = []
    for s in hard_sites:
        old_s = (old_agg[starts[s]:starts[s + 1]]
                 if old_agg is not None else None)
        x0 = x_lp[s * R:(s + 1) * R] if (site_warm and x_lp is not None) \
            else None
        subs.append((quotas[s], gpus[s], power[s], old_s, lam_r, x0))
    for s, x in zip(hard_sites, _solve_sites(shared, subs, workers)):
        counts2d[s] = x
    return counts2d, int(acc.sum()), len(hard_sites)


def _global_repair(fcounts: np.ndarray, pool: ColumnPool, cost: np.ndarray,
                   gpus: np.ndarray, power: np.ndarray, load: np.ndarray,
                   old_agg: Optional[np.ndarray], r_limit: float,
                   deadline: float, exact: bool = True,
                   restore_best: Optional[np.ndarray] = None,
                   swap_rel_tol: float = 0.0
                   ) -> tuple[FleetState, bool]:
    """Fleet-level feasibility + polish over assembled site counts."""
    st = FleetState(fcounts, pool, cost, gpus, pool.site, power,
                    old_group=old_agg, r_limit=r_limit,
                    restore_best=restore_best)
    st.trim(load)               # drain-aware surplus trim
    drains_ok = st.project_drains()
    #                             hard R_L feasibility across sites —
    #                             before the cover, so restorations claim
    #                             their headroom first and the repair
    #                             places serving capacity around them
    st.cover_all(load)          # greedy cheapest-completion repair
    _drain_exchange(st, load, deadline=deadline)
    _swap_improve(st, load, deadline=deadline, exact=exact,
                  rel_tol=swap_rel_tol)
    return st, drains_ok


def _quotas_from_lp(pool: ColumnPool, x_lp: np.ndarray,
                    S: int) -> np.ndarray:
    """Per-site per-class capacity quotas from the fractional optimum.

    Flat bincount with site-major bins — accumulates in column order,
    bit-identical to the historical ``np.add.at`` scatter.
    """
    return np.bincount(pool.site * 9 + pool.cls,
                       weights=x_lp * pool.load,
                       minlength=S * 9).reshape(S, 9)


def _solve_decomposed(pool: ColumnPool, sites: list[SiteSpec],
                      power_w: np.ndarray, load_per_class: np.ndarray,
                      objective: Objective, time_limit: float,
                      old: Optional[Plan] = None, r_frac: float = 0.03,
                      workers: Optional[int] = None,
                      site_warm: bool = True,
                      site_rate: Optional[np.ndarray] = None) -> Plan:
    t0 = time.perf_counter()
    S = len(sites)
    table = pool.table
    soa = table_soa(table)
    gpus = np.array([s.num_gpus for s in sites], float)
    power = np.asarray(power_w, float)
    load = np.maximum(np.asarray(load_per_class, float), 0.0)
    cost = pool.cost(objective, site_rate)
    # the site subproblem's shared row costs are per table row (shared
    # across sites) — site-rate scaling binds through the master duals
    # and the repair's column costs, not here
    row_cost = soa.e2e if objective == "latency" else soa.power

    if old is not None:
        old_agg = _live_old_agg(old, power, pool)
        r_limit = _drain_budget(old_agg, r_frac)
    else:
        old_agg, r_limit = None, np.inf
    prices, lam_r, x_lp = _lp_master(pool, gpus, power, load, cost,
                                     old_agg, r_limit)
    quotas = _quotas_from_lp(pool, x_lp, S)
    counts2d, _, _ = _assign_sites(
        pool, soa, quotas, gpus, power, old_agg, _site_group_starts(pool),
        lam_r, x_lp if site_warm else None, row_cost, prices, time_limit,
        workers, site_warm)
    # Sites rationally *decline* quota priced exactly at the LP margin
    # (integer serving rounds up, declining does not), so the marginal
    # capacity of each class intentionally lands in the global repair
    # below — a ratio-greedy cover that is near-LP-optimal at the margin.
    # Do not re-price and re-solve on shortfall: forcing a declined
    # quota back onto its site makes a GPU-starved site serve at a worse
    # TP instead of exporting the load (observed as a 5% objective gap).

    fcounts = counts2d.reshape(-1).astype(float)
    st, drains_ok = _global_repair(fcounts, pool, cost, gpus, power, load,
                                   old_agg, r_limit,
                                   deadline=t0 + time_limit)
    counts = np.round(fcounts).astype(int)
    cap = np.bincount(pool.cls, weights=counts * pool.load, minlength=9)
    unserved = np.maximum(load - cap, 0.0)
    unserved[unserved <= 1e-9] = 0.0
    # projection is best-effort in fractional power-scaling corners
    # (restoring integer instances cannot always reach a fractional
    # old-live total) — never fail silently when the budget is missed
    status = "decomposed"
    if not drains_ok:
        status = "decomposed_overbudget"
        warnings.warn(
            f"plan_l: drain projection left fleet drains "
            f"{st.fleet_drains:.1f} above R_L={st.r_limit:.1f} "
            "(no feasible restoration); plan returned with status "
            "'decomposed_overbudget'", RuntimeWarning, stacklevel=3)
    return Plan(columns=pool.columns(), counts=counts, unserved=unserved,
                objective=objective, status=status,
                solve_seconds=time.perf_counter() - t0, num_sites=S,
                _cols=pool.column_arrays(), _pool=pool)


# ------------------------------------------------------------------
# session path: warm restricted master + incremental dirty-site re-plans
# ------------------------------------------------------------------
class _MasterCache:
    """Static pieces of the aggregate master, shared across a session.

    Everything here depends only on (pool, objective): the float TP
    column, the (s,c,t) group index and per-site group ranges, and each
    group's min-cost column (restore fallback when a live group has no
    support column). The per-slot restricted assembly gathers from
    these instead of rebuilding fleet-wide arrays every solve.
    """

    def __init__(self, pool: ColumnPool, cost: np.ndarray):
        self.pool = pool
        self.cost = cost
        self.tp_f = pool.tp.astype(float)
        self.codes, self.g_site, _, _ = pool.sct()
        self.G = len(self.g_site)
        self.starts = _site_group_starts(pool)
        order = np.argsort(cost, kind="stable")[::-1]
        cheap = np.full(self.G, -1, dtype=np.intp)
        cheap[self.codes[order]] = order        # last write = min cost,
        self.group_cheap = cheap                # first index on ties
        # per-group min cost-per-rps column — FleetState._group_best's
        # default score, hoisted so per-slot repairs skip the argsort
        score = cost / np.maximum(pool.load, 1e-12)
        sorder = np.argsort(score, kind="stable")[::-1]
        rb = np.full(self.G, -1, dtype=np.intp)
        rb[self.codes[sorder]] = sorder
        self.restore_best = rb
        # per-class cost-ascending column order (capacity seed below)
        self.cls_order = [np.nonzero(pool.cls == c)[0][
            np.argsort(cost[pool.cls == c], kind="stable")]
            for c in range(9)]

    def capacity_seed(self, gpus: np.ndarray, power_w: np.ndarray,
                      load: np.ndarray, margin: float = 2.0,
                      sites_sel: Optional[np.ndarray] = None) -> np.ndarray:
        """Cheapest columns whose relaxed capacity covers ``margin``× load.

        The previous slot's LP support is tiny (active sites only), so
        after a fleet-wide power drop a support-only restricted LP
        drops load and its duals jump to DROP_PENALTY — pricing then
        floods with ~every column in the pool. Seeding each class with
        its cost-cheapest columns until their fractional instance
        bound (min of GPU and power headroom) covers a multiple of the
        class demand keeps the first restricted solve feasible, so the
        duals start near the fleet optimum and pricing converges in a
        round or two. Pure function of (pool, cost, slot inputs) —
        identical for incremental and full re-plans by construction.
        ``sites_sel`` restricts the candidate columns to those sites
        (the incremental sub-master); selecting every site is
        bit-identical to no selection.
        """
        pool = self.pool
        smask = None
        if sites_sel is not None:
            smask = np.zeros(pool.num_sites, dtype=bool)
            smask[sites_sel] = True
        pw = np.asarray(power_w, float)
        picks = []
        for c in range(9):
            if load[c] <= 1e-9:
                continue
            oc = self.cls_order[c]
            if smask is not None:
                oc = oc[smask[pool.site[oc]]]
            # one column per site: a site's class-c columns share its
            # GPU/power headroom, so summing all their bounds would
            # overcount the site ~|operating points|-fold and balloon
            # the seed; the cheapest column per site is the LP's likely
            # pick and pricing rounds add any missed mixes
            first = np.unique(pool.site[oc], return_index=True)[1]
            oc = oc[np.sort(first)]
            soc = pool.site[oc]
            ub = np.minimum(gpus[soc] // np.maximum(self.tp_f[oc], 1.0),
                            pw[soc] / np.maximum(pool.power[oc], 1e-12))
            cum = np.cumsum(np.maximum(ub, 0.0) * pool.load[oc])
            k = int(np.searchsorted(cum, margin * load[c])) + 1
            picks.append(oc[:k])
        if not picks:
            return np.empty(0, dtype=np.intp)
        return np.concatenate(picks)


def _lp_master_restricted(cache: _MasterCache, gpus: np.ndarray,
                          power_w: np.ndarray, load: np.ndarray,
                          support: np.ndarray,
                          old_agg: Optional[np.ndarray], r_limit: float,
                          max_rounds: int = 2, batch: int = 8192,
                          sites_sel: Optional[np.ndarray] = None
                          ) -> Optional[tuple]:
    """Restricted master + column generation off the previous support.

    scipy's HiGHS binding exposes no basis warm-start, but the aggregate
    LP's optimal support is tiny (hundreds of columns at 10k sites): a
    restricted LP over the previous slot's support + fresh drain block
    solves in milliseconds, and one reduced-cost pricing pass over the
    full pool (r_j = c_j + π_gpu·tp + π_pw·power − λ_c·load − α_g)
    either certifies it optimal for the whole fleet or adds the worst
    violated columns and re-solves. Returns (prices, λ_R, x_lp, support,
    rounds, converged); ``converged=False`` means ``max_rounds`` ran
    out and the last restricted optimum is returned as-is (load is
    still fully covered thanks to the capacity seed — only the prices
    are approximate). None only when a restricted LP itself fails —
    the caller then falls back to the cold full-pool master.

    ``sites_sel`` prices a *sub-fleet*: candidate columns, drain links
    and the pricing pass restrict to the selected sites, and the caller
    hands in residual ``load`` / ``r_limit`` with the unselected sites'
    fixed assignments folded into them — the incremental dirty-site
    master, O(dirty columns) per round. Selecting every site with the
    un-reduced inputs is bit-identical to ``sites_sel=None``.
    """
    from scipy.optimize import linprog

    pool, cost = cache.pool, cache.cost
    n = len(pool)
    S = len(gpus)
    codes, G = cache.codes, cache.G
    dgrp = (np.nonzero(old_agg > 1e-9)[0] if old_agg is not None
            else np.empty(0, dtype=np.intp))
    pw0 = np.asarray(power_w, float)
    smask = sel = None
    if sites_sel is not None:
        sel = np.asarray(sites_sel, dtype=np.intp)   # sorted site ids
        smask = np.zeros(S, dtype=bool)
        smask[sel] = True
        support = np.asarray(support, dtype=np.intp)
        support = support[smask[pool.site[support]]]
        if len(dgrp):
            dgrp = dgrp[smask[cache.g_site[dgrp]]]
    idx = np.concatenate([np.asarray(support, dtype=np.intp),
                          cache.capacity_seed(gpus, pw0, load,
                                              sites_sel=sites_sel)])
    if len(dgrp):
        # every live group needs a column, or its drain link row would
        # force d_g = old_g with no way to keep the capacity instead
        covered = np.zeros(G, dtype=bool)
        covered[codes[idx]] = True
        missing = dgrp[~covered[dgrp]]
        if len(missing):
            idx = np.concatenate([idx, cache.group_cheap[missing]])
    idx = np.unique(idx)
    pw = pw0
    load9 = np.asarray(load, float)
    if smask is None:
        u = np.arange(n, dtype=np.intp)
        Sr, gpus_r, pw_r = S, gpus, pw
    else:
        u = np.nonzero(smask[pool.site])[0]     # priced universe
        # compact GPU/power rows to the selected sites — a 10%-dirty
        # sub-master otherwise still carries 2S trivial fleet rows,
        # and the LP pays presolve for every one of them
        Sr, gpus_r, pw_r = len(sel), gpus[sel], pw[sel]
    cost_u, tp_u, pow_u = cost[u], cache.tp_f[u], pool.power[u]
    site_u, cls_u, load_u, codes_u = (pool.site[u], pool.cls[u],
                                      pool.load[u], codes[u])
    row_u = site_u if sel is None else np.searchsorted(sel, site_u)
    res = None
    for rounds in range(1, max_rounds + 1):
        k = len(idx)
        Gd = len(dgrp)
        nv = k + 9 + Gd
        c_vec = np.concatenate([cost[idx], np.full(9, DROP_PENALTY),
                                np.zeros(Gd)])
        site_k = (pool.site[idx] if sel is None
                  else np.searchsorted(sel, pool.site[idx]))
        b = ConstraintBuilder(nv)
        b.ub(site_k, np.arange(k), cache.tp_f[idx], gpus_r)
        b.ub(site_k, np.arange(k), pool.power[idx], pw_r)
        b.ub(np.concatenate([pool.cls[idx], np.arange(9)]),
             np.concatenate([np.arange(k), k + np.arange(9)]),
             np.concatenate([-pool.load[idx], -np.ones(9)]), -load9)
        if Gd:
            gmap = np.full(G, -1, dtype=np.intp)
            gmap[dgrp] = np.arange(Gd)
            loc = gmap[codes[idx]]
            msk = loc >= 0
            b.ub(np.concatenate([loc[msk], np.arange(Gd)]),
                 np.concatenate([np.arange(k)[msk], k + 9 + np.arange(Gd)]),
                 np.concatenate([-np.ones(int(msk.sum())), -np.ones(Gd)]),
                 -old_agg[dgrp])
            b.ub(np.zeros(Gd, dtype=np.intp), k + 9 + np.arange(Gd),
                 np.ones(Gd), [float(r_limit)])
        A_ub, b_ub, _, _ = b.build()
        res = linprog(c_vec, A_ub=A_ub, b_ub=b_ub, method="highs")
        if not res.success:
            return None
        marg = res.ineqlin.marginals
        pi_g = np.maximum(-marg[:Sr], 0.0)
        pi_p = np.maximum(-marg[Sr:2 * Sr], 0.0)
        lam_c = np.maximum(-marg[2 * Sr:2 * Sr + 9], 0.0)
        alpha = np.zeros(G)
        lam_r = 0.0
        if Gd:
            alpha[dgrp] = np.maximum(
                -marg[2 * Sr + 9:2 * Sr + 9 + Gd], 0.0)
            lam_r = float(max(-marg[-1], 0.0))
        red = (cost_u + pi_g[row_u] * tp_u + pi_p[row_u] * pow_u
               - lam_c[cls_u] * load_u - alpha[codes_u])
        red[np.searchsorted(u, idx)] = 0.0
        vpos = np.nonzero(red < -1e-7)[0]
        viol = u[vpos]
        if len(viol) == 0 or rounds == max_rounds:
            # converged (pricing certifies fleet-wide optimality), or
            # rounds exhausted: the whole optimum moved (fleet-wide
            # weather front) and chasing it column-by-column costs more
            # than it buys — the truncated restricted optimum already
            # covers all load (capacity seed) and feeds a repair
            # pipeline that keeps R_L hard, so return it flagged
            # rather than burning 10x the budget on the cold master
            x_lp = np.zeros(n)
            x_lp[idx] = np.maximum(res.x[:k], 0.0)
            return lam_c, lam_r, x_lp, idx, rounds, len(viol) == 0
        if len(viol) > batch:
            viol = viol[np.argpartition(red[vpos], batch)[:batch]]
        idx = np.unique(np.concatenate([idx, viol]))
    return None


class PlannerLSession:
    """Stateful Planner-L driver for event-driven fleet-scale re-plans.

    One session owns one fleet (table, sites, objective): the dense
    column pool, the master cache, and the previous slot's solution are
    built once and reused every ``plan()`` call. Three things make the
    chained re-plans cheap where ``plan_l`` starts over each slot:

      * **warm restricted master** — the aggregate LP re-solves over
        the previous slot's support with reduced-cost pricing over the
        full pool (``_lp_master_restricted``); milliseconds instead of
        seconds at 10k sites, exact (certified by pricing) or it falls
        back to the cold master.
      * **incremental dirty-site re-plans** (``mode="auto"``) — a site
        re-solves its quota ILP only when its knowledge-plane power
        moved beyond ``dirty_tol`` (relative) or its previous
        assignment no longer fits the new power cap; clean sites reuse
        the previous slot's accepted counts verbatim. The master still
        re-prices the whole fleet, and the global repair (trim /
        project_drains / cover / polish) runs fleet-wide, so the R_L
        drain budget stays a hard constraint — clean-site reuse can
        never violate it. Falls back to a full re-plan when fleet load
        shifts more than ``dirty_tol`` (quotas move everywhere) or the
        dirty fraction exceeds ``max_dirty_frac`` (incremental would
        not pay); ``plan.meta["fallback"]`` names the reason.
      * **λ_R refinement** — after assembly, if fleet drains still
        exceed R_L, up to ``subgradient_rounds`` multiplicative updates
        raise λ_R and re-solve only the sites draining beyond their
        sub-budget (seeded from the master's fractional drains), so one
        global price no longer under-drains at fleet scale; the hard
        budget is then enforced by the projection as always.

    ``mode="full"`` re-solves every site but keeps the warm master and
    λ_R refinement; ``mode="cold"`` replays the exact ``plan_l``
    pipeline (bit-identical to ``plan_l(old=prev)``, pinned in tests).
    Every plan carries ``meta`` diagnostics (mode, dirty-set size,
    master rounds, per-stage seconds). Single-threaded determinism:
    results are bit-identical across ``workers`` settings, like
    ``plan_l``.
    """

    def __init__(self, table: LookupTable, sites: list[SiteSpec], *,
                 objective: Objective = "latency", r_frac: float = 0.03,
                 time_limit: float = 60.0, workers: Optional[int] = None,
                 site_warm: bool = True, dirty_tol: float = 0.02,
                 max_dirty_frac: float = 0.5, subgradient_rounds: int = 2,
                 swap_rel_tol: float = 1e-3, dual_coupling: bool = True):
        self.table = table
        self.sites = sites
        self.objective: Objective = objective
        self.r_frac = float(r_frac)
        self.time_limit = float(time_limit)
        self.workers = workers
        self.site_warm = bool(site_warm)
        self.dirty_tol = float(dirty_tol)
        self.max_dirty_frac = float(max_dirty_frac)
        self.subgradient_rounds = int(subgradient_rounds)
        self.swap_rel_tol = float(swap_rel_tol)
        self.dual_coupling = bool(dual_coupling)
        self.pool = ColumnPool.dense(table, len(sites))
        self.soa = table_soa(table)
        self.gpus = np.array([s.num_gpus for s in sites], float)
        self.cost = self.pool.cost(objective)
        self.row_cost = (self.soa.e2e if objective == "latency"
                         else self.soa.power)
        self.cache = _MasterCache(self.pool, self.cost)
        self._prev: Optional[dict] = None
        self._subs: dict = {}           # dirty-count -> sub-fleet pool

    def _subfleet(self, D: int) -> tuple:
        """(pool, cost, restore_best) for a ``D``-site sub-fleet (cached).

        The session pool is dense site-major — every site carries the
        same table-row block — so the dirty sub-fleet's columns are
        structurally ``ColumnPool.dense(table, D)`` with sites
        renumbered to their dirty rank. Selecting all ``S`` sites
        reproduces the session pool's arrays exactly, which is what
        keeps all-sites-dirty incremental == full bit-for-bit through
        the repair stage.
        """
        hit = self._subs.get(D)
        if hit is None:
            sp = ColumnPool.dense(self.table, D)
            sc = sp.cost(self.objective)
            codes = sp.sct()[0]
            score = sc / np.maximum(sp.load, 1e-12)
            order = np.argsort(score, kind="stable")[::-1]
            rb = np.full(int(codes.max()) + 1 if len(codes) else 0, -1,
                         dtype=np.intp)
            rb[codes[order]] = order
            hit = (sp, sc, rb)
            self._subs[D] = hit
        return hit

    # ---- dirty-set detection ----
    def _dirty_mask(self, power: np.ndarray, load: np.ndarray,
                    meta: dict) -> Optional[np.ndarray]:
        prev = self._prev
        lref = np.maximum(np.maximum(load, prev["load"]), 1e-9)
        if float(np.max(np.abs(load - prev["load"]) / lref)) > self.dirty_tol:
            meta["fallback"] = "load_moved"
            return None
        dp = np.abs(power - prev["power"])
        ref = np.maximum(np.maximum(prev["power"], power), 1.0)
        dirty = dp > self.dirty_tol * ref
        # reuse must stay power-feasible: a site whose new cap is below
        # its previous assignment's draw has to re-solve
        used = np.bincount(self.pool.site,
                           weights=prev["counts2d"].reshape(-1)
                           * self.pool.power, minlength=len(power))
        dirty |= power < used - 1e-6
        frac = float(dirty.mean()) if len(dirty) else 0.0
        meta["dirty_frac"] = frac
        if frac > self.max_dirty_frac:
            meta["fallback"] = "dirty_frac"
            return None
        return dirty

    # ---- λ_R subgradient refinement ----
    def _refine_lam_r(self, counts2d: np.ndarray, quotas: np.ndarray,
                      power: np.ndarray, old_agg: np.ndarray,
                      r_limit: float, lam_r: float, x_lp: np.ndarray,
                      prices: np.ndarray) -> tuple[np.ndarray, int, float]:
        codes, g_site, G = self.cache.codes, self.cache.g_site, self.cache.G
        S = counts2d.shape[0]
        starts = self.cache.starts
        # per-site drain sub-budgets from the master's fractional drains
        d_frac = np.maximum(
            old_agg - np.bincount(codes, weights=x_lp, minlength=G), 0.0)
        site_budget = np.bincount(g_site, weights=d_frac, minlength=S)
        rounds = 0
        for _ in range(self.subgradient_rounds):
            gcount = np.bincount(
                codes, weights=counts2d.reshape(-1).astype(float),
                minlength=G)
            drains = np.maximum(old_agg - gcount, 0.0)
            overshoot = float(drains.sum()) - r_limit
            if overshoot <= 1e-9:
                break
            # multiplicative price step ∝ relative violation
            lam_r = max(lam_r, 1e-6) * (
                1.0 + min(2.0, overshoot / max(r_limit, 1.0)))
            site_drains = np.bincount(g_site, weights=drains, minlength=S)
            # one instance of slack per site — integer rounding noise
            over = np.nonzero(site_drains > site_budget + 1.0 + 1e-9)[0]
            if len(over) == 0:
                break
            rounds += 1
            shared = (self.soa.cls, self.soa.tp, self.soa.load,
                      self.soa.power, self.row_cost, prices,
                      self.time_limit)
            # x0=None forces branch-and-cut: the rounding fast path
            # ignores drain pricing, so a re-priced λ_R only binds
            # through the ILP's d_g objective terms
            subs = [(quotas[s], self.gpus[s], power[s],
                     old_agg[starts[s]:starts[s + 1]], lam_r, None)
                    for s in over.tolist()]
            for s, x in zip(over.tolist(),
                            _solve_sites(shared, subs, self.workers)):
                counts2d[s] = x
        return counts2d, rounds, lam_r

    # ---- main entry ----
    def plan(self, power_w: np.ndarray, load_per_class: np.ndarray, *,
             mode: str = "auto") -> Plan:
        """Solve one slot; ``mode`` ∈ {"auto", "full", "cold"}."""
        t0 = time.perf_counter()
        pool = self.pool
        S = len(self.sites)
        R = len(self.table.rows)
        power = np.asarray(power_w, float)
        load = np.maximum(np.asarray(load_per_class, float), 0.0)
        prev = self._prev
        meta: dict = {"num_sites": S}
        old_plan = prev["plan"] if prev is not None else None
        if old_plan is not None:
            old_agg = _live_old_agg(old_plan, power, pool)
            r_limit = _drain_budget(old_agg, self.r_frac)
        else:
            old_agg, r_limit = None, np.inf

        dirty = None
        if prev is None or mode == "cold":
            mode_eff = "cold"
        elif mode == "full":
            mode_eff = "full"
        else:
            dirty = self._dirty_mask(power, load, meta)
            mode_eff = "incremental" if dirty is not None else "full"
        meta["mode"] = mode_eff
        meta["dirty_sites"] = (int(dirty.sum()) if dirty is not None
                               else (0 if mode_eff == "cold" else S))

        # ---- master ----
        tm = time.perf_counter()
        warm = None
        sel = None
        cmask = flat_prev = None
        load_m, r_m, clean_drains = load, r_limit, 0.0
        if mode_eff == "incremental":
            # fold the clean sites' reused assignments into the RHS and
            # price only the dirty sub-fleet: residual class demand,
            # residual drain budget, dirty columns.  With every site
            # dirty the residuals and the selection reduce bit-for-bit
            # to the full-mode inputs (the all-dirty == full pin).
            sel = np.nonzero(dirty)[0]
            cmask = ~dirty[pool.site]
            flat_prev = prev["counts2d"].reshape(-1).astype(float)
            clean_cap = np.bincount(
                pool.cls[cmask],
                weights=flat_prev[cmask] * pool.load[cmask], minlength=9)
            load_m = np.maximum(load - clean_cap, 0.0)
            gclean = np.bincount(self.cache.codes[cmask],
                                 weights=flat_prev[cmask],
                                 minlength=self.cache.G)
            cgmask = ~dirty[self.cache.g_site]
            clean_drains = float(np.maximum(
                old_agg - gclean, 0.0)[cgmask].sum())
            r_m = r_limit - clean_drains
            meta["clean_drains"] = clean_drains
        if (mode_eff != "cold" and prev is not None
                and (sel is None or len(sel))):
            warm = _lp_master_restricted(self.cache, self.gpus, power,
                                         load_m, prev["support"], old_agg,
                                         r_m, sites_sel=sel)
        if warm is not None:
            prices, lam_r, x_lp, support, rounds, converged = warm
            meta["master"] = "restricted"
            meta["master_rounds"] = rounds
            meta["master_converged"] = converged
        elif sel is not None and len(sel) == 0:
            # nothing moved beyond tolerance: keep every assignment
            prices, lam_r = np.zeros(9), 0.0
            x_lp = np.zeros(len(pool))
            support = np.asarray(prev["support"], dtype=np.intp)
            meta["master"] = "skipped"
        else:
            if mode_eff != "cold" and prev is not None:
                meta["master_fallback"] = True
            prices, lam_r, x_lp = _lp_master(pool, self.gpus, power, load,
                                             self.cost, old_agg, r_limit)
            support = np.nonzero(x_lp > 1e-9)[0]
            meta["master"] = "full"
        if cmask is not None:
            # composite fractional solution: clean sites at their reused
            # counts, dirty sites at the sub-master optimum (empty
            # clean set leaves x_lp untouched — the all-dirty case)
            x_lp[cmask] = flat_prev[cmask]
            # ---- cross-site dual coupling (the ISSUE 9 carried gap) --
            # a site can be "clean" by its own power/load deltas while
            # the master's capacity/drain duals touching it moved — a
            # clean site next to a hugely dirty neighbor used to keep
            # stale quotas until the next full re-plan. Price each
            # site's reused assignment under the previous and current
            # duals; sites whose dual pressure moved beyond dirty_tol
            # join the dirty set: their reused counts stay the
            # fractional seed (so their quota is unchanged) but they now
            # re-solve at the NEW prices and participate in the
            # sub-fleet repair, which can move capacity onto/off them.
            # No master re-run — the restricted master's duals are the
            # signal, the repair closes the gap.
            if (self.dual_coupling and warm is not None
                    and prev.get("duals") is not None and len(sel)
                    and old_agg is not None):
                p_old, lam_old = prev["duals"]
                cap_sc = np.bincount(
                    pool.site * 9 + pool.cls,
                    weights=flat_prev * pool.load,
                    minlength=S * 9).reshape(S, 9)
                live_site = np.bincount(self.cache.g_site,
                                        weights=old_agg, minlength=S)
                press_new = cap_sc @ prices + lam_r * live_site
                press_old = cap_sc @ p_old + lam_old * live_site
                ref = np.maximum(np.maximum(np.abs(press_new),
                                            np.abs(press_old)), 1e-9)
                newly = ((np.abs(press_new - press_old) / ref
                          > self.dirty_tol) & ~dirty)
                meta["dual_dirty"] = int(newly.sum())
                if newly.any():
                    dirty = dirty | newly
                    sel = np.nonzero(dirty)[0]
                    cmask = ~dirty[pool.site]
                    clean_cap = np.bincount(
                        pool.cls[cmask],
                        weights=flat_prev[cmask] * pool.load[cmask],
                        minlength=9)
                    load_m = np.maximum(load - clean_cap, 0.0)
                    gclean = np.bincount(self.cache.codes[cmask],
                                         weights=flat_prev[cmask],
                                         minlength=self.cache.G)
                    cgmask = ~dirty[self.cache.g_site]
                    clean_drains = float(np.maximum(
                        old_agg - gclean, 0.0)[cgmask].sum())
                    r_m = r_limit - clean_drains
                    meta["clean_drains"] = clean_drains
                    meta["dirty_sites"] = int(dirty.sum())
        meta["t_master"] = time.perf_counter() - tm

        # ---- per-site assignment ----
        ts = time.perf_counter()
        quotas = _quotas_from_lp(pool, x_lp, S)
        counts2d, n_acc, n_hard = _assign_sites(
            pool, self.soa, quotas, self.gpus, power, old_agg,
            self.cache.starts, lam_r, x_lp if self.site_warm else None,
            self.row_cost, prices, self.time_limit, self.workers,
            self.site_warm, site_mask=dirty)
        meta["accepted_sites"] = n_acc
        meta["hard_ilps"] = n_hard
        if dirty is not None:
            keep = ~dirty               # clean sites: previous assignment
            counts2d[keep] = prev["counts2d"][keep]
        meta["t_sites"] = time.perf_counter() - ts

        # ---- λ_R refinement (skipped in cold mode: plan_l parity) ----
        lam_rounds = 0
        if (mode_eff != "cold" and old_agg is not None
                and self.subgradient_rounds > 0):
            counts2d, lam_rounds, lam_r = self._refine_lam_r(
                counts2d, quotas, power, old_agg, r_limit, lam_r, x_lp,
                prices)
        meta["lam_r_rounds"] = lam_rounds

        # ---- global repair ----
        tr = time.perf_counter()
        if sel is not None:
            # trim / project / cover / polish over the dirty sub-fleet
            # only, against the residual load and drain budget — the
            # clean sites' counts (already repaired last slot) stay
            # byte-identical and their drains are accounted in r_m
            D = len(sel)
            if D:
                sp, sc, rb = self._subfleet(D)
                fsub = counts2d[sel].reshape(-1).astype(float)
                starts = self.cache.starts
                lens = starts[sel + 1] - starts[sel]
                off = np.repeat(starts[sel], lens)
                within = (np.arange(int(lens.sum()))
                          - np.repeat(np.cumsum(lens) - lens, lens))
                st, drains_ok = _global_repair(
                    fsub, sp, sc, self.gpus[sel], power[sel], load_m,
                    old_agg[off + within], r_m,
                    deadline=t0 + self.time_limit, exact=False,
                    restore_best=rb, swap_rel_tol=self.swap_rel_tol)
                counts2d[sel] = np.round(fsub).astype(int).reshape(D, -1)
                fleet_dr = st.fleet_drains + clean_drains
            else:
                drains_ok, fleet_dr = True, clean_drains
            if clean_drains > 0.0 and fleet_dr > r_limit + 1e-9:
                drains_ok = False
            counts = counts2d.reshape(-1).copy()
        else:
            fcounts = counts2d.reshape(-1).astype(float)
            st, drains_ok = _global_repair(
                fcounts, pool, self.cost, self.gpus, power, load, old_agg,
                r_limit, deadline=t0 + self.time_limit,
                exact=(mode_eff == "cold"),
                restore_best=self.cache.restore_best,
                swap_rel_tol=(0.0 if mode_eff == "cold"
                              else self.swap_rel_tol))
            counts = np.round(fcounts).astype(int)
            fleet_dr = st.fleet_drains
        meta["t_repair"] = time.perf_counter() - tr
        meta["fleet_drains"] = float(fleet_dr)
        cap = np.bincount(pool.cls, weights=counts * pool.load, minlength=9)
        unserved = np.maximum(load - cap, 0.0)
        unserved[unserved <= 1e-9] = 0.0
        status = "decomposed"
        if not drains_ok:
            status = "decomposed_overbudget"
            warnings.warn(
                f"PlannerLSession: drain projection left fleet drains "
                f"{fleet_dr:.1f} above R_L={r_limit:.1f}; plan "
                "returned with status 'decomposed_overbudget'",
                RuntimeWarning, stacklevel=2)
        plan = Plan(columns=pool.columns(), counts=counts,
                    unserved=unserved, objective=self.objective,
                    status=status,
                    solve_seconds=time.perf_counter() - t0, num_sites=S,
                    _cols=pool.column_arrays(), _pool=pool, meta=meta)
        # next-slot support: this slot's fractional LP support + active
        # plan columns — NOT the whole restricted working set (support ∪
        # capacity seed ∪ priced-in columns), which compounds across
        # slots and re-inflates every later master LP
        support_out = np.unique(np.concatenate(
            [np.nonzero(x_lp > 1e-9)[0], np.nonzero(counts > 0)[0]]))
        # duals for next slot's cross-site coupling check; a skipped
        # master solved nothing, so its zero prices are not a signal —
        # carry the last real duals forward
        if meta.get("master") == "skipped" and prev is not None:
            duals = prev.get("duals")
        else:
            duals = (np.asarray(prices, float).copy(), float(lam_r))
        self._prev = dict(power=power.copy(), load=load.copy(),
                          counts2d=counts.reshape(S, R).copy(), plan=plan,
                          support=support_out, duals=duals)
        return plan


def plan_l(table: LookupTable, sites: list[SiteSpec], power_w: np.ndarray,
           load_per_class: np.ndarray, *, objective: Objective = "latency",
           old: Optional[Plan] = None, r_frac: float = 0.03,
           time_limit: float = 60.0, method: Method = "auto",
           workers: Optional[int] = None, site_warm: bool = True,
           site_rate: Optional[np.ndarray] = None) -> Plan:
    """Solve the Fig. 10 ILP for one 15-min slot.

    ``method`` selects the solve path (see module docstring): "auto"
    (the default) is the drain-priced Lagrangian decomposition at every
    fleet size — the full constraint set, R_L included, with per-site
    ILPs solved independently; "monolithic" is the exact single-solve
    reference. ``workers`` sizes the process pool for the hard site
    ILPs on the decomposed path (None = auto: sequential for small hard
    batches, else one worker per core up to 8); any value returns
    bit-identical plans. ``site_warm`` enables the rounding fast path
    off the master LP's site restriction (disable for an
    all-branch-and-cut A/B — the PR 2-style sequential loop).
    ``site_rate``: per-site [S] relative price/carbon signal for the
    grid objectives ("cost"/"carbon") — scales each site's power cost
    so the planner shifts load toward cheap/clean sites.
    """
    S = len(sites)
    pool = ColumnPool.dense(table, S)
    if method in ("auto", "decomposed"):
        return _solve_decomposed(pool, sites, power_w, load_per_class,
                                 objective, time_limit, old=old,
                                 r_frac=r_frac, workers=workers,
                                 site_warm=site_warm, site_rate=site_rate)
    return _solve_monolithic(pool, sites, power_w, load_per_class, objective,
                             old, r_frac, time_limit, site_rate=site_rate)
