"""Planner-L — the 15-min lookahead ILP (paper Fig. 10).

Given per-site power/GPU budgets, predicted per-class peak load, and the
profiling lookup table, choose integer instance counts X_{c,f,t,s,l}
minimizing aggregate E2E latency (or power) subject to:

  (1) per-site GPU cap           (2) per-site power cap
  (3) per-class serving capacity (4,5) one (f,l) per (s,c,t) via binary Y
  (6,7) bounded reconfigurations vs the previous plan

Deviations from the literal Fig. 10 (documented in DESIGN.md):
  * Reconfiguration counting is at (s,c,t) granularity — *TP* changes,
    which is the stated intent ("Planner-L bounds TP reconfigurations") —
    and counts *drains* of live instances only: bring-up of fresh
    instances on idle GPUs is hidden by DynamoLLM-style background weight
    transfer (the paper adopts exactly this optimisation, K3), and
    capacity that already lost its power needs no drain. Without this,
    the diurnal load ramp itself would exhaust R_L — an artifact the
    paper's wording ("TP changes") clearly does not intend.
  * A per-class slack variable (heavily penalised) keeps the ILP feasible
    under extreme power droughts; slack == predicted request drops. The
    paper handles the same situation operationally ("min-latency converges
    to min-power in extreme resource-constrained cases").
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal, Optional

import numpy as np
from scipy import sparse

from repro.core.lookup import LookupTable, Row
from repro.core.milp import MilpResult, solve_milp

DROP_PENALTY = 1e6          # per unserved rps — dominates any latency gain
Objective = Literal["latency", "power"]


@dataclass(frozen=True)
class SiteSpec:
    name: str
    num_gpus: int


@dataclass
class Plan:
    """Solved assignment for one slot.

    Derived views (``gpu_used``/``power_used``/``capacity``/``mean_e2e``)
    are vectorized over cached per-column arrays (``column_arrays``) —
    built lazily once per plan — so they stay O(columns) numpy bincounts
    even when called every simulated second. ``group_table`` returns the
    cached columnar dispatch table consumed by the Request Scheduler's
    fast path.
    """
    columns: list[tuple[int, Row]]          # (site, row) per column
    counts: np.ndarray                      # instances per column (int)
    unserved: np.ndarray                    # [9] rps that cannot be served
    objective: Objective
    status: str
    solve_seconds: float
    num_sites: int
    _cols: Optional[tuple] = field(default=None, repr=False, compare=False)
    _gtable: object = field(default=None, repr=False, compare=False)

    def column_arrays(self) -> tuple:
        """(site, cls, tp, load, power, e2e) parallel arrays, cached."""
        if self._cols is None:
            n = len(self.columns)
            site = np.empty(n, dtype=np.intp)
            cls_ = np.empty(n, dtype=np.intp)
            tp = np.empty(n, dtype=float)
            load = np.empty(n, dtype=float)
            power = np.empty(n, dtype=float)
            e2e = np.empty(n, dtype=float)
            for i, (s, r) in enumerate(self.columns):
                site[i] = s
                cls_[i] = r.cls
                tp[i] = r.tp
                load[i] = r.load
                power[i] = r.power
                e2e[i] = r.e2e
            self._cols = (site, cls_, tp, load, power, e2e)
        return self._cols

    def group_table(self):
        """Cached columnar view of the active groups (fast dispatch)."""
        if self._gtable is None:
            from repro.core.scheduler import GroupTable
            self._gtable = GroupTable.from_plan(self)
        return self._gtable

    # ---- derived views (vectorized) ----
    def gpu_used(self) -> np.ndarray:
        site, _, tp, _, _, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * tp,
                           minlength=self.num_sites)

    def power_used(self) -> np.ndarray:
        site, _, _, _, power, _ = self.column_arrays()
        return np.bincount(site, weights=self.counts * power,
                           minlength=self.num_sites)

    def capacity(self) -> np.ndarray:
        """[9] provisioned serving capacity in rps per class."""
        _, cls_, _, load, _, _ = self.column_arrays()
        return np.bincount(cls_, weights=self.counts * load, minlength=9)

    def mean_e2e(self, load_per_class: Optional[np.ndarray] = None) -> float:
        """Provisioned-capacity-weighted mean E2E latency.

        ``load_per_class`` is accepted for API compatibility but unused:
        the weighting is by provisioned rps (counts x row load), which is
        what the planner objective optimizes and what the comparisons in
        tests/benchmarks have always measured.
        """
        _, _, _, load, _, e2e = self.column_arrays()
        w = self.counts * load
        return float((w * e2e).sum()) / max(float(w.sum()), 1e-9)

    def total_power(self) -> float:
        return float(self.power_used().sum())

    def active(self) -> list[tuple[int, Row, int]]:
        return [(s, r, int(x)) for (s, r), x in zip(self.columns, self.counts)
                if x > 0]

    def gpu_budget(self) -> dict[tuple[int, int, int], int]:
        """GPU_{s,c,t} — the budget handed to Planner-S."""
        out: dict[tuple[int, int, int], int] = {}
        for (s, r), x in zip(self.columns, self.counts):
            if x > 0:
                k = (s, r.cls, r.tp)
                out[k] = out.get(k, 0) + int(x) * r.tp
        return out

    def wrr_weights(self) -> dict[int, list[tuple[int, Row, float]]]:
        """Per class: [(site, row, weight)] with weight ∝ provisioned rps."""
        cap = self.capacity()
        out: dict[int, list[tuple[int, Row, float]]] = {c: [] for c in range(9)}
        for (s, r), x in zip(self.columns, self.counts):
            if x > 0 and cap[r.cls] > 0:
                out[r.cls].append((s, r, x * r.load / cap[r.cls]))
        return out

    def agg_by_sct(self) -> dict[tuple[int, int, int], int]:
        out: dict[tuple[int, int, int], int] = {}
        for (s, r), x in zip(self.columns, self.counts):
            if x > 0:
                k = (s, r.cls, r.tp)
                out[k] = out.get(k, 0) + int(x)
        return out


def build_columns(table: LookupTable, num_sites: int):
    cols: list[tuple[int, Row]] = []
    for s in range(num_sites):
        for r in table.rows:
            cols.append((s, r))
    return cols


def plan_l(table: LookupTable, sites: list[SiteSpec], power_w: np.ndarray,
           load_per_class: np.ndarray, *, objective: Objective = "latency",
           old: Optional[Plan] = None, r_frac: float = 0.03,
           time_limit: float = 60.0) -> Plan:
    """Solve the Fig. 10 ILP for one 15-min slot."""
    S = len(sites)
    cols = build_columns(table, S)
    n = len(cols)
    col_site = np.array([s for s, _ in cols])
    col_tp = np.array([r.tp for _, r in cols])
    col_load = np.array([r.load for _, r in cols])
    col_power = np.array([r.power for _, r in cols])
    col_cls = np.array([r.cls for _, r in cols])
    col_cost = np.array([r.e2e if objective == "latency" else r.power
                         for _, r in cols])

    # (s,c,t) groups for constraint (4) and reconfig counting
    sct_keys = sorted({(s, r.cls, r.tp) for s, r in cols})
    sct_index = {k: i for i, k in enumerate(sct_keys)}
    col_sct = np.array([sct_index[(s, r.cls, r.tp)] for s, r in cols])
    G = len(sct_keys)

    use_reconfig = old is not None
    # variable layout: [X (n) | Y (n) | slack (9) | R (G)]
    nv = n + n + 9 + (G if use_reconfig else 0)
    iX = np.arange(n)
    iY = n + np.arange(n)
    iSl = 2 * n + np.arange(9)
    iR = 2 * n + 9 + np.arange(G) if use_reconfig else None

    c_vec = np.zeros(nv)
    c_vec[iX] = col_cost
    c_vec[iSl] = DROP_PENALTY

    rows_ub, data_ub, cols_ub, b_ub = [], [], [], []

    def add_ub(terms, rhs):
        i = len(b_ub)
        for j, v in terms:
            rows_ub.append(i)
            cols_ub.append(j)
            data_ub.append(v)
        b_ub.append(rhs)

    N_total = sum(s.num_gpus for s in sites)
    # (1) per-site GPU cap ; (2) per-site power cap
    for s in range(S):
        mask = np.where(col_site == s)[0]
        add_ub([(iX[j], float(col_tp[j])) for j in mask], float(sites[s].num_gpus))
        add_ub([(iX[j], float(col_power[j])) for j in mask], float(power_w[s]))
    # (4) one (f,l) per (s,c,t):  sum_{f,l} Y <= 1
    for g in range(G):
        mask = np.where(col_sct == g)[0]
        add_ub([(iY[j], 1.0) for j in mask], 1.0)
    # (5) X <= N_total * Y
    for j in range(n):
        add_ub([(iX[j], 1.0), (iY[j], -float(N_total))], 0.0)
    # (6,7) reconfiguration bound: drains of *live* previous capacity only.
    # Old capacity at a site is first scaled by how much of the old plan's
    # power draw the new slot's power still supports — capacity whose power
    # died needs no drain (the instances are dark regardless).
    if use_reconfig:
        old_power = old.power_used()
        scale = np.ones(S)
        for s in range(S):
            if old_power[s] > 0:
                scale[s] = min(1.0, power_w[s] / old_power[s])
        old_agg = np.zeros(G)
        for (s, r), x in zip(old.columns, old.counts):
            k = (s, r.cls, r.tp)
            if k in sct_index:
                old_agg[sct_index[k]] += x * scale[s]
        total_old = max(1.0, old_agg.sum())
        r_limit = max(1.0, r_frac * total_old)
        for g in range(G):
            mask = np.where(col_sct == g)[0]
            # drain count: R >= old_live - sum X   (growth is free)
            add_ub([(iX[j], -1.0) for j in mask] + [(iR[g], -1.0)],
                   float(-old_agg[g]))
        add_ub([(iR[g], 1.0) for g in range(G)], float(r_limit))

    A_ub = sparse.csr_matrix((data_ub, (rows_ub, cols_ub)),
                             shape=(len(b_ub), nv))
    b_ub = np.array(b_ub)

    # (3) capacity: sum X*load + slack_c >= Load_c
    rows_lb, cols_lb, data_lb, b_lb = [], [], [], []
    for cidx in range(9):
        mask = np.where(col_cls == cidx)[0]
        i = len(b_lb)
        for j in mask:
            rows_lb.append(i)
            cols_lb.append(iX[j])
            data_lb.append(float(col_load[j]))
        rows_lb.append(i)
        cols_lb.append(iSl[cidx])
        data_lb.append(1.0)
        b_lb.append(float(load_per_class[cidx]))
    A_lb = sparse.csr_matrix((data_lb, (rows_lb, cols_lb)),
                             shape=(len(b_lb), nv))
    b_lb = np.array(b_lb)

    integrality = np.zeros(nv)
    integrality[iX] = 1
    integrality[iY] = 1
    upper = np.full(nv, np.inf)
    upper[iX] = np.array([sites[s].num_gpus // max(t, 1)
                          for s, t in zip(col_site, col_tp)], float)
    upper[iY] = 1.0
    upper[iSl] = np.maximum(load_per_class, 0.0)

    res = solve_milp(c_vec, A_ub=A_ub, b_ub=b_ub, A_lb=A_lb, b_lb=b_lb,
                     integrality=integrality, upper=upper,
                     time_limit=time_limit)
    x = res.x
    return Plan(columns=cols, counts=np.round(x[iX]).astype(int),
                unserved=np.maximum(x[iSl], 0.0), objective=objective,
                status=res.status, solve_seconds=res.solve_seconds,
                num_sites=S)
