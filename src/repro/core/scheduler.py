"""Request Scheduler, packing heuristic, and Configurator (paper §4).

The Request Scheduler dispatches arriving inference requests across sites
with weighted round-robin (WRR), weights taken from the latest plan's
provisioned per-class capacity. The paper's Request Class Predictor
(Albert/DistilBert + regressor, 99.95% bucket accuracy) is treated as an
oracle exactly as the paper does ("we treat output length as an oracle in
our experiments") — ``classify`` on the trace plays that role.

The packing heuristic moves smaller-class requests into under-loaded
instances configured for larger classes (LS→LM, …), starting from the
larger requests — improving latency when a class transiently overloads
its own instances while a bigger class has headroom.

Fast path
---------
Dispatch is the hot inner loop of the week/fine simulators (672 slots,
900 s × 3 variants) and of fleet-scale benchmarks, so it has two
implementations:

  * ``dispatch`` — columnar/vectorized over a ``GroupTable``
    (struct-of-arrays), used everywhere. Both the WRR pass and the
    packing waterfall are numpy matrix ops: WRR shares come from a
    per-class capacity bincount, packing uses the precomputed [9, 9]
    class-dominance mask plus a stable argsort-by-e2e host order and a
    cumsum waterfall per class (9 iterations total, never per-group).
  * ``dispatch_reference`` — the original per-``InstanceGroup`` Python
    loop, kept verbatim as the semantic reference. Equivalence to 1e-9
    on randomized plans is enforced by tests/test_scheduler.py and by
    benchmarks/bench_dispatch.py.

Invariants both paths maintain: served + dropped == arrivals exactly
per class; per-site loads sum to total served; packing only moves a
class onto hosts whose class strictly dominates it (both buckets >=,
not equal); hosts are filled in ascending-e2e order with stable ties.

The Configurator applies TP/frequency changes between plans; groups with
pending TP re-shards are frozen (excluded from Planner-S placement) for
``tp_reshard_seconds`` — the paper's C3 overhead, hidden DynamoLLM-style
by background weight transfer. Its (s, c, t) diffs come from
``Plan.agg_by_sct()``, which aggregates straight off the plan's columnar
pool (one np.unique + bincount — no per-object loop), the same pool
``GroupTable.from_plan`` reads for dispatch and ``Plan.gpu_budget_pool``
reads for the Planner-S hand-off.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.lookup import LookupTable, Row
from repro.core.planner_l import Plan

# class index helpers: c = 3*input_bucket + output_bucket
def _in_bucket(c: int) -> int:
    return c // 3


def _out_bucket(c: int) -> int:
    return c % 3


def smaller_classes(c: int) -> list[int]:
    """Classes strictly dominated by c (both buckets <=, not equal) —
    requests of those classes can safely run on a class-c instance."""
    ic, oc = _in_bucket(c), _out_bucket(c)
    return [3 * i + o for i in range(ic + 1) for o in range(oc + 1)
            if (i, o) != (ic, oc)]


# DOMINANCE[host_cls, req_cls]: a host of class ``host_cls`` may serve
# overflow of class ``req_cls`` (strict dominance, both buckets).
DOMINANCE = np.zeros((9, 9), dtype=bool)
for _c in range(9):
    DOMINANCE[_c, smaller_classes(_c)] = True


@dataclass
class InstanceGroup:
    """All instances at one (site, row) operating point."""
    site: int
    row: Row
    count: int

    @property
    def capacity(self) -> float:
        return self.count * self.row.load


class GroupTable:
    """Columnar (struct-of-arrays) view of a plan's instance groups.

    One row per (site, lookup-row) group; all fields are parallel numpy
    arrays so dispatch is pure vector math. Built once per plan (see
    ``Plan.group_table``) and reused across every dispatch against that
    plan; per-second brownouts only swap the ``counts`` column (see
    ``with_counts``) while the static geometry (dominance-filtered host
    masks, stable e2e host order) is shared.
    """

    __slots__ = ("site", "cls", "count", "load", "e2e", "power",
                 "capacity", "num_sites", "order", "host_ok",
                 "site_groups", "site_e2e_sum")

    def __init__(self, site: np.ndarray, cls: np.ndarray, count: np.ndarray,
                 load: np.ndarray, e2e: np.ndarray, power: np.ndarray,
                 num_sites: int):
        self.site = np.asarray(site, dtype=np.intp)
        self.cls = np.asarray(cls, dtype=np.intp)
        self.count = np.asarray(count, dtype=float)
        self.load = np.asarray(load, dtype=float)
        self.e2e = np.asarray(e2e, dtype=float)
        self.power = np.asarray(power, dtype=float)
        self.capacity = self.count * self.load
        self.num_sites = int(num_sites)
        # stable ascending-e2e order == the reference's stable host sort
        self.order = np.argsort(self.e2e, kind="stable")
        # host_ok[g, c]: group g's class strictly dominates class c
        self.host_ok = DOMINANCE[self.cls]
        # per-site group stats for the router's straggler EWMA (static)
        self.site_groups = np.bincount(self.site, minlength=self.num_sites)
        self.site_e2e_sum = np.bincount(self.site, weights=self.e2e,
                                        minlength=self.num_sites)

    def __len__(self) -> int:
        return self.site.shape[0]

    @classmethod
    def from_groups(cls, groups: list[InstanceGroup],
                    num_sites: int) -> "GroupTable":
        return cls(site=np.array([g.site for g in groups], dtype=np.intp),
                   cls=np.array([g.row.cls for g in groups], dtype=np.intp),
                   count=np.array([g.count for g in groups], dtype=float),
                   load=np.array([g.row.load for g in groups], dtype=float),
                   e2e=np.array([g.row.e2e for g in groups], dtype=float),
                   power=np.array([g.row.power for g in groups], dtype=float),
                   num_sites=num_sites)

    @classmethod
    def from_plan(cls, plan: Plan, active_only: bool = True) -> "GroupTable":
        site, cl, tp, load, power, e2e = plan.column_arrays()
        counts = plan.counts.astype(float)
        if active_only:
            m = counts > 0
            site, cl, load, power, e2e, counts = (
                site[m], cl[m], load[m], power[m], e2e[m], counts[m])
        return cls(site=site, cls=cl, count=counts, load=load, e2e=e2e,
                   power=power, num_sites=plan.num_sites)

    def with_counts(self, counts: np.ndarray) -> "GroupTable":
        """Cheap shallow copy with a different ``count`` column (brownouts)."""
        t = GroupTable.__new__(GroupTable)
        for name in GroupTable.__slots__:       # share all static geometry
            setattr(t, name, getattr(self, name))
        t.count = np.asarray(counts, dtype=float)
        t.capacity = t.count * t.load
        return t

    def total_power(self) -> float:
        return float((self.count * self.power).sum())


@dataclass
class DispatchResult:
    served: np.ndarray            # [9] rps served within capacity
    dropped: np.ndarray           # [9] rps dropped (power/capacity)
    mean_e2e: np.ndarray          # [9] load-weighted mean E2E per class
    packed: np.ndarray            # [9] rps moved by the packing heuristic
    per_site_load: np.ndarray     # [S] rps landing on each site

    def aggregate_e2e(self) -> float:
        m = self.served > 0
        if not m.any():
            return 0.0
        return float((self.mean_e2e[m] * self.served[m]).sum()
                     / self.served[m].sum())


class RequestScheduler:
    """WRR dispatch + optional packing, fluid-flow semantics."""

    def __init__(self, num_sites: int, packing: bool = True):
        self.num_sites = num_sites
        self.packing = packing

    def groups_from_plan(self, plan: Plan) -> list[InstanceGroup]:
        return [InstanceGroup(site=s, row=r, count=int(x))
                for s, r, x in plan.active()]

    # ---------------- vectorized fast path ----------------
    def dispatch(self, groups, arrivals: np.ndarray) -> DispatchResult:
        """Route ``arrivals`` [9] rps across ``groups`` by WRR weights.

        ``groups`` may be a ``GroupTable`` (fast path, preferred) or a
        ``list[InstanceGroup]`` (converted on the fly). Overflow beyond
        rated capacity that packing cannot place is reported as dropped;
        the fluid backlog / 2x queueing model lives in the caller
        (``simulate_slot_fine``), which re-offers queued load as demand.
        """
        if not isinstance(groups, GroupTable):
            groups = GroupTable.from_groups(groups, self.num_sites)
        t = groups
        S = self.num_sites
        load = arrivals.astype(float)
        cap9 = np.bincount(t.cls, weights=t.capacity, minlength=9)

        # ---- first pass: own-class WRR (∝ group capacity) ----
        take = np.minimum(load, cap9)
        take[cap9 <= 0] = 0.0
        frac = np.divide(take, cap9, out=np.zeros(9), where=cap9 > 0)
        share = t.capacity * frac[t.cls]                       # [n]
        free = t.capacity - share
        served = take.copy()
        overflow = load - take
        e2e_num = np.bincount(t.cls, weights=share * t.e2e, minlength=9)
        per_site = np.bincount(t.site, weights=share, minlength=S)
        packed = np.zeros(9)

        # ---- packing: overflow of smaller classes into larger hosts ----
        if self.packing and (overflow > 1e-12).any():
            order = t.order
            site_o = t.site[order]
            e2e_o = t.e2e[order]
            host_ok_o = t.host_ok[order]
            free_o = free[order]
            for c in range(8, -1, -1):        # larger requests first (paper)
                ov = overflow[c]
                if ov <= 1e-12:
                    continue
                hosts = np.nonzero(host_ok_o[:, c] & (free_o > 1e-12))[0]
                if hosts.size == 0:
                    continue
                # waterfall: fill hosts in ascending-e2e order via cumsum
                cum = np.cumsum(free_o[hosts])
                taken = np.diff(np.minimum(cum, ov), prepend=0.0)
                moved = min(ov, cum[-1])
                free_o[hosts] -= taken
                overflow[c] -= moved
                served[c] += moved
                packed[c] += moved
                # a smaller request on a larger-class instance finishes
                # no slower than the host class's e2e
                e2e_num[c] += float((taken * e2e_o[hosts]).sum())
                per_site += np.bincount(site_o[hosts], weights=taken,
                                        minlength=S)
        dropped = overflow
        mean_e2e = np.where(served > 0, e2e_num / np.maximum(served, 1e-12), 0.0)
        return DispatchResult(served=served, dropped=dropped, mean_e2e=mean_e2e,
                              packed=packed, per_site_load=per_site)

    # ---------------- loop reference (equivalence oracle) ----------------
    def dispatch_reference(self, groups: list[InstanceGroup],
                           arrivals: np.ndarray) -> DispatchResult:
        """Original per-object dispatch loop, kept as the semantic oracle
        for the vectorized path (tests assert 1e-9 agreement)."""
        S = self.num_sites
        served = np.zeros(9)
        dropped = np.zeros(9)
        packed = np.zeros(9)
        e2e_num = np.zeros(9)
        per_site = np.zeros(S)
        cap = np.zeros(9)
        for g in groups:
            cap[g.row.cls] += g.capacity

        load = arrivals.astype(float).copy()
        free = {id(g): g.capacity for g in groups}

        # ---- first pass: own-class WRR (∝ group capacity) ----
        overflow = np.zeros(9)
        for c in range(9):
            gs = [g for g in groups if g.row.cls == c]
            if not gs or cap[c] <= 0:
                overflow[c] = load[c]
                continue
            take = min(load[c], cap[c])
            overflow[c] = load[c] - take
            for g in gs:
                share = take * (g.capacity / cap[c])
                free[id(g)] -= share
                served[c] += share
                e2e_num[c] += share * g.row.e2e
                per_site[g.site] += share
        # ---- packing: overflow of smaller classes into larger hosts ----
        if self.packing:
            for c in range(8, -1, -1):        # larger requests first (paper)
                if overflow[c] <= 1e-12:
                    continue
                hosts = [g for g in groups
                         if c in smaller_classes(g.row.cls)
                         and free[id(g)] > 1e-12]
                hosts.sort(key=lambda g: g.row.e2e)
                for g in hosts:
                    if overflow[c] <= 1e-12:
                        break
                    take = min(overflow[c], free[id(g)])
                    free[id(g)] -= take
                    overflow[c] -= take
                    served[c] += take
                    packed[c] += take
                    e2e_num[c] += take * g.row.e2e
                    per_site[g.site] += take
        dropped = overflow
        mean_e2e = np.where(served > 0, e2e_num / np.maximum(served, 1e-12), 0.0)
        return DispatchResult(served=served, dropped=dropped, mean_e2e=mean_e2e,
                              packed=packed, per_site_load=per_site)


@dataclass
class Configurator:
    """Tracks TP re-shards between consecutive plans; freezes groups."""
    tp_reshard_seconds: float = 30.0
    freq_switch_seconds: float = 0.05
    _pending: dict[tuple[int, int, int], float] = field(default_factory=dict)

    def apply(self, old: Plan | None, new: Plan, now: float) -> None:
        """Diff (s,c,t) instance counts; start re-shard timers on changes.
        Already-expired timers are purged so long-running drivers (the
        week simulator applies once per slot) don't accumulate stale
        pending entries."""
        self._pending = {k: t for k, t in self._pending.items() if t > now}
        if old is None:
            return
        o = old.agg_by_sct()
        n = new.agg_by_sct()
        for k in set(o) | set(n):
            if o.get(k, 0) != n.get(k, 0):
                self._pending[k] = now + self.tp_reshard_seconds

    def frozen(self, now: float) -> set:
        self._pending = {k: t for k, t in self._pending.items() if t > now}
        return set(self._pending)

    def reconfig_count(self, old: Plan | None, new: Plan) -> int:
        if old is None:
            return 0
        o = old.agg_by_sct()
        n = new.agg_by_sct()
        return int(sum(abs(o.get(k, 0) - n.get(k, 0)) for k in set(o) | set(n)))
