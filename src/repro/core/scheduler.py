"""Request Scheduler, packing heuristic, and Configurator (paper §4).

The Request Scheduler dispatches arriving inference requests across sites
with weighted round-robin (WRR), weights taken from the latest plan's
provisioned per-class capacity. The paper's Request Class Predictor
(Albert/DistilBert + regressor, 99.95% bucket accuracy) is treated as an
oracle exactly as the paper does ("we treat output length as an oracle in
our experiments") — ``classify`` on the trace plays that role.

The packing heuristic moves smaller-class requests into under-loaded
instances configured for larger classes (LS→LM, …), starting from the
larger requests — improving latency when a class transiently overloads
its own instances while a bigger class has headroom.

The Configurator applies TP/frequency changes between plans; groups with
pending TP re-shards are frozen (excluded from Planner-S placement) for
``tp_reshard_seconds`` — the paper's C3 overhead, hidden DynamoLLM-style
by background weight transfer.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.lookup import LookupTable, Row
from repro.core.planner_l import Plan

# class index helpers: c = 3*input_bucket + output_bucket
def _in_bucket(c: int) -> int:
    return c // 3


def _out_bucket(c: int) -> int:
    return c % 3


def smaller_classes(c: int) -> list[int]:
    """Classes strictly dominated by c (both buckets <=, not equal) —
    requests of those classes can safely run on a class-c instance."""
    ic, oc = _in_bucket(c), _out_bucket(c)
    return [3 * i + o for i in range(ic + 1) for o in range(oc + 1)
            if (i, o) != (ic, oc)]


@dataclass
class InstanceGroup:
    """All instances at one (site, row) operating point."""
    site: int
    row: Row
    count: int

    @property
    def capacity(self) -> float:
        return self.count * self.row.load


@dataclass
class DispatchResult:
    served: np.ndarray            # [9] rps served within capacity
    dropped: np.ndarray           # [9] rps dropped (power/capacity)
    mean_e2e: np.ndarray          # [9] load-weighted mean E2E per class
    packed: np.ndarray            # [9] rps moved by the packing heuristic
    per_site_load: np.ndarray     # [S] rps landing on each site

    def aggregate_e2e(self) -> float:
        m = self.served > 0
        if not m.any():
            return 0.0
        return float((self.mean_e2e[m] * self.served[m]).sum()
                     / self.served[m].sum())


class RequestScheduler:
    """WRR dispatch + optional packing, fluid-flow semantics."""

    def __init__(self, num_sites: int, packing: bool = True):
        self.num_sites = num_sites
        self.packing = packing

    def groups_from_plan(self, plan: Plan) -> list[InstanceGroup]:
        return [InstanceGroup(site=s, row=r, count=int(x))
                for s, r, x in plan.active()]

    def dispatch(self, groups: list[InstanceGroup], arrivals: np.ndarray,
                 backlog: np.ndarray | None = None) -> DispatchResult:
        """Route ``arrivals`` [9] rps across ``groups`` by WRR weights.

        Queueing beyond rated capacity inflates latency via a fluid
        backlog (Little's law); arrivals beyond 2x capacity are dropped.
        """
        S = self.num_sites
        served = np.zeros(9)
        dropped = np.zeros(9)
        packed = np.zeros(9)
        e2e_num = np.zeros(9)
        per_site = np.zeros(S)
        cap = np.zeros(9)
        for g in groups:
            cap[g.row.cls] += g.capacity

        load = arrivals.astype(float).copy()
        free = {id(g): g.capacity for g in groups}

        # ---- first pass: own-class WRR (∝ group capacity) ----
        overflow = np.zeros(9)
        for c in range(9):
            gs = [g for g in groups if g.row.cls == c]
            if not gs or cap[c] <= 0:
                overflow[c] = load[c]
                continue
            take = min(load[c], cap[c])
            overflow[c] = load[c] - take
            for g in gs:
                share = take * (g.capacity / cap[c])
                free[id(g)] -= share
                served[c] += share
                e2e_num[c] += share * g.row.e2e
                per_site[g.site] += share
        # ---- packing: overflow of smaller classes into larger hosts ----
        if self.packing:
            for c in range(8, -1, -1):        # larger requests first (paper)
                if overflow[c] <= 1e-12:
                    continue
                hosts = [g for g in groups
                         if c in smaller_classes(g.row.cls)
                         and free[id(g)] > 1e-12]
                hosts.sort(key=lambda g: g.row.e2e)
                for g in hosts:
                    if overflow[c] <= 1e-12:
                        break
                    take = min(overflow[c], free[id(g)])
                    free[id(g)] -= take
                    overflow[c] -= take
                    served[c] += take
                    packed[c] += take
                    # a smaller request on a larger-class instance finishes
                    # no slower than the host class's e2e
                    e2e_num[c] += take * g.row.e2e
                    per_site[g.site] += take
        dropped = overflow
        mean_e2e = np.where(served > 0, e2e_num / np.maximum(served, 1e-12), 0.0)
        return DispatchResult(served=served, dropped=dropped, mean_e2e=mean_e2e,
                              packed=packed, per_site_load=per_site)


@dataclass
class Configurator:
    """Tracks TP re-shards between consecutive plans; freezes groups."""
    tp_reshard_seconds: float = 30.0
    freq_switch_seconds: float = 0.05
    _pending: dict[tuple[int, int, int], float] = field(default_factory=dict)

    def apply(self, old: Plan | None, new: Plan, now: float) -> None:
        """Diff (s,c,t) instance counts; start re-shard timers on changes."""
        if old is None:
            return
        o = old.agg_by_sct()
        n = new.agg_by_sct()
        for k in set(o) | set(n):
            if o.get(k, 0) != n.get(k, 0):
                self._pending[k] = now + self.tp_reshard_seconds

    def frozen(self, now: float) -> set:
        self._pending = {k: t for k, t in self._pending.items() if t > now}
        return set(self._pending)

    def reconfig_count(self, old: Plan | None, new: Plan) -> int:
        if old is None:
            return 0
        o = old.agg_by_sct()
        n = new.agg_by_sct()
        return int(sum(abs(o.get(k, 0) - n.get(k, 0)) for k in set(o) | set(n)))
