"""Profiling lookup tables — paper K2 / §5.1.

The paper runs a deep power-profiling benchmark (H100 DGX + vLLM + DCGM,
Llama-3.1-70B) and distills it into two functions consumed by the planners:

    e2e(c, f, t, l)    end-to-end latency of class-c requests at load l
                       on a TP-t instance at frequency f
    power(c, f, t, l)  peak instance power at that operating point

This container has no GPUs, so the tables are *derived* from the same
analytical roofline model the dry-run validates (DESIGN.md §3): per-class
prefill/decode latencies from FLOPs / HBM bytes / TP-collective bytes at
the chosen hardware profile, continuous-batching steady state via Little's
law, M/G/1 queueing inflation, and the DVFS power model. The table
*interface* is identical to the paper's (~2,000 rows after SLO filtering;
rows violating TTFT/TBT SLOs are excluded, like the grey cells of Fig 13).

Replicated paper behaviours (validated in tests/test_lookup.py):
  * higher TP or higher frequency → lower latency, higher power;
  * higher load → latency and power both inflate;
  * smallest TP cannot sustain high load for mid/large classes (SLO cut);
  * coding (longer inputs) sustains lower loads than conversation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.workload import CLASSES, WorkloadTrace
from repro.power.model import (HardwareModel, H100_DGX, NODE_MULTIPLIER,
                               accelerator_power)

BYTES = 2                      # bf16 weights/activations
SLO_MULTIPLIER = 5.0           # paper: 5x isolated TTFT/TBT at TP_max, f_max
SLO_MULTIPLier = SLO_MULTIPLIER  # deprecated alias (pre-PR-2 typo), kept for imports
LOAD_GRID = (0.015625, 0.03125, 0.0625, 0.125, 0.25, 0.5, 1.0, 2.0, 4.0,
             8.0, 16.0, 32.0)
MAX_UTIL = 0.95                # queueing stability bound
MFU_PREFILL = 0.55
MFU_DECODE = 0.35


@dataclass(frozen=True)
class ClassProfile:
    name: str
    mean_in: float
    mean_out: float


@dataclass(frozen=True)
class Row:
    cls: int                   # index into CLASSES
    tp: int
    freq: float
    load: float                # requests/s
    ttft: float                # s (queue included)
    tbt: float                 # s/token at steady-state batch
    e2e: float                 # s
    power: float               # instance peak power [W]
    util: float
    batch: float               # steady-state decode batch


@dataclass(frozen=True)
class ClassSLO:
    """Per-class deadline references (isolated run at TP_max / f_max).

    ``slo_ttft``/``slo_tbt`` are the absolute wall-clock deadlines the
    paper uses to filter table rows (5x the isolated reference). The raw
    references (``t_ref``, ``tbt_ref``) are kept so a consumer on a
    *virtual* clock — where one engine tick is one nominal token time —
    can rescale: ttft_deadline_ticks = SLO_MULTIPLIER * t_ref / tbt_ref,
    tbt_deadline_ticks = SLO_MULTIPLIER.
    """
    t_ref: float               # isolated prefill time [s]
    tbt_ref: float             # isolated per-token decode time [s]
    slo_ttft: float            # = SLO_MULTIPLIER * t_ref
    slo_tbt: float             # = SLO_MULTIPLIER * tbt_ref

    def ttft_deadline_ticks(self, tick_tokens: float = 1.0) -> float:
        """TTFT deadline in virtual-clock ticks (1 tick ≡ ``tick_tokens``
        nominal token times at the isolated reference)."""
        return SLO_MULTIPLIER * self.t_ref / (self.tbt_ref * tick_tokens)

    def tbt_deadline_ticks(self, tick_tokens: float = 1.0) -> float:
        return SLO_MULTIPLIER / tick_tokens


class LookupTable:
    """Dense-keyed lookup with the paper's (c, f, t, l) accessors."""

    def __init__(self, arch: str, hw: HardwareModel, classes, rows,
                 slos: Optional[list["ClassSLO"]] = None):
        self.arch = arch
        self.hw = hw
        self.classes: list[ClassProfile] = classes
        self.rows: list[Row] = rows
        # per-class SLO references; absent only for hand-built tables
        self.slos: list[ClassSLO] = slos or []
        self._by_key = {(r.cls, r.freq, r.tp, r.load): r for r in rows}
        self._by_class: dict[int, list[Row]] = {}
        for r in rows:
            self._by_class.setdefault(r.cls, []).append(r)

    def e2e(self, c: int, f: float, t: int, l: float) -> float:
        return self._by_key[(c, f, t, l)].e2e

    def power(self, c: int, f: float, t: int, l: float) -> float:
        return self._by_key[(c, f, t, l)].power

    def get(self, c, f, t, l) -> Optional[Row]:
        return self._by_key.get((c, f, t, l))

    def valid_rows(self, c: int) -> list[Row]:
        return self._by_class.get(c, [])

    def __len__(self):
        return len(self.rows)


# ------------------------------------------------------------------
# analytical serving model
# ------------------------------------------------------------------
def _prefill_time(cfg: ModelConfig, hw: HardwareModel, L_in: float, tp: int,
                  rel_f: float) -> float:
    """One prompt through the model on a TP-``tp`` instance."""
    flops = cfg.flops_per_token(L_in, "prefill") * L_in
    t_compute = flops / (tp * hw.peak_flops * rel_f * MFU_PREFILL)
    weight_bytes = cfg.active_param_count() * BYTES / tp
    t_mem = weight_bytes / hw.hbm_bw
    # TP collectives: 2 all-reduces of [L_in, d] per layer, ring 2(t-1)/t
    coll = (cfg.num_layers * 2 * 2 * (tp - 1) / tp
            * L_in * cfg.d_model * BYTES / hw.link_bw) if tp > 1 else 0.0
    return max(t_compute, t_mem) + coll


def _tbt_coeffs(cfg: ModelConfig, hw: HardwareModel, ctx: float, tp: int,
                rel_f: float) -> tuple[float, float]:
    """TBT(batch n) = W + K·n (weight read + per-sequence cost)."""
    W = cfg.active_param_count() * BYTES / (tp * hw.hbm_bw)
    if tp > 1:
        W += cfg.num_layers * 2 * 2 * (tp - 1) / tp * cfg.d_model * BYTES / hw.link_bw
    kv = cfg.kv_bytes_per_token() * ctx / (tp * hw.hbm_bw)
    comp = cfg.flops_per_token(ctx, "decode") / (tp * hw.peak_flops * rel_f * MFU_DECODE)
    K = kv + comp
    return W, K


def _row(cfg, hw, c_idx, cp: ClassProfile, tp, freq, load) -> Optional[Row]:
    rel_f = freq / hw.f_max
    L_in, L_out = cp.mean_in, cp.mean_out
    ctx = L_in + L_out / 2
    t_pref = _prefill_time(cfg, hw, L_in, tp, rel_f)
    W, K = _tbt_coeffs(cfg, hw, ctx, tp, rel_f)
    # steady-state decode batch: n = load * L_out * TBT(n)  (Little's law)
    denom = 1.0 - load * L_out * K
    if denom <= 1e-6:
        return None                       # token throughput cap exceeded
    n = load * L_out * W / denom
    tbt = W + K * n
    # utilization: each request exclusively costs prefill + L_out*K seconds
    rho = load * (t_pref + L_out * K)
    if rho >= MAX_UTIL:
        return None
    service = t_pref + L_out * tbt
    wait = rho / (1.0 - rho) * service / 2.0        # M/G/1-ish inflation
    ttft = wait + t_pref
    e2e = wait + service
    # power: compute-rate utilisation (decode is memory-bound -> low util)
    flops_rate = load * (cfg.flops_per_token(L_in, "prefill") * L_in
                         + cfg.flops_per_token(ctx, "decode") * L_out)
    util = min(1.0, flops_rate / (tp * hw.peak_flops * rel_f * MFU_PREFILL))
    util_peak = min(1.0, 0.25 + util * 1.25)        # transient headroom
    power = tp * accelerator_power(hw, util_peak, freq) * NODE_MULTIPLIER
    return Row(cls=c_idx, tp=tp, freq=freq, load=load, ttft=ttft, tbt=tbt,
               e2e=e2e, power=power, util=util, batch=n)


def class_profiles(trace: WorkloadTrace) -> list[ClassProfile]:
    return [ClassProfile(CLASSES[i], mi, mo)
            for i, (mi, mo) in enumerate(trace.mean_lengths())]


def build_table(cfg: ModelConfig, trace: WorkloadTrace,
                hw: HardwareModel = H100_DGX,
                load_grid=LOAD_GRID, freq_grid=None) -> LookupTable:
    """The full profiling exercise -> SLO-filtered lookup table.

    ``freq_grid``/``load_grid`` subsets shrink the planner ILPs (the week
    simulator uses a 4x5 grid; standalone profiling benches use the full
    7x10 = paper-scale ~2,000-row table).
    """
    classes = class_profiles(trace)
    rows: list[Row] = []
    slos: list[ClassSLO] = []
    freqs = tuple(freq_grid) if freq_grid is not None else hw.frequencies
    tp_max, f_max = max(hw.tp_degrees), hw.f_max
    for c_idx, cp in enumerate(classes):
        # isolated reference at TP_max / f_max defines the class SLOs
        t_ref = _prefill_time(cfg, hw, cp.mean_in, tp_max, 1.0)
        W, K = _tbt_coeffs(cfg, hw, cp.mean_in + cp.mean_out / 2, tp_max, 1.0)
        slo = ClassSLO(t_ref=t_ref, tbt_ref=W + K,
                       slo_ttft=SLO_MULTIPLIER * t_ref,
                       slo_tbt=SLO_MULTIPLIER * (W + K))
        slos.append(slo)
        for tp in hw.tp_degrees:
            for freq in freqs:
                for load in load_grid:
                    r = _row(cfg, hw, c_idx, cp, tp, freq, load)
                    if r is None or r.ttft > slo.slo_ttft or r.tbt > slo.slo_tbt:
                        continue
                    rows.append(r)
    return LookupTable(cfg.name, hw, classes, rows, slos=slos)
