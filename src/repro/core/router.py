"""Heron — the logically-centralized cross-site router (paper Fig. 9).

Ties the components together for online operation:

    Planner-L  (15 min)   TP + frequency + load assignment, sticky (R_L)
    Configurator          applies TP re-shards, freezes pending groups
    Planner-S  (~5 s)     frequency/load re-solve inside L's GPU budget
    RequestScheduler      WRR dispatch + packing heuristic

``HeronRouter.step_slot`` advances one 15-min slot; ``step_seconds``
advances Planner-S/dispatch inside the slot. The same object also exposes
the straggler mitigation used at 1000+-node scale: per-site service-
latency EWMAs deweight slow sites inside the WRR (the router is the
failure/straggler absorber — the paper's own K1 story).

RoutingPolicy
-------------
``HeronRouter`` natively implements the simulators' pluggable control-
plane interface (``repro.sim.policy.RoutingPolicy``): ``plan_slot`` /
``plan_fine`` map onto the two planner cadences, ``route`` dispatches an
arbitrary (e.g. brownout-shedded) group table through the router's
Request Scheduler, ``observe`` feeds the per-site latency EWMAs with the
fleet-relative slowdown signal, and ``on_event`` consumes ScenarioEngine
control events (``site_down`` / ``site_up`` drive
``mark_site_down``/``mark_site_up``; curtailment notices need no action
here because the power forecast already reflects announced curtailment).
``simulate_week("heron", ...)`` therefore exercises *this object* —
straggler haircut and site-health replanning shape weekly results, and
the Configurator's re-shard freeze clock ticks at slot cadence (its
freeze windows bind Planner-S whenever ``plan_fine`` runs) — rather than
re-implementing the planning loop; registered under the policy names
``"heron"`` (min-latency) and ``"heron_min_power"``.

Failover contract
-----------------
Site health events (``site_down`` / full-depth ``grid_trip``) do two
things: the planner stops assigning the site (``_effective_power`` zeroes
it), and ``failover_order(site)`` tells the serving layer where the dying
site's *in-flight* work should land — surviving sites ranked by their
aggregate WRR weight under the current plan, i.e. the same dispatch-path
view of spare capacity the scheduler routes new work by. The caller
(``sim.cluster.ServingCluster``) drains the dying site's engine into
transcript snapshots and re-admits them sticky-first down this order,
spending a per-request retry budget with ``serving.engine.retry_backoff``
between attempts; a request that exhausts the budget is a permanent
failure and counts against goodput. Policies without ``failover_order``
get index-order failover — the contract is the *ordering*, preemption
safety itself lives in the engine's keyed sampling streams.

Straggler knobs (``straggler_alpha`` / ``straggler_threshold`` /
``straggler_min_haircut``) are constructor parameters — see
``_effective_power`` for the graded-haircut calibration they control.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.lookup import LookupTable
from repro.core.planner_l import Method, Objective, Plan, SiteSpec, plan_l
from repro.core.planner_s import plan_s
from repro.core.predictor import SeriesPredictor
from repro.core.scheduler import Configurator, DispatchResult, RequestScheduler

SLOT_SECONDS = 900.0            # one Planner-L slot (15 min)

# Straggler knobs calibrated against the Azure-trace latency shapes the
# streamed generator produces (``calibrate_straggler_knobs`` below, seed 0
# — pinned by tests/test_sim.py::test_router_straggler_knob_defaults_and
# _factory and the default-drift regression in tests/test_e2e.py). The
# pre-calibration defaults (2.0 / 0.25) were guesses: 2.0 left ~50% of
# headroom between the worst healthy-fleet EWMA excursion (~1.08x fleet
# median) and the trip point unused — real stragglers below 2x rode
# free — and 0.25 kept deweighting proportionally far beyond the ~2.8x
# inflation the workload's own p99/mean tail ratio can explain, i.e. it
# acted on a signal range the latency shapes say carries no information.
STRAGGLER_ALPHA = 0.2
STRAGGLER_THRESHOLD = 1.35
STRAGGLER_MIN_HAIRCUT = 0.47


def calibrate_straggler_knobs(traces=None, *, num_users: int = 1_000_000,
                              num_sites: int = 4,
                              duration_s: float = 6 * 3600.0,
                              window_s: float = 60.0,
                              alpha: float = STRAGGLER_ALPHA,
                              seed: int = 0, headroom: float = 1.25):
    """Derive ``(straggler_threshold, straggler_min_haircut)`` from the
    Azure-trace latency shapes of ``data.workload.stream_requests``.

    The straggler signal the router observes is per-site mean service
    latency relative to the fleet median. On a *healthy* fleet that ratio
    is not 1.0: sites differ in class mix (regional diurnal phase) and
    every window carries lognormal length-sampling noise, so an
    uncalibrated threshold either trips on mix noise (too low) or lets
    real stragglers ride free (too high). This replays a streamed window
    of the generator's traffic, tracks each site's EWMA of mean nominal
    service time (prefill-discounted ``lin`` + ``lout``, in token-time
    units) relative to the fleet median, and returns:

      * ``threshold``: ``headroom`` x the worst healthy fleet-relative
        EWMA excursion — mix noise can never trip the haircut, and
        everything above is genuine slowdown;
      * ``min_haircut``: ``threshold / (p99/mean of the per-request
        service proxy)`` — the haircut stays *proportional* across the
        whole inflation range the workload itself can explain (a site
        stuck on tail-heavy requests inflates its window mean toward the
        proxy's p99), and floors beyond it: deeper inflation is
        non-workload pathology where the proportional signal model no
        longer holds, and the floored residual keeps the site absorbing
        load so its EWMA can recover via ``observe``.
    """
    from repro.data.workload import make_trace, stream_requests
    if traces is None:
        traces = [make_trace("coding"), make_trace("conversation")]
    S = num_sites
    ewma = np.zeros(S)
    burn_in = int(np.ceil(3.0 / alpha))        # ~95% settled
    worst_ewma = 0.0
    proxy_sum = proxy_n = 0.0
    proxy_sample: list[np.ndarray] = []
    nwin = 0
    for ch in stream_requests(traces, num_users=num_users, num_sites=S,
                              duration_s=duration_s, chunk_s=window_s,
                              seed=seed):
        if len(ch) < 2 * S:
            continue
        # nominal service proxy in token-time units: decode is one token
        # time per output token; prefill tokens batch ~an order of
        # magnitude cheaper (MFU_PREFILL vs memory-bound decode)
        proxy = ch.lin / 8.0 + ch.lout
        proxy_sum += float(proxy.sum())
        proxy_n += len(proxy)
        proxy_sample.append(proxy[:: max(len(proxy) // 256, 1)])
        mean = np.full(S, np.nan)
        for s in range(S):
            m = ch.site == s
            if m.any():
                mean[s] = proxy[m].mean()
        rel = mean / max(float(np.nanmedian(mean)), 1e-9)
        ok = np.isfinite(rel)
        ewma[ok] = (1 - alpha) * ewma[ok] + alpha * rel[ok]
        nwin += 1
        if nwin <= burn_in:
            continue
        fleet = float(np.median(ewma[ewma > 0])) if (ewma > 0).any() else 1.0
        worst_ewma = max(worst_ewma, float(np.max(ewma / max(fleet, 1e-9))))
    threshold = round(headroom * worst_ewma, 2)
    tail = np.percentile(np.concatenate(proxy_sample), 99)
    tail_ratio = float(tail) / max(proxy_sum / max(proxy_n, 1.0), 1e-9)
    min_haircut = round(min(1.0, max(0.1, threshold / tail_ratio)), 2)
    return threshold, min_haircut


@dataclass
class HeronRouter:
    table: LookupTable
    sites: list[SiteSpec]
    objective: Objective = "latency"
    r_frac: float = 0.03
    planner_s_period: float = 5.0
    packing: bool = True
    time_limit_l: float = 60.0
    time_limit_s: float = 10.0
    straggler_alpha: float = STRAGGLER_ALPHA       # EWMA coefficient
    # deweight sites slower than threshold x fleet median; floor the
    # graded haircut — both calibrated (calibrate_straggler_knobs)
    straggler_threshold: float = STRAGGLER_THRESHOLD
    straggler_min_haircut: float = STRAGGLER_MIN_HAIRCUT
    planner_method: Method = "auto"       # "monolithic" = exact reference
    planner_workers: Optional[int] = None  # site-ILP process pool size
    # event-driven Planner-L: keep a PlannerLSession across slots and
    # re-plan incrementally (dirty-site sub-solve) when knowledge-plane
    # power moved less than ``dirty_tol`` on most sites. Default off —
    # the stateless plan_l path is the pinned reference behavior.
    incremental: bool = False
    dirty_tol: float = 0.02

    _plan_l: Optional[Plan] = None
    _plan_s: Optional[Plan] = None
    _cfgtor: Configurator = field(default_factory=Configurator)
    _dispatcher: Optional[RequestScheduler] = None
    _site_latency_ewma: Optional[np.ndarray] = None
    _site_alive: Optional[np.ndarray] = None
    _now: float = 0.0
    _session = None                     # lazy PlannerLSession

    def __post_init__(self):
        S = len(self.sites)
        self._dispatcher = RequestScheduler(S, packing=self.packing)
        self._site_latency_ewma = np.zeros(S)
        self._site_alive = np.ones(S, bool)

    # ---------------- site health (fault tolerance) ----------------
    def mark_site_down(self, s: int) -> None:
        """Site lost (grid trip, fibre cut, maintenance) — replan without it."""
        self._site_alive[s] = False

    def mark_site_up(self, s: int) -> None:
        self._site_alive[s] = True

    def observe_latency(self, s: int, latency: float) -> None:
        a = self.straggler_alpha
        self._site_latency_ewma[s] = (1 - a) * self._site_latency_ewma[s] + a * latency

    def observe_latencies(self, mask: np.ndarray, latency: np.ndarray) -> None:
        """Vectorized EWMA update for all sites selected by ``mask``."""
        a = self.straggler_alpha
        ew = self._site_latency_ewma
        ew[mask] = (1 - a) * ew[mask] + a * latency[mask]

    def _effective_power(self, power_w: np.ndarray) -> np.ndarray:
        p = power_w.copy()
        p[~self._site_alive] = 0.0
        # Stragglers: fleet-relative EWMA deweighting inside the WRR is
        # expressed to the planner as a power haircut (fewer requests
        # land). Calibration follows the paper's K1 story — the router is
        # the straggler absorber, deweighting a slow site *in proportion
        # to its observed slowdown* rather than by a fixed step: a site
        # at the 2x-fleet threshold keeps its full power (continuous at
        # the boundary, so jitter near the threshold cannot flap routing
        # weights), a site 2x past it keeps half, and the haircut floors
        # at ``straggler_min_haircut`` so a pathological site still
        # absorbs some load instead of being silently evicted. As the
        # EWMA recovers the severity falls and the haircut relaxes back
        # to 1 (tests/test_sim.py::test_router_straggler_haircut_recovers).
        ew = self._site_latency_ewma
        if ew.max() > 0:
            fleet = max(np.median(ew[ew > 0]) if (ew > 0).any() else 0.0, 1e-9)
            severity = ew / (self.straggler_threshold * fleet)
            slow = severity > 1.0
            p[slow] *= np.clip(1.0 / severity[slow],
                               self.straggler_min_haircut, 1.0)
        return p

    def _site_rate(self) -> Optional[np.ndarray]:
        """Per-site price/carbon signal for the grid objectives — the
        base router has none (None threads through the planners as the
        historical cost vector). Grid-aware subclasses override."""
        return None

    # ---------------- planning ----------------
    def step_slot(self, predicted_power_w: np.ndarray,
                  predicted_load: np.ndarray) -> Plan:
        """Run Planner-L for the next 15-min slot.

        With ``incremental=True`` (and the default decomposed method) the
        slot solve goes through a persistent ``PlannerLSession``: sites
        whose effective power moved within ``dirty_tol`` keep last slot's
        accepted assignment and only the dirty sub-fleet re-solves, with
        automatic fall-back to a full re-plan on fleet-wide shifts (see
        ``PlannerLSession`` for the dirty/fallback rules).
        """
        power = self._effective_power(predicted_power_w)
        if self.incremental and self.planner_method != "monolithic":
            if self._session is None:
                from repro.core.planner_l import PlannerLSession
                self._session = PlannerLSession(
                    self.table, self.sites, objective=self.objective,
                    r_frac=self.r_frac, time_limit=self.time_limit_l,
                    workers=self.planner_workers,
                    dirty_tol=self.dirty_tol)
            p = self._session.plan(power, predicted_load)
        else:
            p = plan_l(self.table, self.sites, power, predicted_load,
                       objective=self.objective, old=self._plan_l,
                       r_frac=self.r_frac, time_limit=self.time_limit_l,
                       method=self.planner_method,
                       workers=self.planner_workers,
                       site_rate=self._site_rate())
        self._cfgtor.apply(self._plan_l, p, self._now)
        self._plan_l = p
        self._plan_s = None
        return p

    def step_seconds(self, now: float, power_w: np.ndarray,
                     observed_load: np.ndarray) -> Plan:
        """Run Planner-S against near-real-time power/load."""
        assert self._plan_l is not None, "step_slot first"
        self._now = now
        frozen = self._cfgtor.frozen(now)
        p = plan_s(self.table, self.sites, self._effective_power(power_w),
                   observed_load, self._plan_l.gpu_budget_pool(),
                   objective=self.objective, frozen_sct=frozen,
                   time_limit=self.time_limit_s, warm=self._plan_s,
                   site_rate=self._site_rate())
        if p.status != "empty":
            self._plan_s = p
        return self._plan_s or self._plan_l

    # ---------------- RoutingPolicy protocol ----------------
    @property
    def name(self) -> str:
        return "heron" if self.objective == "latency" else "heron_min_power"

    def plan_slot(self, pred_power_w: np.ndarray,
                  pred_load: np.ndarray) -> Plan:
        """RoutingPolicy entry for the Planner-L cadence: advances the
        router clock one slot per call (so Configurator re-shard freezes
        tick and expire at slot cadence instead of piling up at t=0),
        then runs ``step_slot``. External callers that drive the clock
        themselves via ``step_seconds(now=...)`` should keep calling
        ``step_slot`` directly."""
        if self._plan_l is not None:
            self._now += SLOT_SECONDS
        return self.step_slot(pred_power_w, pred_load)

    def plan_fine(self, now: float, power_w: np.ndarray,
                  observed_load: np.ndarray) -> Plan:
        """RoutingPolicy alias for ``step_seconds`` (Planner-S cadence)."""
        return self.step_seconds(now, power_w, observed_load)

    def route(self, groups, arrivals_rps: np.ndarray) -> DispatchResult:
        """Dispatch ``arrivals_rps`` over an externally-realized group
        table (the week simulator routes the brownout-shedded plan, not
        the nominal one) through the router's Request Scheduler."""
        return self._dispatcher.dispatch(groups, arrivals_rps)

    def observe(self, latency: np.ndarray,
                mask: Optional[np.ndarray] = None) -> None:
        """Feed the straggler EWMAs a fleet-relative latency signal.

        The week simulator reports each site's service-latency *inflation*
        (1.0 = nominal): structural cross-site E2E differences are
        plan-intentional and must not read as straggling, while a real
        straggler pushes its signal past ``straggler_threshold`` x the
        fleet median and earns the graded haircut.
        """
        if mask is None:
            mask = np.ones(len(self.sites), dtype=bool)
        self.observe_latencies(mask, np.asarray(latency, dtype=float))

    def on_event(self, event) -> None:
        """Consume a ScenarioEngine control event (health signals).

        ``site_down``/``site_up`` are binary site-health edges. A
        ``grid_trip`` carries the trip depth in ``value`` (fraction of
        power lost): a full trip (~1.0) means the site is dark and is
        treated as down; a partial trip is a brownout the planner already
        absorbs through the power forecast, so the site stays routable.
        ``grid_restored`` clears a full trip.
        """
        kind = getattr(event, "kind", None)
        if kind == "site_down":
            self.mark_site_down(event.site)
        elif kind == "site_up":
            self.mark_site_up(event.site)
        elif kind == "grid_trip":
            if getattr(event, "value", 1.0) >= 0.999:
                self.mark_site_down(event.site)
        elif kind == "grid_restored":
            self.mark_site_up(event.site)
        # curtailment notices: the planner already sees capped power via
        # the (announced) forecast — nothing extra to freeze here.

    # ---------------- failover ----------------
    def failover_order(self, site: int) -> list[int]:
        """Preferred landing order for work drained off a dying ``site``.

        The failover contract (honored by ``sim.cluster.ServingCluster``):
        when a site dies, its preempted transcripts are re-routed to the
        surviving sites in this order — sticky (first choice absorbs until
        it rejects), with the caller applying the per-request retry budget
        and ``serving.engine.retry_backoff`` between attempts.

        Ranking reuses the existing dispatch path's view of the world:
        surviving sites ordered by their aggregate WRR weight under the
        current plan (most provisioned spare serving capacity first), so
        failover lands where the planner already wanted load. Falls back
        to alive-sites-by-index when no plan has been solved yet.

        The aggregation runs columnar off ``plan.column_arrays()`` — a
        trip at fleet scale used to walk ``wrr_weights()``'s per-group
        python lists (every active column, dict-of-tuples) just to sum
        per-site weights the arrays give in one ``bincount``.
        """
        S = len(self.sites)
        alive = self._site_alive.copy()
        alive[site] = False
        idx = np.nonzero(alive)[0]
        plan = self._plan_s or self._plan_l
        if plan is None:
            return idx.tolist()
        c_site, c_cls, _, c_load, _, _ = plan.column_arrays()
        counts = np.asarray(plan.counts, float)
        cap = plan.capacity()
        w = counts * c_load / np.maximum(cap[c_cls], 1e-300)
        w[cap[c_cls] <= 0] = 0.0
        agg = np.bincount(c_site, weights=w, minlength=S)
        # descending weight, index ascending on ties (lexsort: last key
        # is primary) — same order the sorted(key=(-agg, s)) walk gave
        return idx[np.lexsort((idx, -agg[idx]))].tolist()

    # ---------------- dispatch ----------------
    def dispatch(self, arrivals_rps: np.ndarray) -> DispatchResult:
        plan = self._plan_s or self._plan_l
        assert plan is not None
        table = plan.group_table()            # cached columnar fast path
        res = self._dispatcher.dispatch(table, arrivals_rps)
        # feed the straggler EWMA: per-site mean group e2e (stats cached
        # on the table — they only depend on the plan)
        loaded = (res.per_site_load > 0) & (table.site_groups > 0)
        mean_e2e = table.site_e2e_sum / np.maximum(table.site_groups, 1)
        self.observe_latencies(loaded, mean_e2e)
        return res


# ------------------------------------------------------------------
# grid-interactive policies (ISSUE 10)
# ------------------------------------------------------------------
@dataclass
class DRHeronPolicy(HeronRouter):
    """Heron + demand response: *acts on* the grid control signals.

    The base router treats ``CURTAILMENT`` as informational (the power
    forecast already carries the cap) and ignores price/carbon notices
    entirely. This subclass turns them into a per-site demand-response
    haircut applied on top of ``_effective_power``:

      * ``CURTAILMENT``(frac) — pre-drain to ``dr_curtail_frac`` of the
        already-capped forecast. Routing *under* the cap leaves wind
        surplus on the curtailed site, which the co-simulated
        ``BatteryBank`` charge step banks for the next trip/spike
        instead of wasting (the ROADMAP's "absorb curtailment" story);
        cleared by ``CURTAILMENT_LIFTED``.
      * ``PRICE_SPIKE``(m) / ``CARBON_RAMP``(m) — shed the site toward
        ``1/m`` of its forecast (floored at ``dr_min_keep``): a 3x price
        spike keeps a third of the load; the planner re-covers the rest
        on cheap/clean sites. Cleared by ``PRICE_NORMAL`` /
        ``CARBON_NORMAL``.

    Haircuts from concurrent signals multiply (a curtailed site in a
    price spike sheds for both); ``site == -1`` applies fleet-wide.
    """
    dr_curtail_frac: float = 0.8        # keep-fraction under curtailment
    dr_min_keep: float = 0.25           # spike-shed floor

    def __post_init__(self):
        super().__post_init__()
        S = len(self.sites)
        self._dr_curtail = np.ones(S)
        self._dr_price = np.ones(S)
        self._dr_carbon = np.ones(S)

    @property
    def name(self) -> str:
        return "dr_heron"

    def _rows(self, site: int) -> slice | int:
        return slice(None) if site < 0 else site

    def on_event(self, event) -> None:
        kind = getattr(event, "kind", None)
        rows = self._rows(getattr(event, "site", -1))
        if kind == "curtailment":
            self._dr_curtail[rows] = self.dr_curtail_frac
        elif kind == "curtailment_lifted":
            self._dr_curtail[rows] = 1.0
        elif kind == "price_spike":
            m = max(float(getattr(event, "value", 1.0)), 1.0)
            self._dr_price[rows] = max(1.0 / m, self.dr_min_keep)
        elif kind == "price_normal":
            self._dr_price[rows] = 1.0
        elif kind == "carbon_ramp":
            m = max(float(getattr(event, "value", 1.0)), 1.0)
            self._dr_carbon[rows] = max(1.0 / m, self.dr_min_keep)
        elif kind == "carbon_normal":
            self._dr_carbon[rows] = 1.0
        else:
            super().on_event(event)

    def _effective_power(self, power_w: np.ndarray) -> np.ndarray:
        p = super()._effective_power(power_w)
        return p * np.minimum(self._dr_curtail,
                              self._dr_price * self._dr_carbon)


@dataclass
class XWindPolicy(HeronRouter):
    """XWind-style cross-site price router.

    Plans under the grid ``"cost"`` objective: each site's power cost is
    scaled by the relative electricity price the control stream
    announces (``PRICE_SPIKE``/``PRICE_NORMAL``), so Planner-L/S shift
    load toward cheap sites *while still serving it* — no shedding,
    pure cross-site arbitrage. The rate vector is mean-normalized
    (``_site_rate``), so a fleet-wide spike changes nothing and only
    price *skew* moves the plan.
    """
    objective: Objective = "cost"

    def __post_init__(self):
        super().__post_init__()
        self._price = np.ones(len(self.sites))

    @property
    def name(self) -> str:
        return "xwind"

    def _site_rate(self) -> Optional[np.ndarray]:
        return self._price / max(float(self._price.mean()), 1e-9)

    def on_event(self, event) -> None:
        kind = getattr(event, "kind", None)
        site = getattr(event, "site", -1)
        rows = slice(None) if site < 0 else site
        if kind == "price_spike":
            self._price[rows] = max(float(getattr(event, "value", 1.0)),
                                    1e-3)
        elif kind == "price_normal":
            self._price[rows] = 1.0
        else:
            super().on_event(event)
