"""Heron — the logically-centralized cross-site router (paper Fig. 9).

Ties the components together for online operation:

    Planner-L  (15 min)   TP + frequency + load assignment, sticky (R_L)
    Configurator          applies TP re-shards, freezes pending groups
    Planner-S  (~5 s)     frequency/load re-solve inside L's GPU budget
    RequestScheduler      WRR dispatch + packing heuristic

``HeronRouter.step_slot`` advances one 15-min slot; ``step_seconds``
advances Planner-S/dispatch inside the slot. The same object also exposes
the straggler mitigation used at 1000+-node scale: per-site service-
latency EWMAs deweight slow sites inside the WRR (the router is the
failure/straggler absorber — the paper's own K1 story).

RoutingPolicy
-------------
``HeronRouter`` natively implements the simulators' pluggable control-
plane interface (``repro.sim.policy.RoutingPolicy``): ``plan_slot`` /
``plan_fine`` map onto the two planner cadences, ``route`` dispatches an
arbitrary (e.g. brownout-shedded) group table through the router's
Request Scheduler, ``observe`` feeds the per-site latency EWMAs with the
fleet-relative slowdown signal, and ``on_event`` consumes ScenarioEngine
control events (``site_down`` / ``site_up`` drive
``mark_site_down``/``mark_site_up``; curtailment notices need no action
here because the power forecast already reflects announced curtailment).
``simulate_week("heron", ...)`` therefore exercises *this object* —
straggler haircut and site-health replanning shape weekly results, and
the Configurator's re-shard freeze clock ticks at slot cadence (its
freeze windows bind Planner-S whenever ``plan_fine`` runs) — rather than
re-implementing the planning loop; registered under the policy names
``"heron"`` (min-latency) and ``"heron_min_power"``.

Failover contract
-----------------
Site health events (``site_down`` / full-depth ``grid_trip``) do two
things: the planner stops assigning the site (``_effective_power`` zeroes
it), and ``failover_order(site)`` tells the serving layer where the dying
site's *in-flight* work should land — surviving sites ranked by their
aggregate WRR weight under the current plan, i.e. the same dispatch-path
view of spare capacity the scheduler routes new work by. The caller
(``sim.cluster.ServingCluster``) drains the dying site's engine into
transcript snapshots and re-admits them sticky-first down this order,
spending a per-request retry budget with ``serving.engine.retry_backoff``
between attempts; a request that exhausts the budget is a permanent
failure and counts against goodput. Policies without ``failover_order``
get index-order failover — the contract is the *ordering*, preemption
safety itself lives in the engine's keyed sampling streams.

Straggler knobs (``straggler_alpha`` / ``straggler_threshold`` /
``straggler_min_haircut``) are constructor parameters — see
``_effective_power`` for the graded-haircut calibration they control.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.lookup import LookupTable
from repro.core.planner_l import Method, Objective, Plan, SiteSpec, plan_l
from repro.core.planner_s import plan_s
from repro.core.predictor import SeriesPredictor
from repro.core.scheduler import Configurator, DispatchResult, RequestScheduler

SLOT_SECONDS = 900.0            # one Planner-L slot (15 min)


@dataclass
class HeronRouter:
    table: LookupTable
    sites: list[SiteSpec]
    objective: Objective = "latency"
    r_frac: float = 0.03
    planner_s_period: float = 5.0
    packing: bool = True
    time_limit_l: float = 60.0
    time_limit_s: float = 10.0
    straggler_alpha: float = 0.2          # EWMA coefficient
    straggler_threshold: float = 2.0      # deweight sites slower than 2x fleet
    straggler_min_haircut: float = 0.25   # floor of the graded power haircut
    planner_method: Method = "auto"       # "monolithic" = exact reference
    planner_workers: Optional[int] = None  # site-ILP process pool size

    _plan_l: Optional[Plan] = None
    _plan_s: Optional[Plan] = None
    _cfgtor: Configurator = field(default_factory=Configurator)
    _dispatcher: Optional[RequestScheduler] = None
    _site_latency_ewma: Optional[np.ndarray] = None
    _site_alive: Optional[np.ndarray] = None
    _now: float = 0.0

    def __post_init__(self):
        S = len(self.sites)
        self._dispatcher = RequestScheduler(S, packing=self.packing)
        self._site_latency_ewma = np.zeros(S)
        self._site_alive = np.ones(S, bool)

    # ---------------- site health (fault tolerance) ----------------
    def mark_site_down(self, s: int) -> None:
        """Site lost (grid trip, fibre cut, maintenance) — replan without it."""
        self._site_alive[s] = False

    def mark_site_up(self, s: int) -> None:
        self._site_alive[s] = True

    def observe_latency(self, s: int, latency: float) -> None:
        a = self.straggler_alpha
        self._site_latency_ewma[s] = (1 - a) * self._site_latency_ewma[s] + a * latency

    def observe_latencies(self, mask: np.ndarray, latency: np.ndarray) -> None:
        """Vectorized EWMA update for all sites selected by ``mask``."""
        a = self.straggler_alpha
        ew = self._site_latency_ewma
        ew[mask] = (1 - a) * ew[mask] + a * latency[mask]

    def _effective_power(self, power_w: np.ndarray) -> np.ndarray:
        p = power_w.copy()
        p[~self._site_alive] = 0.0
        # Stragglers: fleet-relative EWMA deweighting inside the WRR is
        # expressed to the planner as a power haircut (fewer requests
        # land). Calibration follows the paper's K1 story — the router is
        # the straggler absorber, deweighting a slow site *in proportion
        # to its observed slowdown* rather than by a fixed step: a site
        # at the 2x-fleet threshold keeps its full power (continuous at
        # the boundary, so jitter near the threshold cannot flap routing
        # weights), a site 2x past it keeps half, and the haircut floors
        # at ``straggler_min_haircut`` so a pathological site still
        # absorbs some load instead of being silently evicted. As the
        # EWMA recovers the severity falls and the haircut relaxes back
        # to 1 (tests/test_sim.py::test_router_straggler_haircut_recovers).
        ew = self._site_latency_ewma
        if ew.max() > 0:
            fleet = max(np.median(ew[ew > 0]) if (ew > 0).any() else 0.0, 1e-9)
            severity = ew / (self.straggler_threshold * fleet)
            slow = severity > 1.0
            p[slow] *= np.clip(1.0 / severity[slow],
                               self.straggler_min_haircut, 1.0)
        return p

    # ---------------- planning ----------------
    def step_slot(self, predicted_power_w: np.ndarray,
                  predicted_load: np.ndarray) -> Plan:
        """Run Planner-L for the next 15-min slot."""
        p = plan_l(self.table, self.sites,
                   self._effective_power(predicted_power_w), predicted_load,
                   objective=self.objective, old=self._plan_l,
                   r_frac=self.r_frac, time_limit=self.time_limit_l,
                   method=self.planner_method, workers=self.planner_workers)
        self._cfgtor.apply(self._plan_l, p, self._now)
        self._plan_l = p
        self._plan_s = None
        return p

    def step_seconds(self, now: float, power_w: np.ndarray,
                     observed_load: np.ndarray) -> Plan:
        """Run Planner-S against near-real-time power/load."""
        assert self._plan_l is not None, "step_slot first"
        self._now = now
        frozen = self._cfgtor.frozen(now)
        p = plan_s(self.table, self.sites, self._effective_power(power_w),
                   observed_load, self._plan_l.gpu_budget_pool(),
                   objective=self.objective, frozen_sct=frozen,
                   time_limit=self.time_limit_s, warm=self._plan_s)
        if p.status != "empty":
            self._plan_s = p
        return self._plan_s or self._plan_l

    # ---------------- RoutingPolicy protocol ----------------
    @property
    def name(self) -> str:
        return "heron" if self.objective == "latency" else "heron_min_power"

    def plan_slot(self, pred_power_w: np.ndarray,
                  pred_load: np.ndarray) -> Plan:
        """RoutingPolicy entry for the Planner-L cadence: advances the
        router clock one slot per call (so Configurator re-shard freezes
        tick and expire at slot cadence instead of piling up at t=0),
        then runs ``step_slot``. External callers that drive the clock
        themselves via ``step_seconds(now=...)`` should keep calling
        ``step_slot`` directly."""
        if self._plan_l is not None:
            self._now += SLOT_SECONDS
        return self.step_slot(pred_power_w, pred_load)

    def plan_fine(self, now: float, power_w: np.ndarray,
                  observed_load: np.ndarray) -> Plan:
        """RoutingPolicy alias for ``step_seconds`` (Planner-S cadence)."""
        return self.step_seconds(now, power_w, observed_load)

    def route(self, groups, arrivals_rps: np.ndarray) -> DispatchResult:
        """Dispatch ``arrivals_rps`` over an externally-realized group
        table (the week simulator routes the brownout-shedded plan, not
        the nominal one) through the router's Request Scheduler."""
        return self._dispatcher.dispatch(groups, arrivals_rps)

    def observe(self, latency: np.ndarray,
                mask: Optional[np.ndarray] = None) -> None:
        """Feed the straggler EWMAs a fleet-relative latency signal.

        The week simulator reports each site's service-latency *inflation*
        (1.0 = nominal): structural cross-site E2E differences are
        plan-intentional and must not read as straggling, while a real
        straggler pushes its signal past ``straggler_threshold`` x the
        fleet median and earns the graded haircut.
        """
        if mask is None:
            mask = np.ones(len(self.sites), dtype=bool)
        self.observe_latencies(mask, np.asarray(latency, dtype=float))

    def on_event(self, event) -> None:
        """Consume a ScenarioEngine control event (health signals).

        ``site_down``/``site_up`` are binary site-health edges. A
        ``grid_trip`` carries the trip depth in ``value`` (fraction of
        power lost): a full trip (~1.0) means the site is dark and is
        treated as down; a partial trip is a brownout the planner already
        absorbs through the power forecast, so the site stays routable.
        ``grid_restored`` clears a full trip.
        """
        kind = getattr(event, "kind", None)
        if kind == "site_down":
            self.mark_site_down(event.site)
        elif kind == "site_up":
            self.mark_site_up(event.site)
        elif kind == "grid_trip":
            if getattr(event, "value", 1.0) >= 0.999:
                self.mark_site_down(event.site)
        elif kind == "grid_restored":
            self.mark_site_up(event.site)
        # curtailment notices: the planner already sees capped power via
        # the (announced) forecast — nothing extra to freeze here.

    # ---------------- failover ----------------
    def failover_order(self, site: int) -> list[int]:
        """Preferred landing order for work drained off a dying ``site``.

        The failover contract (honored by ``sim.cluster.ServingCluster``):
        when a site dies, its preempted transcripts are re-routed to the
        surviving sites in this order — sticky (first choice absorbs until
        it rejects), with the caller applying the per-request retry budget
        and ``serving.engine.retry_backoff`` between attempts.

        Ranking reuses the existing dispatch path's view of the world:
        surviving sites ordered by their aggregate WRR weight under the
        current plan (most provisioned spare serving capacity first), so
        failover lands where the planner already wanted load. Falls back
        to alive-sites-by-index when no plan has been solved yet.
        """
        alive = [s for s in range(len(self.sites))
                 if self._site_alive[s] and s != site]
        plan = self._plan_s or self._plan_l
        if plan is None:
            return alive
        agg = np.zeros(len(self.sites))
        for rows in plan.wrr_weights().values():
            for s, _row, w in rows:
                agg[s] += w
        return sorted(alive, key=lambda s: (-agg[s], s))

    # ---------------- dispatch ----------------
    def dispatch(self, arrivals_rps: np.ndarray) -> DispatchResult:
        plan = self._plan_s or self._plan_l
        assert plan is not None
        table = plan.group_table()            # cached columnar fast path
        res = self._dispatcher.dispatch(table, arrivals_rps)
        # feed the straggler EWMA: per-site mean group e2e (stats cached
        # on the table — they only depend on the plan)
        loaded = (res.per_site_load > 0) & (table.site_groups > 0)
        mean_e2e = table.site_e2e_sum / np.maximum(table.site_groups, 1)
        self.observe_latencies(loaded, mean_e2e)
        return res
