"""Deployment right-sizing (paper §2.2, Figs 3-5).

Provision compute at the X-th percentile of a site's long-term generation:
cheap at-source power 100% of the time, residual shortfall only X% of the
time. This module reproduces the paper's three analyses:

  * ``opex_fraction``        — Fig 3: lifetime power OPEX vs GPU CAPEX;
  * ``capability_per_price`` — Fig 4: C/P of a wind-sited GPU vs a grid DC,
    parity in ~2y at the 5th pctile / ~5y at the 20th;
  * ``fleet_provisioning``   — Fig 5: SuperPODs deployable at the largest
    Y% farms; 6,636 pods ≈ 6.7 M H100s at x = 80 with real GEM-like sizes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.power.model import SUPERPOD_GPUS, SUPERPOD_PEAK_MW

HOURS_PER_YEAR = 8766.0

# EIA / PPA price points used throughout the paper (USD per kWh)
PRICE_US_ENTERPRISE = 0.085
PRICE_CALIFORNIA = 0.244
PRICE_GERMANY = 0.18
PRICE_GERMANY_CRISIS = 0.40
PRICE_WIND_PPA = 0.025

GPU_PRICE_USD = 30_000.0
GPU_PRICE_BULK_USD = 20_000.0
# Per-GPU draw used in the paper's Fig 3 TCO arithmetic. Back-solving their
# published fractions (12.4% @ 5y/US/30K, 35.6% California, 27% Germany)
# gives ~1.0 kW/GPU — i.e. GPU TDP plus a share of node overhead, slightly
# below the 1.274 kW (0.7 x 1.82) the *cluster* power accounting uses.
GPU_POWER_KW = 1.0
GPU_PEAK_FLOPS_YEAR = 1e22         # paper: ~1e22 FLOPs/year at peak [4]


def opex_fraction(years: float, price_kwh: float,
                  capex: float = GPU_PRICE_USD) -> float:
    """Fig 3: cumulative power OPEX as a fraction of GPU CAPEX."""
    energy_kwh = GPU_POWER_KW * HOURS_PER_YEAR * years
    return energy_kwh * price_kwh / capex


def capability_per_price(years: np.ndarray, *, price_kwh: float,
                         availability: float = 1.0,
                         capex: float = 25_000.0) -> np.ndarray:
    """Fig 4: cumulative compute cycles per dollar over the GPU lifetime.

    ``availability`` < 1 models lost cycles when site generation drops
    below the provisioned threshold (wind deployments); grid DCs use 1.0.
    """
    years = np.asarray(years, float)
    flops = GPU_PEAK_FLOPS_YEAR * availability * years
    opex = GPU_POWER_KW * HOURS_PER_YEAR * years * price_kwh * availability
    return flops / (capex + opex)


def availability_at_percentile(long_term_mw: np.ndarray, pct: float) -> float:
    """Fraction of provisioned compute-hours actually powered.

    Provisioning at the pct-th percentile P* means demand = P*; delivered
    power is min(gen, P*), so availability = E[min(gen, P*)] / P*.
    """
    p_star = np.percentile(long_term_mw, pct)
    if p_star <= 0:
        return 0.0
    return float(np.minimum(long_term_mw, p_star).mean() / p_star)


def parity_year(price_dc: float, price_wind: float, availability: float,
                capex: float = 25_000.0, horizon: float = 12.0) -> float:
    """First year where wind-sited C/P overtakes the traditional-DC C/P."""
    years = np.linspace(0.25, horizon, 480)
    cp_dc = capability_per_price(years, price_kwh=price_dc, capex=capex)
    cp_wind = capability_per_price(years, price_kwh=price_wind,
                                   availability=availability, capex=capex)
    better = np.nonzero(cp_wind >= cp_dc)[0]
    return float(years[better[0]]) if len(better) else float("inf")


@dataclass
class Provisioning:
    site_name: str
    peak_mw: float
    threshold_mw: float          # Xth-pctile generation
    superpods: int
    gpus: int

    @property
    def demand_mw(self) -> float:
        return self.superpods * SUPERPOD_PEAK_MW


def provision_site(name: str, peak_mw: float, long_term_mw: np.ndarray,
                   pct: float = 20.0) -> Provisioning:
    """Right-size one site: SuperPOD multiples under the pct-ile threshold."""
    thresh = float(np.percentile(long_term_mw, pct))
    pods = int(thresh // SUPERPOD_PEAK_MW)
    return Provisioning(site_name=name, peak_mw=peak_mw, threshold_mw=thresh,
                        superpods=pods, gpus=pods * SUPERPOD_GPUS)


def fleet_provisioning(sites, pct: float = 20.0, largest_fraction: float = 0.2):
    """Fig 5: provision the largest ``largest_fraction`` of a site population."""
    ranked = sorted(sites, key=lambda s: s.peak_mw, reverse=True)
    top = ranked[: max(1, int(len(ranked) * largest_fraction))]
    provs = [provision_site(s.name, s.peak_mw, s.long_term_mw, pct) for s in top]
    return provs
