"""MILP solving substrate for the Heron planners.

Exact solves go through ``scipy.optimize.milp`` (HiGHS branch-and-cut —
the offline stand-in for the paper's COIN-OR CBC). Very large instances or
solver timeouts fall back to LP relaxation + floor-rounding + greedy
repair, which preserves feasibility of the ≤-constraints by construction
and repairs ≥-constraints (serving capacity) greedily by cheapest column.

Warm starts
-----------
``scipy.optimize.milp`` cannot seed an incumbent, so warm starting is
implemented *around* the solver: ``solve_milp(..., warm=x0)`` clips and
rounds the previous solution onto the new bounds, repairs it against the
new constraints (shed over-draw, add cheapest capacity), and accepts it —
skipping branch-and-cut entirely — iff its objective is within
``warm_accept_gap`` of the LP-relaxation lower bound of the *new*
problem. The LP bound makes the shortcut sound: a stale or badly
repaired solution fails the gap test and falls through to the cold
solve. Planner-S re-solves inside a slot move power/load by a few
percent per second, so the previous second's plan almost always passes
(status ``"warm"``), turning the per-second MILP into one LP plus a few
vector repairs.

Two-part acceptance (``warm_split``)
------------------------------------
Planner objectives mix two scales: completion cost (latency/power per
instance, O(1..1e3)) and slack penalised at ``DROP_PENALTY`` (1e6 per
unserved rps). A single relative gap on their sum collapses in
slack-saturated droughts: 1% of a slack-dominated objective is under a
rps of unserved, so the one-instance rounding gap between any integer
point and the fractional LP rejects every warm candidate — even ones
that match the true MILP optimum — and the planner cold-solves each
second exactly when solves are hardest. ``warm_split`` (a boolean mask
of the penalty columns) splits the test: the cost part must sit within
``warm_accept_gap`` of the LP's cost part, and the penalty part within
the same relative gap of the LP's penalty part *plus* an absolute
allowance ``warm_slack_abs`` (one instance-granularity of drops, in
objective units) that is granted only when the LP itself carries slack
— outside droughts the penalty test stays exact, so a warm point that
drops servable load is still rejected.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import numpy as np
from scipy import sparse
from scipy.optimize import Bounds, LinearConstraint, linprog, milp


@dataclass
class MilpResult:
    x: np.ndarray
    status: str          # 'optimal' | 'warm' | 'fallback' | 'infeasible'
    objective: float
    solve_seconds: float
    used_fallback: bool = False


def solve_milp(c, A_ub=None, b_ub=None, A_lb=None, b_lb=None,
               integrality=None, upper=None, time_limit: float = 60.0,
               mip_rel_gap: float = 1e-3,
               warm: Optional[np.ndarray] = None,
               warm_accept_gap: float = 0.01,
               warm_split: Optional[np.ndarray] = None,
               warm_slack_abs: float = 0.0,
               warm_slack_unit: Optional[np.ndarray] = None,
               warm_class: Optional[np.ndarray] = None) -> MilpResult:
    """min c.x  s.t.  A_ub x <= b_ub,  A_lb x >= b_lb,  0 <= x <= upper.

    ``warm``: a previous solution over the same variable layout; accepted
    without a branch-and-cut solve when, after repair, it is feasible and
    within ``warm_accept_gap`` (relative) of the LP bound.
    ``warm_split``: boolean mask of penalty (slack) columns enabling the
    two-part acceptance test (see module docstring); ``warm_slack_abs``
    is the absolute penalty-part allowance granted when the LP itself
    carries slack. ``warm_slack_unit`` refines that allowance to the
    actual instance granularity: a per-variable array of the penalty cost
    of rounding that column by one unit (0 for columns that carry none) —
    the drought allowance becomes the largest unit among the non-penalty
    columns the LP left *fractional* (the true integer-rounding frontier)
    instead of a pool-wide worst case, so warm projections cannot
    over-admit drops on pools that merely *contain* large-instance
    groups. When given, it supersedes ``warm_slack_abs``.
    ``warm_class``: per-variable class ids partitioning the penalty test
    — the slack of each class is tested against its *own* class's
    fractional frontier, so a mixed pool no longer hands every class the
    allowance of whichever class carries the largest instances.
    """
    t0 = time.perf_counter()
    n = len(c)
    ub = np.full(n, np.inf) if upper is None else np.asarray(upper, float)
    integ = np.zeros(n) if integrality is None else np.asarray(integrality)

    if warm is not None:
        if len(warm) != n:
            raise ValueError(f"warm vector has {len(warm)} entries for "
                             f"{n} variables — stale layout?")
        x = _warm_repair(np.asarray(warm, float), c, A_ub, b_ub, A_lb, b_lb,
                         integ, ub)
        if x is not None:
            x_lp = _lp_solution(c, A_ub, b_ub, A_lb, b_lb, ub)
            if x_lp is not None and _warm_accept(c, x, x_lp, warm_split,
                                                 warm_accept_gap,
                                                 warm_slack_abs,
                                                 warm_slack_unit,
                                                 warm_class):
                return MilpResult(x=x, status="warm", objective=float(c @ x),
                                  solve_seconds=time.perf_counter() - t0)

    cons = []
    if A_ub is not None and A_ub.shape[0]:
        cons.append(LinearConstraint(A_ub, -np.inf, b_ub))
    if A_lb is not None and A_lb.shape[0]:
        cons.append(LinearConstraint(A_lb, b_lb, np.inf))
    bounds = Bounds(np.zeros(n), ub)
    res = milp(c=c, constraints=cons, bounds=bounds, integrality=integ,
               options={"time_limit": time_limit, "mip_rel_gap": mip_rel_gap})
    dt = time.perf_counter() - t0
    if res.status == 0 and res.x is not None:
        x = np.where(integ > 0, np.round(res.x), res.x)
        return MilpResult(x=x, status="optimal", objective=float(res.fun),
                          solve_seconds=dt)
    # ---- fallback: LP relax + round down + greedy repair ----
    x = _lp_round_repair(c, A_ub, b_ub, A_lb, b_lb, integ, ub)
    dt = time.perf_counter() - t0
    if x is None:
        return MilpResult(x=np.zeros(n), status="infeasible",
                          objective=float("inf"), solve_seconds=dt,
                          used_fallback=True)
    return MilpResult(x=x, status="fallback", objective=float(c @ x),
                      solve_seconds=dt, used_fallback=True)


def _stack_leq(A_ub, b_ub, A_lb, b_lb):
    """Fold A_lb x >= b_lb into the <= system: one (A, b) pair."""
    if A_lb is not None and A_lb.shape[0]:
        if A_ub is not None:
            return sparse.vstack([A_ub, -A_lb]), np.concatenate([b_ub, -b_lb])
        return -A_lb, -b_lb
    return A_ub, b_ub


def _lp_bound(c, A_ub, b_ub, A_lb, b_lb, ub) -> Optional[float]:
    """LP-relaxation lower bound (one HiGHS simplex, no integrality)."""
    x = _lp_solution(c, A_ub, b_ub, A_lb, b_lb, ub)
    return None if x is None else float(c @ x)


def _lp_solution(c, A_ub, b_ub, A_lb, b_lb, ub) -> Optional[np.ndarray]:
    """LP-relaxation optimum (one HiGHS simplex, no integrality)."""
    n = len(c)
    A, b = _stack_leq(A_ub, b_ub, A_lb, b_lb)
    res = linprog(c, A_ub=A, b_ub=b, bounds=list(zip(np.zeros(n), ub)),
                  method="highs")
    return res.x if res.success else None


def _warm_accept(c, x, x_lp, split, gap, slack_abs,
                 slack_unit=None, cls=None) -> bool:
    """LP-bound acceptance: single-part, or two-part when ``split`` set."""
    if split is None:
        bound = float(c @ x_lp)
        return float(c @ x) <= bound + gap * max(1.0, abs(bound))
    m = np.asarray(split, bool)
    cost_x, cost_lp = float(c[~m] @ x[~m]), float(c[~m] @ x_lp[~m])
    pen_lp = float(c[m] @ x_lp[m])
    # absolute (one-instance-granularity) allowances only when the LP
    # itself is slack-saturated — outside droughts a warm point must
    # serve everything the LP serves, and the cost test stays relative
    drought = pen_lp > 1e-9
    cost_allow = (float(c[~m].max()) if drought and (~m).any() else 0.0)
    if cost_x > cost_lp + gap * max(1.0, abs(cost_lp)) + cost_allow:
        return False
    if cls is None:
        pen_x = float(c[m] @ x[m])
        allow = _drought_allowance(x_lp, m, slack_abs, slack_unit) \
            if drought else 0.0
        return pen_x <= pen_lp + gap * max(1.0, abs(pen_lp)) + allow
    cl = np.asarray(cls)
    for k in np.unique(cl[m]):
        mk = m & (cl == k)
        pen_x_k = float(c[mk] @ x[mk])
        pen_lp_k = float(c[mk] @ x_lp[mk])
        # per-class drought test: a class only earns the one-instance
        # rounding allowance when the LP drops *its* load, and only at
        # the granularity of its own fractional columns
        allow_k = (_drought_allowance(x_lp, m, slack_abs, slack_unit,
                                      sel=cl == k)
                   if pen_lp_k > 1e-9 else 0.0)
        if pen_x_k > pen_lp_k + gap * max(1.0, abs(pen_lp_k)) + allow_k:
            return False
    return True


def _drought_allowance(x_lp, split, slack_abs, slack_unit,
                       sel=None) -> float:
    """Penalty-part absolute allowance granted inside a drought.

    With ``slack_unit`` (per-variable penalty of a one-unit rounding of
    that column), the allowance tracks the LP's actual integer frontier:
    the largest unit among non-penalty columns the LP left fractional —
    those are the columns an integer point must round, and rounding one
    down sheds at most its own instance of load. Columns the LP holds at
    integral values need no rounding, so a pool merely *containing* a
    large-instance group no longer widens acceptance. Falls back to the
    largest unit among active columns (degenerate LPs can sit on integer
    vertices while the warm point still re-rounds), then to the scalar
    ``slack_abs``. ``sel`` restricts the candidate columns to one class
    (the per-class acceptance passes each class's own column mask) and
    switches the fractional frontier from the largest unit to the *sum*
    of the class's fractional units — an integer point rounds each
    fractional variable down at most once, so the class can shed up to
    that sum, and with few classes sharing a pool several of its columns
    are routinely left fractional at the LP vertex.
    """
    if slack_unit is None:
        return slack_abs
    u = np.asarray(slack_unit, float)
    zi = ~split & (u > 0)
    if sel is not None:
        zi = zi & np.asarray(sel, bool)
    frac = zi & (np.abs(x_lp - np.round(x_lp)) > 1e-6)
    if frac.any():
        return float(u[frac].sum() if sel is not None else u[frac].max())
    active = zi & (x_lp > 1e-9)
    if active.any():
        return float(u[active].max())
    return 0.0


def _repair_geq(x, c, A_lb, b_lb, integ, ub, allowed=None) -> None:
    """Repair A_lb x >= b_lb in place: bump the cheapest helpful column.

    ``allowed`` optionally restricts the candidate columns (the final
    warm-repair pass uses it to fill residual shortfall with pure-slack
    columns only, which no ≤-row can re-break).
    """
    if A_lb is None or not A_lb.shape[0]:
        return
    A = sparse.csr_matrix(A_lb)
    for _ in range(10_000):
        lhs = A @ x
        short = lhs < b_lb - 1e-9
        if not short.any():
            break
        i = int(np.argmax(b_lb - lhs))
        col_gain = A[i].toarray().ravel()
        ok = (col_gain > 1e-12) & (x < ub - 1e-9)
        if allowed is not None:
            ok &= allowed
        cand = np.where(ok)[0]
        if len(cand) == 0:
            break  # cannot repair; return best effort
        j = cand[np.argmin(c[cand] / col_gain[cand])]
        x[j] += 1.0 if integ[j] > 0 else (b_lb[i] - lhs[i]) / col_gain[j]


def _repair_leq(x, A_ub, b_ub, integ) -> None:
    """Repair A_ub x <= b_ub in place: shed the heaviest contributor."""
    if A_ub is None or not A_ub.shape[0]:
        return
    A = sparse.csr_matrix(A_ub)
    for _ in range(10_000):
        lhs = A @ x
        over = lhs > b_ub + 1e-6
        if not over.any():
            break
        i = int(np.argmax(lhs - b_ub))
        row = A[i].toarray().ravel()
        cand = np.where((row > 1e-12) & (x > 1e-9))[0]
        if len(cand) == 0:
            break
        j = cand[np.argmax(row[cand] * np.maximum(x[cand], 1))]
        x[j] = max(0.0, x[j] - (1.0 if integ[j] > 0 else
                                (lhs[i] - b_ub[i]) / row[j]))


def _feasible(x, A_ub, b_ub, A_lb, b_lb) -> bool:
    if A_ub is not None and A_ub.shape[0]:
        if (A_ub @ x > b_ub + 1e-6).any():
            return False
    if A_lb is not None and A_lb.shape[0]:
        if (A_lb @ x < b_lb - 1e-6).any():
            return False
    return True


def _warm_repair(x0, c, A_ub, b_ub, A_lb, b_lb, integ,
                 ub) -> Optional[np.ndarray]:
    """Project a previous solution onto the new feasible region.

    Shed ≤-violations first (power dropped since the last solve), then
    add capacity for ≥-violations (load rose), then re-shed in case the
    additions overdrew a cap. The re-shed can break ≥-rows again (the
    classic shed/cover cycle when a cap binds tightly in a drought), so
    a final pass fills any residual shortfall using only columns with
    no ≤-row footprint — the pure slack variables — which nothing can
    re-break. Returns None if still infeasible — the caller then
    cold-solves.
    """
    x = np.clip(x0, 0.0, np.where(np.isfinite(ub), ub, np.inf))
    x[integ > 0] = np.round(x[integ > 0])
    x = np.minimum(x, np.where(np.isfinite(ub), ub, np.inf))
    _repair_leq(x, A_ub, b_ub, integ)
    _repair_geq(x, c, A_lb, b_lb, integ, ub)
    _repair_leq(x, A_ub, b_ub, integ)
    if A_ub is not None and A_ub.shape[0]:
        foot = np.asarray(abs(sparse.csr_matrix(A_ub)).sum(axis=0)).ravel()
        _repair_geq(x, c, A_lb, b_lb, integ, ub, allowed=foot <= 1e-12)
    return x if _feasible(x, A_ub, b_ub, A_lb, b_lb) else None


def _lp_round_repair(c, A_ub, b_ub, A_lb, b_lb, integ, ub):
    n = len(c)
    A, b = _stack_leq(A_ub, b_ub, A_lb, b_lb)
    res = linprog(c, A_ub=A, b_ub=b,
                  bounds=list(zip(np.zeros(n), ub)), method="highs")
    if not res.success:
        return None
    x = res.x.copy()
    x[integ > 0] = np.floor(x[integ > 0] + 1e-9)
    # repair >= constraints (capacity) by bumping the cheapest helpful column
    _repair_geq(x, c, A_lb, b_lb, integ, ub)
    # re-check <= feasibility; if violated, undo proportionally
    _repair_leq(x, A_ub, b_ub, integ)
    return x
