"""MILP solving substrate for the Heron planners.

Exact solves go through ``scipy.optimize.milp`` (HiGHS branch-and-cut —
the offline stand-in for the paper's COIN-OR CBC). Very large instances or
solver timeouts fall back to LP relaxation + floor-rounding + greedy
repair, which preserves feasibility of the ≤-constraints by construction
and repairs ≥-constraints (serving capacity) greedily by cheapest column.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np
from scipy import sparse
from scipy.optimize import Bounds, LinearConstraint, linprog, milp


@dataclass
class MilpResult:
    x: np.ndarray
    status: str                 # 'optimal' | 'fallback' | 'infeasible'
    objective: float
    solve_seconds: float
    used_fallback: bool = False


def solve_milp(c, A_ub=None, b_ub=None, A_lb=None, b_lb=None,
               integrality=None, upper=None, time_limit: float = 60.0,
               mip_rel_gap: float = 1e-3) -> MilpResult:
    """min c.x  s.t.  A_ub x <= b_ub,  A_lb x >= b_lb,  0 <= x <= upper."""
    t0 = time.perf_counter()
    n = len(c)
    cons = []
    if A_ub is not None and A_ub.shape[0]:
        cons.append(LinearConstraint(A_ub, -np.inf, b_ub))
    if A_lb is not None and A_lb.shape[0]:
        cons.append(LinearConstraint(A_lb, b_lb, np.inf))
    ub = np.full(n, np.inf) if upper is None else np.asarray(upper, float)
    bounds = Bounds(np.zeros(n), ub)
    integ = np.zeros(n) if integrality is None else np.asarray(integrality)
    res = milp(c=c, constraints=cons, bounds=bounds, integrality=integ,
               options={"time_limit": time_limit, "mip_rel_gap": mip_rel_gap})
    dt = time.perf_counter() - t0
    if res.status == 0 and res.x is not None:
        x = np.where(integ > 0, np.round(res.x), res.x)
        return MilpResult(x=x, status="optimal", objective=float(res.fun),
                          solve_seconds=dt)
    # ---- fallback: LP relax + round down + greedy repair ----
    x = _lp_round_repair(c, A_ub, b_ub, A_lb, b_lb, integ, ub)
    dt = time.perf_counter() - t0
    if x is None:
        return MilpResult(x=np.zeros(n), status="infeasible",
                          objective=float("inf"), solve_seconds=dt,
                          used_fallback=True)
    return MilpResult(x=x, status="fallback", objective=float(c @ x),
                      solve_seconds=dt, used_fallback=True)


def _lp_round_repair(c, A_ub, b_ub, A_lb, b_lb, integ, ub):
    n = len(c)
    A_parts, bl_parts, bu_parts = [], [], []
    if A_ub is not None and A_ub.shape[0]:
        A_parts.append(A_ub)
        bl_parts.append(np.full(A_ub.shape[0], -np.inf))
        bu_parts.append(b_ub)
    if A_lb is not None and A_lb.shape[0]:
        A_parts.append(A_lb)
        bl_parts.append(b_lb)
        bu_parts.append(np.full(A_lb.shape[0], np.inf))
    A = sparse.vstack(A_parts) if A_parts else None
    res = linprog(c, A_ub=sparse.vstack([A_ub, -A_lb]) if A_lb is not None else A_ub,
                  b_ub=np.concatenate([b_ub, -b_lb]) if A_lb is not None else b_ub,
                  bounds=list(zip(np.zeros(n), ub)), method="highs")
    if not res.success:
        return None
    x = res.x.copy()
    x[integ > 0] = np.floor(x[integ > 0] + 1e-9)
    # repair >= constraints (capacity) by bumping the cheapest helpful column
    if A_lb is not None and A_lb.shape[0]:
        A_lb_d = sparse.csr_matrix(A_lb)
        for _ in range(10_000):
            lhs = A_lb_d @ x
            short = lhs < b_lb - 1e-9
            if not short.any():
                break
            i = int(np.argmax(b_lb - lhs))
            col_gain = A_lb_d[i].toarray().ravel()
            cand = np.where((col_gain > 1e-12) & (x < ub - 1e-9))[0]
            if len(cand) == 0:
                break  # cannot repair; return best effort
            j = cand[np.argmin(c[cand] / col_gain[cand])]
            x[j] += 1.0 if integ[j] > 0 else (b_lb[i] - lhs[i]) / col_gain[j]
        # re-check <= feasibility; if violated, undo proportionally
    if A_ub is not None and A_ub.shape[0]:
        A_ub_d = sparse.csr_matrix(A_ub)
        for _ in range(10_000):
            lhs = A_ub_d @ x
            over = lhs > b_ub + 1e-6
            if not over.any():
                break
            i = int(np.argmax(lhs - b_ub))
            row = A_ub_d[i].toarray().ravel()
            cand = np.where((row > 1e-12) & (x > 1e-9))[0]
            if len(cand) == 0:
                break
            j = cand[np.argmax(row[cand] * np.maximum(x[cand], 1))]
            x[j] = max(0.0, x[j] - (1.0 if integ[j] > 0 else
                                    (lhs[i] - b_ub[i]) / row[j]))
    return x
