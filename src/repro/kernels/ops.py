"""Jit'd public wrappers around the Pallas kernels.

Each op dispatches between the Pallas kernel (TPU target; ``interpret=True``
executes it on this CPU container) and the pure-XLA fallback used by the
model zoo when shapes don't tile (odd head_dim, tiny smoke shapes). The
wrappers are the integration point the serving engine and models call; the
oracles live in ``ref.py`` and the sweep tests in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.decode_attention import (
    paged_decode_attention as _paged_decode_pallas,
)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.flash_attention import (
    paged_extend_attention as _paged_extend_pallas,
)
from repro.kernels.grouped_matmul import expert_matmul as _gmm_pallas
from repro.kernels.wkv6 import wkv6 as _wkv6_pallas

# hardware-aligned tiling requirements (MXU lane = 128)
_FLASH_MIN_BLOCK = 16


def _tileable(n: int, block: int) -> bool:
    return n % block == 0 or (n < block and block % n == 0)


@functools.partial(jax.jit, static_argnames=("causal", "prefix_len",
                                             "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, prefix_len: int = 0,
                    use_pallas: bool = True, interpret: bool = True):
    """[B,Sq,H,hd] x [B,Sk,KVH,hd]² -> [B,Sq,H,hd]."""
    Sq, Sk, hd = q.shape[1], k.shape[1], q.shape[-1]
    ok = (use_pallas and Sq % _FLASH_MIN_BLOCK == 0
          and Sk % _FLASH_MIN_BLOCK == 0 and hd % 8 == 0)
    if ok:
        bq = min(128, Sq)
        bk = min(128, Sk)
        return _flash_pallas(q, k, v, causal=causal, prefix_len=prefix_len,
                             block_q=bq, block_k=bk, interpret=interpret)
    return ref.attention_ref(q, k, v, causal=causal, prefix_len=prefix_len)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, use_pallas: bool = True,
                     interpret: bool = True):
    """[B,H,hd] against ragged [B,S,KVH,hd] caches -> [B,H,hd]."""
    S, hd = k_cache.shape[1], q.shape[-1]
    ok = use_pallas and S % _FLASH_MIN_BLOCK == 0 and hd % 8 == 0
    if ok:
        bk = min(256, S)
        return _decode_pallas(q, k_cache, v_cache, lengths, block_k=bk,
                              interpret=interpret)
    return ref.decode_attention_ref(q, k_cache, v_cache, lengths)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_decode_attention(q, k_pool, v_pool, table, lengths, *,
                           k_scale=None, v_scale=None,
                           use_pallas: bool = True, interpret: bool = True):
    """[B,H,hd] against a paged cache: pools [P,page,KVH,hd] + block table
    [B,maxP] (sentinel P) + valid ``lengths`` [B] -> [B,H,hd]. Optional
    [P,page,KVH] scales switch on the fused int8-dequant path."""
    page, hd = k_pool.shape[1], q.shape[-1]
    ok = use_pallas and page % 8 == 0 and hd % 8 == 0
    if ok:
        return _paged_decode_pallas(q, k_pool, v_pool, table, lengths,
                                    k_scale=k_scale, v_scale=v_scale,
                                    interpret=interpret)
    if k_scale is not None:
        # XLA fallback dequantises the gathered view before attending
        kd = (ref.paged_gather_ref(k_pool, table).astype(jnp.float32)
              * ref.paged_gather_ref(k_scale, table)[..., None])
        vd = (ref.paged_gather_ref(v_pool, table).astype(jnp.float32)
              * ref.paged_gather_ref(v_scale, table)[..., None])
        return ref.decode_attention_ref(q, kd, vd, lengths)
    return ref.paged_decode_attention_ref(q, k_pool, v_pool, table, lengths)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def paged_extend_attention(q, k_pool, v_pool, k_new, v_new, table, pos, *,
                           use_pallas: bool = True, interpret: bool = True):
    """Chunked prefill [B,C,H,hd] continued from a paged cache at per-row
    offsets ``pos`` [B] -> [B,C,H,hd]. The chunk's own K/V ride along
    (not yet in the pool); the kernel folds them under the causal
    triangle after streaming the cached pages."""
    page, hd, C = k_pool.shape[1], q.shape[-1], q.shape[1]
    ok = (use_pallas and page % 8 == 0 and hd % 8 == 0
          and C % 8 == 0)
    if ok:
        return _paged_extend_pallas(q, k_pool, v_pool, k_new, v_new,
                                    table, pos, interpret=interpret)
    return ref.paged_extend_attention_ref(q, k_pool, v_pool, k_new, v_new,
                                          table, pos)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def expert_matmul(xe, w, fill=None, *, use_pallas: bool = True,
                  interpret: bool = True):
    """Capacity-bucketed expert GEMM [E,C,D]x[E,D,F] -> [E,C,F]."""
    E, C, D = xe.shape
    F = w.shape[-1]
    ok = (use_pallas and C % _FLASH_MIN_BLOCK == 0 and D % 128 == 0
          and F % 128 == 0)
    if ok:
        bc = min(128, C)
        bd = min(512, D)
        bf = min(128, F)
        return _gmm_pallas(xe, w, fill, block_c=bc, block_d=bd, block_f=bf,
                           interpret=interpret)
    y = jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(xe.dtype)
    if fill is not None:
        row = jnp.arange(C)[None, :, None]
        y = jnp.where(row < fill[:, None, None], y, 0).astype(xe.dtype)
    return y


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas",
                                             "interpret"))
def wkv6(r, k, v, logw, u, state0, *, chunk: int = 64,
         use_pallas: bool = True, interpret: bool = True):
    """Chunked WKV6 recurrence -> (out fp32, state fp32)."""
    S, hd = r.shape[1], r.shape[-1]
    ok = use_pallas and S % min(chunk, S) == 0 and hd % 8 == 0
    if ok:
        return _wkv6_pallas(r, k, v, logw, u, state0,
                            chunk=min(chunk, S), interpret=interpret)
    return ref.wkv6_ref(r, k, v, logw, u, state0)
