"""Pallas TPU kernels for the perf-critical compute layers.

Kernels (each: pl.pallas_call + explicit BlockSpec VMEM tiling):
  flash_attention  — prefill online-softmax attention (TTFT hot spot)
  decode_attention — split-K ragged-cache decode (decode/long-ctx hot spot)
  grouped_matmul   — capacity-bucketed MoE expert GEMM
  wkv6             — chunked RWKV6 recurrence (long_500k arch)

``ops`` holds the jit'd dispatch wrappers; ``ref`` the pure-jnp oracles.
Validated with interpret=True on CPU (tests/test_kernels.py sweeps).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
