"""Pallas TPU flash-attention kernel (prefill hot spot).

Prefill dominates TTFT — the latency term Heron trades against power — so
this is the first kernel on the serving path. TPU-native design (not a CUDA
port): the online-softmax tiling is laid out for the MXU/VMEM hierarchy:

  * grid = (batch x kv_head, q_blocks, kv_blocks); the kv dimension is the
    innermost (sequential) axis so each (b, h, qb) accumulates its running
    (m, l, acc) in VMEM scratch across kv steps — no HBM round-trips for
    the softmax state;
  * q/k/v blocks are (BLOCK_Q x head_dim) / (BLOCK_K x head_dim) VMEM tiles
    with BLOCK_Q = BLOCK_K = 128 (MXU-native 128x128 systolic tiles);
  * GQA is handled by folding the q-head group into the q-block rows:
    a [G*BLOCK_Q, hd] q tile shares one [BLOCK_K, hd] k/v tile, so kv HBM
    traffic is amortised G-fold (the point of GQA);
  * causal masking skips fully-masked kv blocks via ``pl.when`` on the
    block index — ~2x fewer MXU flops at long sequence.

VMEM budget at (G=4, block 128, hd=128), fp32 accumulators:
  q (G·128·128·4) + k,v (2·128·128·4) + s (G·128·128·4) + acc (G·128·128·4)
  ≈ 1.2 MB << 16 MB/core.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               num_kv_blocks: int, prefix_len: int):
    """One (bh, qb, kb) grid step.

    q_ref: [1, G*block_q, hd] — this q block's rows for every grouped head,
    interleaved as (G, block_q). k_ref/v_ref: [1, block_k, hd].
    Scratch m/l: [G*block_q, 1]; acc: [G*block_q, hd] — persist across kb.
    """
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal block skip: a kv block strictly after the q block contributes
    # nothing (the bidirectional prefix only ever *adds* visibility for
    # kv positions < prefix_len, which live in early blocks).
    q_start = qb * block_q
    k_start = kb * block_k
    needed = jnp.logical_or(
        jnp.logical_not(jnp.bool_(causal)),
        jnp.logical_or(k_start <= q_start + block_q - 1,
                       k_start < prefix_len))

    @pl.when(needed)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [G*bq, hd]
        k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G*bq, bk]
        if causal:
            gbq = s.shape[0]
            rows = jax.lax.broadcasted_iota(jnp.int32, (gbq, block_k), 0)
            q_pos = q_start + rows % block_q              # row -> q position
            k_pos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (gbq, block_k), 1)
            mask = jnp.logical_or(q_pos >= k_pos, k_pos < prefix_len)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                               # [G*bq, 1]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))

    @pl.when(kb == num_kv_blocks - 1)
    def _fin():
        o_ref[0, ...] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, prefix_len: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """Flash attention. q: [B, Sq, H, hd]; k/v: [B, Sk, KVH, hd] with
    H % KVH == 0 (GQA). Returns [B, Sq, H, hd].

    ``prefix_len`` marks a bidirectional prefix (PaliGemma-style): kv
    positions < prefix_len stay visible to every q row under causal.
    ``interpret=True`` executes on CPU (this container); pass False on TPU.
    """
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0, (Sq, block_q)
    assert Sk % block_k == 0, (Sk, block_k)
    scale = 1.0 / math.sqrt(hd)
    nq = Sq // block_q
    nk = Sk // block_k

    # layout: fold (B, KVH) into the leading grid dim; per q block the G
    # grouped heads are stacked into rows so one k/v tile serves them all.
    qr = (q.reshape(B, nq, block_q, KVH, G, hd).transpose(0, 3, 1, 4, 2, 5)
          .reshape(B * KVH, nq * G * block_q, hd))
    kr = k.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KVH, Sk, hd)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kv_blocks=nk, prefix_len=prefix_len)

    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, G * block_q, hd), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qb, kb: (bh, kb, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qb, kb: (bh, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, G * block_q, hd),
                               lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, nq * G * block_q, hd),
                                       q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, 1), jnp.float32),
            pltpu.VMEM((G * block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    # undo the per-block head interleave
    out = (out.reshape(B, KVH, nq, G, block_q, hd).transpose(0, 2, 4, 1, 3, 5)
           .reshape(B, Sq, H, hd))
    return out


def _paged_ext_kernel(tab_ref, pos_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                      o_ref, m_scr, l_scr, acc_scr, *, scale: float,
                      page: int, num_pages_logical: int, chunk: int):
    """One (bh, kv-step) grid step of the paged extend (chunked prefill
    continued from a paged cache).

    Steps ``j < nP`` stream physical page ``table[b, j]`` ([1,1,page,hd])
    masked to the row's cached length ``pos[b]``; the LAST step folds the
    chunk's own K/V ([1,chunk,hd]) under the causal triangle. q_ref:
    [1, G*chunk, hd] (grouped heads stacked into rows, as in the dense
    flash kernel)."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]

    def _fold(s, v):
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))

    @pl.when(jnp.logical_and(j < num_pages_logical, j * page < pos))
    def _page_step():
        q = q_ref[0].astype(jnp.float32) * scale          # [G*C, hd]
        k = k_ref[0, 0].astype(jnp.float32)               # [page, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G*C, page]
        k_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _fold(jnp.where(k_pos < pos, s, NEG_INF), v)

    @pl.when(j == num_pages_logical)
    def _chunk_step():
        q = q_ref[0].astype(jnp.float32) * scale          # [G*C, hd]
        k = kn_ref[0].astype(jnp.float32)                 # [C, hd]
        v = vn_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G*C, C]
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % chunk
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _fold(jnp.where(cols <= rows, s, NEG_INF), v)
        o_ref[0, ...] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_extend_attention(q, k_pool, v_pool, k_new, v_new, table, pos, *,
                           interpret: bool = True):
    """Chunked-prefill attention continued from a PAGED cache.

    q: [B, C, H, hd] (the chunk's queries at ragged per-row offsets
    ``pos``); k/v_pool: [P, page, KVH, hd]; k/v_new: [B, C, KVH, hd] (the
    chunk's own K/V, NOT yet in the pool); table: [B, maxP] int32 block
    table (sentinel ``P``); pos: [B] cached tokens per row. Each q row i
    sees the row's whole cached prefix plus chunk columns <= i. Returns
    [B, C, H, hd].

    Grid = (B*KVH, maxP + 1): one split per logical page (scalar-prefetch
    block-table translation, skipped past ``pos``) plus one final split
    for the chunk's causal triangle.
    """
    B, C, H, hd = q.shape
    P, page, KVH = k_pool.shape[:3]
    nP = table.shape[1]
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)

    qr = (q.reshape(B, C, KVH, G, hd).transpose(0, 2, 3, 1, 4)
          .reshape(B * KVH, G * C, hd))
    kr = k_pool.transpose(0, 2, 1, 3)                  # [P, KVH, page, hd]
    vr = v_pool.transpose(0, 2, 1, 3)
    knr = k_new.transpose(0, 2, 1, 3).reshape(B * KVH, C, hd)
    vnr = v_new.transpose(0, 2, 1, 3).reshape(B * KVH, C, hd)
    posr = jnp.repeat(pos.astype(jnp.int32), KVH)      # [B*KVH]

    def page_idx(bh, j, tab):
        jj = jnp.minimum(j, nP - 1)   # chunk step: any mapped page (unused)
        return (jnp.minimum(tab[bh // KVH, jj], P - 1), bh % KVH, 0, 0)

    kernel = functools.partial(_paged_ext_kernel, scale=scale, page=page,
                               num_pages_logical=nP, chunk=C)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * KVH, nP + 1),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, j, tab: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G * C, hd), lambda bh, j, tab: (bh, 0, 0)),
            pl.BlockSpec((1, 1, page, hd), page_idx),
            pl.BlockSpec((1, 1, page, hd), page_idx),
            pl.BlockSpec((1, C, hd), lambda bh, j, tab: (bh, 0, 0)),
            pl.BlockSpec((1, C, hd), lambda bh, j, tab: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G * C, hd), lambda bh, j, tab: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G * C, 1), jnp.float32),
            pltpu.VMEM((G * C, 1), jnp.float32),
            pltpu.VMEM((G * C, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KVH, G * C, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), posr, qr, kr, vr, knr, vnr)
    return (out.reshape(B, KVH, G, C, hd).transpose(0, 3, 1, 2, 4)
            .reshape(B, C, H, hd))
