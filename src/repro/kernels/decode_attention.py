"""Pallas TPU decode-attention kernel (decode_32k / long_500k hot spot).

Decode is HBM-bound KV streaming: one new token's q attends over a long
cache. TPU-native split-K design:

  * grid = (batch x kv_head, kv_splits); each split streams one
    [BLOCK_K, hd] cache chunk HBM→VMEM and folds it into running
    (m, l, acc) partial-softmax state held in VMEM scratch — the classic
    split-K combine without materialising per-split partials in HBM;
  * the q tile is tiny ([G, hd] — the GQA group of the kv head), so the
    whole kernel is bandwidth-limited by design: bytes moved ≈ cache bytes,
    the roofline floor for decode;
  * per-sequence valid length masks the tail chunk via iota compare, so
    ragged batches (continuous batching) need no cache compaction.

VMEM: k,v chunks 2·256·128·2B = 128 KB + q/acc ≈ negligible — far under
budget, leaving room for the pipeline's double buffering.

Paged variant (``paged_decode_attention``): the cache is a shared pool of
``[P, page, KVH, hd]`` physical pages addressed through a per-sequence
block table ``[B, maxP]`` (sentinel ``P`` = unmapped). The table rides the
grid as a SCALAR-PREFETCH argument (``pltpu.PrefetchScalarGridSpec``), so
the k/v BlockSpec index_maps translate (sequence, logical page) ->
physical page BEFORE the DMA is issued — the kernel streams exactly the
pages the sequence owns, never the dead tail of a dense max_seq row. One
grid split per logical page; splits past the valid length skip via
``pl.when`` exactly like the dense tail masking, so the HBM bytes scale
with the LIVE cache, not the allocation. The int8 twin fuses per-token
dequant in VMEM like the dense path.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, block_k: int, num_splits: int,
                ks_ref=None, vs_ref=None):
    """One (bh, split) grid step. q_ref: [1, G, hd]; k/v_ref: [1, bk, hd].

    ``ks_ref``/``vs_ref``: optional [1, bk] per-token dequant scales — the
    int8-cache path (§Perf H3): the cache streams HBM→VMEM at 1 B/element
    and is dequantised here, in VMEM, for free alongside the MXU feed.
    """
    sp = pl.program_id(1)

    @pl.when(sp == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = sp * block_k
    # skip chunks entirely past this sequence's valid length
    @pl.when(k_start < length)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [G, hd]
        k = k_ref[0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        if ks_ref is not None:
            k = k * ks_ref[0][:, None]                    # fused dequant
            v = v * vs_ref[0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, bk]
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))

    @pl.when(sp == num_splits - 1)
    def _fin():
        o_ref[0, ...] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = True):
    """Single-token attention over a ragged KV cache.

    q: [B, H, hd]; k_cache/v_cache: [B, S, KVH, hd]; lengths: [B] int32
    (number of valid cached tokens per sequence, including any freshly
    inserted current-token K/V). Returns [B, H, hd].
    """
    B, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(B, KVH, G, hd).reshape(B * KVH, G, hd)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    lens = jnp.repeat(lengths.astype(jnp.int32), KVH)      # [B*KVH]

    kernel = functools.partial(_dec_kernel, scale=scale, block_k=block_k,
                               num_splits=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, sp: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda bh, sp: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, sp: (bh, sp, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, sp: (bh, sp, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, sp: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(B, H, hd)


def decode_attention_int8(q, k_cache, v_cache, k_scale, v_scale, lengths, *,
                          block_k: int = DEFAULT_BLOCK_K,
                          interpret: bool = True):
    """int8-cache decode attention (§Perf H3).

    q: [B, H, hd] (fp); k/v_cache: [B, S, KVH, hd] int8 with per-(token,
    kv-head) scales [B, S, KVH] fp32. The cache streams at 1 B/element —
    halving the decode memory-roofline term — and is dequantised inside
    the kernel while feeding the MXU. Returns [B, H, hd] in q.dtype.
    """
    import functools as _ft
    B, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(B * KVH, G, hd)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * KVH, S, hd)
    ksr = k_scale.transpose(0, 2, 1).reshape(B * KVH, S)
    vsr = v_scale.transpose(0, 2, 1).reshape(B * KVH, S)
    lens = jnp.repeat(lengths.astype(jnp.int32), KVH)

    def kernel(len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
               m_scr, l_scr, acc_scr):
        _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                    acc_scr, scale=scale, block_k=block_k, num_splits=nk,
                    ks_ref=ks_ref, vs_ref=vs_ref)

    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, sp: (bh,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda bh, sp: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, sp: (bh, sp, 0)),
            pl.BlockSpec((1, block_k), lambda bh, sp: (bh, sp)),
            pl.BlockSpec((1, block_k, hd), lambda bh, sp: (bh, sp, 0)),
            pl.BlockSpec((1, block_k), lambda bh, sp: (bh, sp)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, sp: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qr, kr, ksr, vr, vsr)
    return out.reshape(B, H, hd)


def _paged_specs(P, page, KVH, G, hd, *, scales: bool):
    """BlockSpecs for the paged pools: the block-table scalar-prefetch ref
    feeds each index_map, translating (sequence bh, logical page j) to the
    PHYSICAL page the DMA streams. Sentinel entries clamp to P-1 — they
    only occur past the valid length, where ``pl.when`` skips the split
    anyway."""
    def page_idx(bh, j, tab):
        return (jnp.minimum(tab[bh // KVH, j], P - 1), bh % KVH, 0, 0)

    def scale_idx(bh, j, tab):
        return (jnp.minimum(tab[bh // KVH, j], P - 1), bh % KVH, 0)

    specs = [
        pl.BlockSpec((1,), lambda bh, j, tab: (bh,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, G, hd), lambda bh, j, tab: (bh, 0, 0)),
        pl.BlockSpec((1, 1, page, hd), page_idx),
        pl.BlockSpec((1, 1, page, hd), page_idx),
    ]
    if scales:
        specs.insert(3, pl.BlockSpec((1, 1, page), scale_idx))
        specs.append(pl.BlockSpec((1, 1, page), scale_idx))
    return specs


def _paged_dec_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                      m_scr, l_scr, acc_scr, *, scale: float, page: int,
                      num_pages_logical: int, ks_ref=None, vs_ref=None):
    """One (bh, logical-page) grid step. k/v_ref: [1, 1, page, hd] — the
    physical page the index_map resolved through the block table."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    k_start = j * page

    @pl.when(k_start < length)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)               # [page, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        if ks_ref is not None:
            k = k * ks_ref[0, 0][:, None]                 # fused dequant
            v = v * vs_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, page]
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))

    @pl.when(j == num_pages_logical - 1)
    def _fin():
        o_ref[0, ...] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, table, lengths, *,
                           interpret: bool = True,
                           k_scale=None, v_scale=None):
    """Single-token attention over a PAGED KV cache.

    q: [B, H, hd]; k/v_pool: [P, page, KVH, hd]; table: [B, maxP] int32
    block table (sentinel ``P`` = unmapped); lengths: [B] valid tokens.
    Optional ``k_scale``/``v_scale`` [P, page, KVH] turn on the fused
    int8-dequant path. Returns [B, H, hd] in q.dtype.
    """
    B, H, hd = q.shape
    P, page, KVH = k_pool.shape[:3]
    nP = table.shape[1]
    assert H % KVH == 0, (H, KVH)
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    int8 = k_scale is not None

    qr = q.reshape(B, KVH, G, hd).reshape(B * KVH, G, hd)
    kr = k_pool.transpose(0, 2, 1, 3)                  # [P, KVH, page, hd]
    vr = v_pool.transpose(0, 2, 1, 3)
    lens = jnp.repeat(lengths.astype(jnp.int32), KVH)  # [B*KVH]

    if int8:
        ksr = k_scale.transpose(0, 2, 1)               # [P, KVH, page]
        vsr = v_scale.transpose(0, 2, 1)

        def kernel(tab_ref, len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                   o_ref, m_scr, l_scr, acc_scr):
            _paged_dec_kernel(tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                              m_scr, l_scr, acc_scr, scale=scale, page=page,
                              num_pages_logical=nP, ks_ref=ks_ref,
                              vs_ref=vs_ref)
        args = (table.astype(jnp.int32), lens, qr, kr, ksr, vr, vsr)
    else:
        kernel = functools.partial(_paged_dec_kernel, scale=scale,
                                   page=page, num_pages_logical=nP)
        args = (table.astype(jnp.int32), lens, qr, kr, vr)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * KVH, nP),
        in_specs=_paged_specs(P, page, KVH, G, hd, scales=int8),
        out_specs=pl.BlockSpec((1, G, hd), lambda bh, j, tab: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * KVH, G, hd), q.dtype),
        interpret=interpret,
    )(*args)
    return out.reshape(B, H, hd)
