"""Pallas TPU grouped (expert) matmul kernel — MoE FFN hot spot.

phi3.5-moe and deepseek-v2 spend most of their FLOPs in per-expert FFNs
applied to capacity-bucketed token groups (``moe.py`` produces xe of shape
[E, C, D]). A plain batched einsum forces XLA to treat E as a leading
batch dim with one fat matmul per expert; this kernel instead tiles each
expert's GEMM for the MXU and lets unused capacity tiles skip work:

  * grid = (E, C/bc, F/bf, D/bd) with the contraction dim innermost
    (sequential) — partials accumulate in a [bc, bf] fp32 VMEM scratch,
    written once per (e, c, f) tile;
  * block sizes (bc, bd, bf) = (128, 512, 128): MXU-aligned 128-multiples;
    the 512-deep contraction slab amortises the accumulate loop while
    keeping x/w tiles at 128·512·2B = 128 KB each — well inside VMEM with
    double buffering;
  * tiles whose token rows are entirely padding (beyond the group's fill
    count) skip both DMA-compute and the writeback via ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 128
DEFAULT_BLOCK_D = 512
DEFAULT_BLOCK_F = 128


def _gmm_kernel(fill_ref, x_ref, w_ref, o_ref, acc_scr, *, block_c: int,
                num_d_blocks: int):
    """One (e, c, f, d) grid step. x_ref: [1,bc,bd]; w_ref: [1,bd,bf]."""
    cb = pl.program_id(1)
    db = pl.program_id(3)
    fill = fill_ref[0]                       # valid rows in this expert group
    live = cb * block_c < fill               # any non-padding row in the tile?

    @pl.when(db == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(live)
    def _step():
        x = x_ref[0].astype(jnp.float32)
        w = w_ref[0].astype(jnp.float32)
        acc_scr[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())))

    @pl.when(db == num_d_blocks - 1)
    def _fin():
        o_ref[0, ...] = acc_scr[...].astype(o_ref.dtype)


def expert_matmul(xe, w, fill=None, *, block_c: int = DEFAULT_BLOCK_C,
                  block_d: int = DEFAULT_BLOCK_D,
                  block_f: int = DEFAULT_BLOCK_F,
                  interpret: bool = True):
    """Capacity-bucketed expert GEMM. xe: [E, C, D]; w: [E, D, F].

    ``fill``: [E] int32 — valid rows per expert (defaults to C). Rows at or
    beyond ``fill`` produce zeros (padding tiles are skipped entirely).
    Returns [E, C, F] in xe.dtype with fp32 accumulation.
    """
    E, C, D = xe.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_d = min(block_d, D)
    block_f = min(block_f, F)
    assert C % block_c == 0 and D % block_d == 0 and F % block_f == 0, \
        (C, D, F, block_c, block_d, block_f)
    if fill is None:
        fill = jnp.full((E,), C, jnp.int32)
    nc, nd, nf = C // block_c, D // block_d, F // block_f

    kernel = functools.partial(_gmm_kernel, block_c=block_c, num_d_blocks=nd)
    out = pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1,), lambda e, c, f, d: (e,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, block_c, block_d), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, block_d, block_f), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), xe.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(fill.astype(jnp.int32), xe, w)
    # zero padding rows (skipped tiles may hold stale garbage on real HW;
    # in interpret mode they are zeros already — mask for both).
    row = jnp.arange(C)[None, :, None]
    return jnp.where(row < fill[:, None, None], out, 0).astype(xe.dtype)
