"""Pallas TPU chunked-WKV6 kernel (RWKV6 recurrence — long_500k hot spot).

rwkv6-1.6b is the arch that *runs* the long_500k cell, and its cost is the
WKV recurrence. The token-by-token form is a length-S serial chain; the
chunked linear-attention form turns it into MXU work:

  intra-chunk:  s = (r·e^{c_prev}) (k·e^{-c})ᵀ  (strictly lower)  → 2 GEMMs
  inter-chunk:  out += (r·e^{c_prev}) S_prev                      → 1 GEMM
  state carry:  S ← e^{c_last} ⊙ S + (k·e^{c_last - c})ᵀ v        → 1 GEMM

TPU-native layout: grid = (batch x head, num_chunks) with the chunk axis
sequential — the [hd, hd] state lives in VMEM scratch across chunks (never
round-trips to HBM), which is exactly the property that makes the decode
path O(1) in sequence. Chunk = 64 tokens balances the O(C²) intra-chunk
score tile against per-chunk GEMM efficiency; all tiles ([C, hd], [hd, hd],
[C, C]) are ≤ 64·64·4B = 16 KB — trivially VMEM-resident.

The per-token log-decay is assumed pre-clamped (rwkv6.py clamps to
[-1.5, 0)), so exp(±cumsum) stays in fp32 range for C = 64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                 s_scr, *, num_chunks: int):
    """One (bh, chunk) grid step. r/k/v/w_ref: [1, C, hd]; u/s0: per-bh."""
    ch = pl.program_id(1)

    @pl.when(ch == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # [C, hd]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)          # log decay, <= 0
    u = u_ref[0].astype(jnp.float32)          # [1, hd] bonus
    C = r.shape[0]

    c = jnp.cumsum(w, axis=0)                 # inclusive log-decay cumsum
    c_prev = c - w
    A = r * jnp.exp(c_prev)                   # decay-to-chunk-start queries
    Bm = k * jnp.exp(-c)                      # inverse-decayed keys
    s = jax.lax.dot_general(A, Bm, (((1,), (1,)), ((), ())))   # [C, C]
    rows = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    s = jnp.where(rows > cols, s, 0.0)        # strictly causal (j < t)
    intra = jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())))
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)          # bonus term
    intra = intra + diag * v
    inter = jax.lax.dot_general(A, s_scr[...], (((1,), (0,)), ((), ())))
    o_ref[0, ...] = (intra + inter).astype(o_ref.dtype)

    # state carry: S ← e^{c_last} ⊙ S + (k e^{c_last − c})ᵀ v
    c_last = c[-1:, :]                        # [1, hd]
    k_dec = k * jnp.exp(c_last - c)
    s_scr[...] = (jnp.exp(c_last).T * s_scr[...] +
                  jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ()))))

    @pl.when(ch == num_chunks - 1)
    def _fin():
        sT_ref[0, ...] = s_scr[...]


def wkv6(r, k, v, logw, u, state0, *, chunk: int = DEFAULT_CHUNK,
         interpret: bool = True):
    """Chunked WKV6. r/k/v/logw: [B, S, H, hd]; u: [H, hd];
    state0: [B, H, hd, hd]. Returns (out [B,S,H,hd] fp32, state fp32).
    """
    B, S, H, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nch = S // chunk

    def fold(a):  # [B,S,H,hd] -> [B*H, S, hd]
        return a.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    rr, kk, vv, ww = fold(r), fold(k), fold(v), fold(logw)
    uu = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    s0 = state0.reshape(B * H, hd, hd)

    kernel = functools.partial(_wkv6_kernel, num_chunks=nch)
    out, state = pl.pallas_call(
        kernel,
        grid=(B * H, nch),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, 1, hd), lambda bh, ch: (bh, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, ch: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, ch: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, uu, s0)

    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return out, state.reshape(B, H, hd, hd)
