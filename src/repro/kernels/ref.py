"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

Each function is the direct O(S²)/O(E·T) math with fp32 accumulation —
slow but obviously correct. The kernels must match these across the
shape/dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, prefix_len: int = 0):
    """q: [B,Sq,H,hd]; k/v: [B,Sk,KVH,hd] (GQA). Full S×S softmax in fp32."""
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if causal:
        q_pos = jnp.arange(Sq)[:, None]
        k_pos = jnp.arange(Sk)[None, :]
        mask = (q_pos >= k_pos) | (k_pos < prefix_len)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B,H,hd]; caches: [B,S,KVH,hd]; lengths: [B] valid tokens.

    One-token attention over the valid prefix of the cache.
    """
    B, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qg = (q.reshape(B, KVH, G, hd) / math.sqrt(hd)).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def paged_gather_ref(pool, table):
    """Materialise a paged pool ([P, page, ...]) as dense per-sequence rows
    via the block table ([B, maxP] int32, sentinel >= P clamped — the junk
    it gathers sits past each row's valid length and is masked by the
    caller). Returns [B, maxP*page, ...]."""
    P = pool.shape[0]
    v = pool[jnp.clip(table, 0, P - 1)]          # [B, maxP, page, ...]
    B, nP, page = v.shape[:3]
    return v.reshape(B, nP * page, *pool.shape[2:])


def paged_decode_attention_ref(q, k_pool, v_pool, table, lengths):
    """Paged twin of ``decode_attention_ref``: gather the pages dense,
    attend over the valid prefix."""
    return decode_attention_ref(q, paged_gather_ref(k_pool, table),
                                paged_gather_ref(v_pool, table), lengths)


def paged_extend_attention_ref(q, k_pool, v_pool, k_new, v_new, table, pos):
    """Chunked prefill continued from a paged cache, dense math.

    q: [B,C,H,hd] at per-row offsets ``pos`` [B]; pools [P,page,KVH,hd];
    k/v_new: [B,C,KVH,hd] (the chunk's own K/V). Row i of the chunk sees
    cache positions < pos[b] plus chunk columns <= i.
    """
    B, C, H, hd = q.shape
    kc = paged_gather_ref(k_pool, table).astype(jnp.float32)
    vc = paged_gather_ref(v_pool, table).astype(jnp.float32)
    S = kc.shape[1]
    KVH = kc.shape[2]
    G = H // KVH
    qg = (q.reshape(B, C, KVH, G, hd) / math.sqrt(hd)).astype(jnp.float32)
    s_c = jnp.einsum("bikgd,bskd->bkgis", qg, kc)
    s_c = jnp.where((jnp.arange(S)[None, :] < pos[:, None])
                    [:, None, None, None, :], s_c, -1e30)
    s_n = jnp.einsum("bikgd,bjkd->bkgij", qg, k_new.astype(jnp.float32))
    tri = jnp.arange(C)[None, :] <= jnp.arange(C)[:, None]      # [i, j]
    s_n = jnp.where(tri[None, None, None], s_n, -1e30)
    p = jax.nn.softmax(jnp.concatenate([s_c, s_n], axis=-1), axis=-1)
    o = (jnp.einsum("bkgis,bskd->bkgid", p[..., :S], vc)
         + jnp.einsum("bkgij,bjkd->bkgid", p[..., S:],
                      v_new.astype(jnp.float32)))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, C, H, hd).astype(q.dtype)


def grouped_matmul_ref(x, w, group_sizes):
    """x: [T, D]; w: [E, D, F]; group_sizes: [E] with sum == T.

    Rows of ``x`` are laid out group-contiguously (tokens of expert e are
    rows offset[e] .. offset[e]+group_sizes[e]); row t is multiplied by its
    group's weight matrix. Returns [T, F] in x.dtype (fp32 accumulation).
    """
    T, D = x.shape
    E, _, F = w.shape
    offsets = jnp.cumsum(group_sizes) - group_sizes
    gid = jnp.sum(jnp.arange(T)[:, None] >= offsets[None, :], axis=1) - 1
    gid = jnp.clip(gid, 0, E - 1)
    wt = w[gid]                                    # [T, D, F]
    y = jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                   wt.astype(jnp.float32))
    return y.astype(x.dtype)


def wkv6_ref(r, k, v, logw, u, state0):
    """Token-by-token WKV6 recurrence (the definitional form).

    r/k/v/logw: [B,S,H,hd]; u: [H,hd]; state0: [B,H,hd,hd].
        S_t   = diag(w_t) S_{t-1} + k_t v_t^T
        out_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    Returns (out [B,S,H,hd] fp32, state [B,H,hd,hd] fp32).
    """
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, logw))
    u = u.astype(f32)

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs                     # [B,H,hd]
        rk_u = jnp.einsum("bhd,bhd->bh", r_t * u[None], k_t)
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S) + rk_u[..., None] * v_t
        S = jnp.exp(w_t)[..., None] * S + jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        return S, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, out = jax.lax.scan(step, state0.astype(f32), xs)
    return jnp.moveaxis(out, 0, 1), state
