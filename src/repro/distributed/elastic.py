"""Elastic re-meshing and failure handling (1000+-node posture).

Two failure domains, two mechanisms:

  * **cross-site** (a wind site browns out / fibre cut): Heron's own job —
    ``HeronRouter.mark_site_down`` re-plans the fleet without the site.
    That is the paper's K1 story; nothing here.

  * **intra-site** (a pod or a data-parallel slice of the serving/training
    mesh dies): ``shrink_mesh`` drops the failed slice and returns the new
    ParallelConfig; ``reshard_tree`` device_puts a (restored) pytree onto
    the surviving mesh. Training restarts from the latest atomic
    checkpoint; serving replays in-flight requests (engine slots are
    request-scoped, so replay == resubmit).

The mesh math is plain: losing a pod on (pod=2, data=16, model=16) yields
(16, 16); losing a data slice yields (15, 16) — model-axis groups are
never split because TP shards are co-located in a pod (ICI domain), which
is why the survivable axes are exactly the pure-DP ones.

``StragglerTracker`` is the router-level mitigation: per-site EWMA of
service latency, deweighted in WRR when slower than ``threshold`` x fleet
median (used by HeronRouter.observe_latency).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import ParallelConfig


def shrink_mesh(parallel: ParallelConfig, *, lost_axis: str,
                lost_index: int) -> ParallelConfig:
    """Drop slice ``lost_index`` of ``lost_axis`` from the mesh.

    Only pure data-parallel axes are survivable (model-axis loss means the
    TP group is gone — that replica restarts from checkpoint elsewhere).
    """
    mesh = parallel.mesh
    assert mesh is not None, "no mesh to shrink"
    if lost_axis not in parallel.data_axes:
        raise ValueError(
            f"axis {lost_axis!r} is not a pure-DP axis; a TP-group loss "
            "is handled by full replica restart, not elastic shrink")
    axis_pos = mesh.axis_names.index(lost_axis)
    devs = np.moveaxis(mesh.devices, axis_pos, 0)
    keep = [i for i in range(devs.shape[0]) if i != lost_index]
    if not keep:
        raise ValueError("cannot shrink to zero slices")
    new_devs = np.moveaxis(devs[keep], 0, axis_pos)
    new_mesh = Mesh(new_devs, mesh.axis_names)
    return replace(parallel, mesh=new_mesh)


def reshard_tree(tree, parallel: ParallelConfig, specs):
    """device_put every leaf onto ``parallel.mesh`` under ``specs``.

    ``specs``: pytree of PartitionSpec (or None) matching ``tree`` — the
    restore path after an elastic shrink (checkpoint → new mesh).
    """
    mesh = parallel.mesh

    def put(x, spec):
        if mesh is None or spec is None:
            return x
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, specs)


@dataclass
class StragglerTracker:
    """Per-site latency EWMAs with fleet-median deweighting."""
    num_sites: int
    alpha: float = 0.2
    threshold: float = 2.0
    floor_weight: float = 0.25

    def __post_init__(self):
        self.ewma = np.zeros(self.num_sites)

    def observe(self, site: int, latency_s: float) -> None:
        e = self.ewma[site]
        self.ewma[site] = latency_s if e == 0 else \
            (1 - self.alpha) * e + self.alpha * latency_s

    def weights(self) -> np.ndarray:
        """Multiplicative WRR deweights in (0, 1]."""
        w = np.ones(self.num_sites)
        seen = self.ewma > 0
        if seen.sum() >= 2:
            fleet = np.median(self.ewma[seen])
            if fleet > 0:
                ratio = self.ewma / fleet
                slow = seen & (ratio > self.threshold)
                w[slow] = np.maximum(self.floor_weight,
                                     self.threshold / ratio[slow])
        return w
