"""Per-leaf PartitionSpecs for params / batches / caches.

The models annotate *activations* with logical axes (sharding.py); this
module assigns physical specs to *storage* — parameter leaves, input
batches, and decode caches — by tree-path rules with divisibility
fallback (a dim is only mapped to mesh axes that divide it; otherwise the
mapping is dropped, never an error — exactly the MaxText-style behaviour
that lets one rule table serve ten architectures).

Conventions (see DESIGN.md §5):
  * TP ("model" axis): attention q/o over heads, FFN hidden, vocab;
  * EP: MoE expert dim over "model"; per-expert FFN width over the data
    axes (weight-stationary storage sharding, gathered per layer);
  * FSDP (train only): remaining large dims of ≥2-D leaves over the data
    axes — params, grads and optimizer state all inherit it;
  * caches: batch over data axes; cache sequence over "model"
    (flash-decoding) or kv_heads over "model" (classic TP decode).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ParallelConfig

# leaf names whose LAST dim carries TP output features (col-parallel)
_COL_PARALLEL = {"wq", "w_gate", "w_up", "cm_k", "w_uq"}
# leaf names whose SECOND-TO-LAST dim carries TP input features (row-parallel)
_ROW_PARALLEL = {"wo", "w_down", "cm_v", "w_out"}
# kv projections: col-parallel only when kv_heads divide the model axis
_KV_PROJ = {"wk", "wv"}
# rwkv time-mix projections behave col-parallel (state heads over model)
_RWKV_COL = {"w_r", "w_k", "w_v", "w_g"}
_RWKV_ROW = {"w_o"}


def _fits(shape, dim: int, axes) -> bool:
    """Can dim ``dim`` of ``shape`` be sharded over mesh axes ``axes``?"""
    if not axes:
        return False
    n = int(np.prod([_AXIS_SIZES.get(a, 1) for a in axes]))
    return shape[dim] % n == 0 and shape[dim] >= n


_AXIS_SIZES: dict[str, int] = {}


def _path_names(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def _leaf_spec(cfg: ModelConfig, parallel: ParallelConfig, names: list[str],
               shape: tuple, *, fsdp: bool) -> P:
    m = parallel.model_axis
    d_axes = tuple(parallel.data_axes)
    msize = parallel.model_size()
    name = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    in_moe = "moe" in names
    nd = len(shape)
    spec: list = [None] * nd

    def try_set(dim: int, axes):
        ax = axes if isinstance(axes, tuple) else (axes,)
        if spec[dim] is None and _fits(shape, dim, ax):
            spec[dim] = axes

    heads_ok = cfg.num_heads % msize == 0
    kv_ok = cfg.num_kv_heads % msize == 0 and not cfg.use_mla

    if name == "embed" and nd == 2:
        try_set(0, m)                                 # vocab-parallel
        if spec[0] is None:
            try_set(1, m)
    elif name == "lm_head":
        try_set(1, m)
        if spec[1] is None:
            try_set(0, m)
    elif in_moe and name in ("w_gate", "w_up") and nd >= 3:
        if getattr(parallel, "moe_expert_axis", "model") == "data":
            # §Perf H8: [*, E, D, F] — experts over data, F TP over model
            try_set(nd - 3, d_axes)
            try_set(nd - 1, m)
        else:
            # [*, E, D, F]: experts over model, F over data (storage)
            try_set(nd - 3, m)
            if parallel.expert_tp_over_data:
                try_set(nd - 1, d_axes)
    elif in_moe and name == "w_down" and nd >= 3:
        if getattr(parallel, "moe_expert_axis", "model") == "data":
            try_set(nd - 3, d_axes)                   # [*, E, F, D]
            try_set(nd - 2, m)
        else:
            try_set(nd - 3, m)
            if parallel.expert_tp_over_data:
                try_set(nd - 2, d_axes)
    elif parent == "shared" and name in ("w_gate", "w_up"):
        try_set(nd - 1, m)                            # shared experts: TP
    elif parent == "shared" and name == "w_down":
        try_set(nd - 2, m)
    elif name == "router":
        pass                                          # tiny, replicated
    elif name in _KV_PROJ:
        if kv_ok:
            try_set(nd - 1, m)
    elif name in _COL_PARALLEL or name in _RWKV_COL:
        if name in ("wq", "w_uq") and not heads_ok:
            pass
        else:
            try_set(nd - 1, m)
    elif name in _ROW_PARALLEL or name in _RWKV_ROW:
        if name == "wo" and not heads_ok and not cfg.use_mla:
            pass
        else:
            try_set(nd - 2, m)
    elif name in ("w_uk", "w_uv"):                    # MLA up-projections
        if heads_ok:
            try_set(nd - 1, m)

    # FSDP: storage-shard the largest still-replicated dim over data axes
    # (skip leaves that already consumed a data axis, e.g. EP expert FFNs —
    # a mesh axis may appear at most once per spec)
    used = {a for s in spec if s is not None
            for a in (s if isinstance(s, tuple) else (s,))}
    if (fsdp and nd >= 2 and int(np.prod(shape)) >= 1 << 16
            and not used.intersection(d_axes)):
        order = sorted(range(nd), key=lambda i: -shape[i])
        for dim in order:
            if spec[dim] is None and _fits(shape, dim, d_axes):
                spec[dim] = d_axes
                break
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def param_specs(cfg: ModelConfig, parallel: ParallelConfig, params_shape, *,
                fsdp: bool = False):
    """Pytree of PartitionSpec matching ``params_shape`` (a specs pytree)."""
    global _AXIS_SIZES
    _AXIS_SIZES = parallel.axis_sizes
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = [
        _leaf_spec(cfg, parallel, _path_names(path), tuple(leaf.shape),
                   fsdp=fsdp)
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(cfg: ModelConfig, parallel: ParallelConfig,
                shape: ShapeConfig):
    """Input batch specs: batch over data axes (model axis for seq via the
    in-model constraints)."""
    global _AXIS_SIZES
    _AXIS_SIZES = parallel.axis_sizes
    d_axes = tuple(parallel.data_axes)
    dp = parallel.data_size()
    b_ax = d_axes if shape.global_batch % max(dp, 1) == 0 else ()
    b = b_ax if b_ax else None

    def spec_for(leaf_shape):
        return P(b, *([None] * (len(leaf_shape) - 1)))

    return spec_for


def cache_specs_tree(cfg: ModelConfig, parallel: ParallelConfig,
                     cache_shape, shape: ShapeConfig):
    """Decode-cache specs: batch over data; cache seq / kv-heads over model.

    For global_batch < data size (long_500k), the cache sequence dim is
    spread over (model + data) — flash-decoding across the whole mesh.
    """
    global _AXIS_SIZES
    _AXIS_SIZES = parallel.axis_sizes
    m = parallel.model_axis
    d_axes = tuple(parallel.data_axes)
    dp = parallel.data_size()
    batch_ok = shape.global_batch % max(dp, 1) == 0
    long_ctx = not batch_ok                     # e.g. B=1 long-context decode
    seq_axes = (m,) + d_axes if long_ctx else (m,)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    S = shape.seq_len

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = len(leaf.shape)
        if name == "pos" or nd <= 1:
            return P()
        spec: list = [None] * nd
        # stacked leaves are [L, B, ...]: batch at axis 1
        if batch_ok and leaf.shape[1] == shape.global_batch:
            spec[1] = d_axes
        # find the cache-sequence dim (== max_seq) and shard it over model
        for dim in range(2, nd):
            n = int(np.prod([_AXIS_SIZES.get(a, 1) for a in seq_axes]))
            if leaf.shape[dim] == S and leaf.shape[dim] % n == 0:
                spec[dim] = seq_axes if len(seq_axes) > 1 else m
                break
        while spec and spec[-1] is None:
            spec.pop()
        return P(*spec)

    specs = [leaf_spec(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def to_shardings(mesh, spec_tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))
