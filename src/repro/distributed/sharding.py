"""Logical-axis sharding rules (flax-linen style, dependency-free).

Models annotate activations/params with *logical* axis names via ``shard``;
a rules table (installed with ``axis_rules``) maps logical names to mesh
axes. With no rules installed (CPU smoke tests), ``shard`` is the identity.

Rules are built per (arch × shape-kind × mesh) by ``make_rules`` — e.g.
``kv_heads`` maps to the ``model`` axis only when the head count divides the
axis size, and decode-shape rules shard the KV-cache sequence dimension.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclass(frozen=True)
class ParallelConfig:
    """How a step function is distributed. ``None`` mesh == single process."""
    mesh: Optional[Mesh] = None
    data_axes: tuple[str, ...] = ("data",)      # pure-DP axes (incl. 'pod')
    model_axis: Optional[str] = "model"         # TP axis
    # beyond-paper knobs (see EXPERIMENTS.md §Perf)
    seq_shard_cache: bool = True                # flash-decoding over sharded cache
    expert_tp_over_data: bool = True            # weight-stationary EP + expert TP
    moe_expert_axis: str = "model"              # "model" | "data" (§Perf H8:
    # experts over data + expert-F TP over model — weights stay, tokens move)
    fsdp_params: bool = True                    # shard params over data axes (train)
    remat: bool = True

    @property
    def axis_sizes(self) -> dict[str, int]:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.data_axes:
            n *= self.axis_sizes.get(a, 1)
        return n

    def model_size(self) -> int:
        return self.axis_sizes.get(self.model_axis, 1) if self.mesh else 1


def current_rules() -> dict:
    return getattr(_STATE, "rules", None) or {}


@contextmanager
def axis_rules(rules: dict):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def logical_to_pspec(logical: Sequence[Optional[str]], rules: Optional[dict] = None) -> P:
    rules = current_rules() if rules is None else rules
    out = []
    for name in logical:
        axes = rules.get(name) if name else None
        out.append(axes if axes else None)
    # trim trailing Nones for tidy specs
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x, *logical: Optional[str]):
    """Annotate ``x`` with logical axes; identity when no rules installed."""
    rules = current_rules()
    if not rules:
        return x
    spec = logical_to_pspec(logical, rules)
    return jax.lax.with_sharding_constraint(x, spec)


def make_rules(cfg, parallel: ParallelConfig, kind: str) -> dict:
    """Logical→physical rules for an (arch, shape-kind) under ``parallel``.

    Logical names used across the model zoo:
      batch, seq (activations), heads, kv_heads, head_dim, embed, vocab,
      ffn, experts, expert_ffn, cache_seq, cache_kv_heads, fsdp (param dim0)
    """
    if parallel.mesh is None:
        return {}
    d_axes = tuple(parallel.data_axes)
    m = parallel.model_axis
    msize = parallel.model_size()
    kv_shardable = cfg.num_kv_heads % max(msize, 1) == 0 and not cfg.use_mla
    rules: dict[str, tuple] = {
        "batch": d_axes,
        "heads": (m,),
        "embed": None,
        "vocab": (m,),
        "ffn": (m,),
        "experts": (m,),
        "head_dim": None,
        "act_embed": None,
    }
    if parallel.expert_tp_over_data:
        rules["expert_ffn"] = d_axes  # within-expert TP over the data row
    if kind in ("train", "prefill"):
        # sequence-parallel residual stream between blocks
        rules["seq"] = (m,)
        rules["kv_heads"] = (m,) if kv_shardable else None
        rules["cache_seq"] = None
        rules["cache_kv_heads"] = (m,) if kv_shardable else None
    else:  # decode
        rules["seq"] = None
        if kv_shardable and not parallel.seq_shard_cache:
            rules["cache_kv_heads"] = (m,)
            rules["cache_seq"] = None
            rules["kv_heads"] = (m,)
            rules["dec_heads"] = (m,)
        else:
            # flash-decoding layout: cache sequence over the model axis
            # (GSPMD turns the softmax reductions into all-reduces);
            # q heads replicated — decode projections are negligible FLOPs.
            rules["cache_seq"] = (m,)
            rules["cache_kv_heads"] = None
            rules["kv_heads"] = None
            rules["dec_heads"] = None
            rules["heads"] = None
            rules["ffn"] = (m,)
            rules["vocab"] = (m,)
        if cfg.attn_free or cfg.family == "hybrid":
            # recurrent state: heads over model
            rules["state_heads"] = (m,)
    # batch==1 long-context: spread the cache over the data axes as well
    rules["cache_seq_long"] = tuple(a for a in ((rules.get("cache_seq") or ()) + d_axes))
    # FSDP storage for params (train only; serving re-materialises per layer)
    rules["fsdp"] = d_axes if (parallel.fsdp_params and kind == "train") else None
    return rules
