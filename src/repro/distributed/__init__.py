from repro.distributed.sharding import (
    axis_rules, shard, logical_to_pspec, current_rules, ParallelConfig,
)

__all__ = ["axis_rules", "shard", "logical_to_pspec", "current_rules", "ParallelConfig"]
