"""Mixture-of-Experts block: dense reference + expert-parallel shard_map path.

EP design (TPU-native, weight-stationary — DESIGN.md §5):
  * experts sharded over the ``model`` axis (E % model_size == 0);
  * each expert's FFN width stored sharded over the data axes (pure storage
    sharding — all-gathered one layer at a time inside the scan, ≤ ~0.5 GB
    transient even for deepseek-v2);
  * tokens (sharded over data×model) are bucketed by destination shard with
    a capacity bound and exchanged with ``all_to_all`` over ``model`` —
    tokens move, weights stay.
Capacity overflow drops tokens (standard GShard semantics); the router's
load-balance auxiliary loss keeps drop rates low in training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
try:                                   # jax >= 0.6: top-level, check_vma kwarg
    from jax import shard_map as _shard_map
    _REPLICATION_KW = "check_vma"
except ImportError:                    # older jax: experimental, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _REPLICATION_KW = "check_rep"


def shard_map(*args, check_vma=None, **kwargs):
    if check_vma is not None:
        kwargs[_REPLICATION_KW] = check_vma
    return _shard_map(*args, **kwargs)

from repro.distributed.sharding import ParallelConfig, shard
from repro.models.layers import dense_init

CAPACITY_FACTOR = 1.5


def moe_params(key, cfg, num_layers=None):
    d, f, E = cfg.d_model, cfg.resolved_moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    L = () if num_layers is None else (num_layers,)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "router": dense_init(ks[0], (*L, d, E), jnp.float32, d),
        "w_gate": dense_init(ks[1], (*L, E, d, f), dt, d),
        "w_up": dense_init(ks[2], (*L, E, d, f), dt, d),
        "w_down": dense_init(ks[3], (*L, E, f, d), dt, f),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], (*L, d, fs), dt, d),
            "w_up": dense_init(kss[1], (*L, d, fs), dt, d),
            "w_down": dense_init(kss[2], (*L, fs, d), dt, fs),
        }
    return p


def _expert_ffn(xe, w_gate, w_up, w_down):
    """xe: [E, C, D]; weights: [E, D, F] / [E, F, D]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _route(x32, router_w, k):
    gates = jax.nn.softmax(x32 @ router_w, axis=-1)          # [T, E]
    weights, idx = lax.top_k(gates, k)                        # [T, k]
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance auxiliary loss (Switch-style)
    E = gates.shape[-1]
    me = gates.mean(axis=0)
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = E * jnp.sum(me * ce)
    return weights, idx, aux


def moe_dense_ref(cfg, p, x):
    """Reference path (single device / smoke tests): computes all experts.

    Routing, top-k, combine, and the expert FFNs are all per-token (the
    aux loss crosses tokens but does not feed the output), so a row's
    result never depends on its batch-mates. The serving engine's batched
    admission and chunked ``extend_fn`` lean on this: MoE prefill chunks
    stay equivalent whether a request is prefilled alone or grouped. The
    EP paths trade this for capacity bounds (token dropping is
    batch-dependent) and are not used by the serving engine.
    """
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    weights, idx, aux = _route(xf.astype(jnp.float32), p["router"], cfg.experts_per_token)
    E = cfg.num_experts
    comb = jnp.zeros((T, E), jnp.float32)
    comb = comb.at[jnp.arange(T)[:, None], idx].add(weights)   # [T, E]
    ye = _expert_ffn(jnp.broadcast_to(xf, (E, T, D)).astype(x.dtype),
                     p["w_gate"], p["w_up"], p["w_down"])      # [E, T, D]
    y = jnp.einsum("te,etd->td", comb, ye.astype(jnp.float32)).astype(x.dtype)
    if cfg.num_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        y = y + h @ sp["w_down"]
    return y.reshape(B, S, D), aux


def _bucket_by(ids, values, num_buckets, capacity):
    """Scatter ``values`` [N, D] into [num_buckets, capacity, D] by ``ids``.

    Returns (buckets, slot, kept) — ``slot`` is the in-bucket position of each
    entry, ``kept`` masks capacity overflow.
    """
    N = ids.shape[0]
    onehot = (ids[:, None] == jnp.arange(num_buckets)[None]).astype(jnp.int32)
    slot = (jnp.cumsum(onehot, axis=0) - 1)
    slot = jnp.take_along_axis(slot, ids[:, None], axis=1)[:, 0]        # [N]
    kept = slot < capacity
    safe_ids = jnp.where(kept, ids, 0)
    safe_slot = jnp.where(kept, slot, capacity)                          # overflow row
    buckets = jnp.zeros((num_buckets, capacity + 1, *values.shape[1:]), values.dtype)
    buckets = buckets.at[safe_ids, safe_slot].set(values * kept.reshape(-1, *([1] * (values.ndim - 1))).astype(values.dtype))
    return buckets[:, :capacity], slot, kept


def moe_ep(cfg, p, x, parallel: ParallelConfig):
    """Expert-parallel MoE via shard_map (tokens all_to_all over ``model``)."""
    mesh = parallel.mesh
    m_axis = parallel.model_axis
    d_axes = tuple(parallel.data_axes)
    M = parallel.model_size()
    DP = parallel.data_size()
    E, k = cfg.num_experts, cfg.experts_per_token
    assert E % M == 0, f"experts {E} must divide model axis {M}"
    E_local = E // M
    B, S, D = x.shape
    # token sharding: batch over data axes, seq over model (SP) when possible
    seq_shardable = S % M == 0
    batch_shardable = B % DP == 0
    x_spec = P(d_axes if batch_shardable else None,
               m_axis if seq_shardable else None, None)
    T_local = (B // (DP if batch_shardable else 1)) * (S // (M if seq_shardable else 1))
    cap_send = max(8, int(T_local * k / M * CAPACITY_FACTOR))
    # expected tokens landing on a local expert = T_local*k/E_local (each
    # shard receives ~T_local*k across its E_local experts). Deriving from
    # cap_send would square the min-8 floor at small T (decode): a 12x
    # expert-GEMM inflation observed in the decode_32k dry-run (§Perf H2).
    cap_expert = max(8, int(T_local * k / E_local * CAPACITY_FACTOR ** 2))

    w_specs = {
        "router": P(None, None),
        "w_gate": P(m_axis, None, d_axes),
        "w_up": P(m_axis, None, d_axes),
        "w_down": P(m_axis, d_axes, None),
    }
    shared_spec = {"w_gate": P(None, m_axis), "w_up": P(None, m_axis),
                   "w_down": P(m_axis, None)}

    def local_fn(x_l, router_w, w_gate, w_up, w_down, shared):
        Bl, Sl, _ = x_l.shape
        xf = x_l.reshape(-1, D)
        Tl = xf.shape[0]
        weights, idx, aux = _route(xf.astype(jnp.float32), router_w, k)
        aux = lax.pmean(aux, (*d_axes, m_axis))
        # ---- dispatch: bucket (token, slot) pairs by destination shard ----
        flat_idx = idx.reshape(-1)                       # [Tl*k] expert id
        dest = flat_idx // E_local                       # destination model shard
        payload = jnp.concatenate(
            [jnp.repeat(xf, k, axis=0),
             (flat_idx % E_local)[:, None].astype(x_l.dtype),
             jnp.ones((Tl * k, 1), x_l.dtype)], axis=-1)
        send, slot, kept = _bucket_by(dest, payload, M, cap_send)
        recv = lax.all_to_all(send, m_axis, split_axis=0, concat_axis=0, tiled=False)
        # recv: [M, cap_send, D+2] — tokens other shards routed to my experts
        rflat = recv.reshape(M * cap_send, D + 2)
        r_x = rflat[:, :D]
        r_eid = jnp.round(rflat[:, D].astype(jnp.float32)).astype(jnp.int32)
        r_valid = rflat[:, D + 1].astype(jnp.float32) > 0.5
        r_eid = jnp.where(r_valid, r_eid, E_local)       # sentinel bucket
        xe_all, eslot, ekept = _bucket_by(r_eid, r_x, E_local + 1, cap_expert)
        xe = xe_all[:E_local]
        # ---- expert FFN (weights all-gathered over data: storage sharding) --
        wg = _gather_ffn(w_gate, d_axes, axis=2)
        wu = _gather_ffn(w_up, d_axes, axis=2)
        wd = _gather_ffn(w_down, d_axes, axis=1)
        ye = _expert_ffn(xe, wg, wu, wd)                 # [E_local, cap_expert, D]
        # ---- un-bucket back to recv order, return via all_to_all ----------
        safe_es = jnp.minimum(eslot, cap_expert - 1)
        y_r = ye[jnp.minimum(r_eid, E_local - 1), safe_es]
        y_r = y_r * (r_valid & ekept & (eslot < cap_expert))[:, None].astype(y_r.dtype)
        back = lax.all_to_all(y_r.reshape(M, cap_send, D), m_axis,
                              split_axis=0, concat_axis=0, tiled=False)
        # ---- combine at source ------------------------------------------
        safe_slot = jnp.minimum(slot, cap_send - 1)
        y_slots = back[dest, safe_slot]                  # [Tl*k, D]
        y_slots = y_slots * kept[:, None].astype(y_slots.dtype)
        w_flat = weights.reshape(-1)[:, None].astype(y_slots.dtype)
        y = (y_slots * w_flat).reshape(Tl, k, D).sum(axis=1)
        if shared is not None:
            # shared experts are *storage*-sharded over model; gather per layer
            # (tokens differ per model shard, so TP-psum here would be wrong)
            wg_s = lax.all_gather(shared["w_gate"], m_axis, axis=1, tiled=True)
            wu_s = lax.all_gather(shared["w_up"], m_axis, axis=1, tiled=True)
            wd_s = lax.all_gather(shared["w_down"], m_axis, axis=0, tiled=True)
            h = jax.nn.silu(xf @ wg_s) * (xf @ wu_s)
            y = y + (h @ wd_s).astype(jnp.float32)
        return y.reshape(Bl, Sl, D).astype(x_l.dtype), aux

    def _gather_ffn(w, axes, axis):
        for a in axes[::-1]:
            w = lax.all_gather(w, a, axis=axis, tiled=True)
        return w

    shared = p.get("shared")
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["w_gate"], w_specs["w_up"],
                  w_specs["w_down"],
                  {k_: shared_spec[k_] for k_ in shared} if shared is not None else None),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)


def moe_ep_over_data(cfg, p, x, parallel: ParallelConfig):
    """2-level EP (§Perf H8): experts sharded over the DATA axes, each
    expert's FFN width TP-sharded over MODEL.

    The baseline layout (experts over model, F storage-sharded over data)
    must all-gather every expert's F-shards each layer — 4.3 GB/device/step
    on dsv2 decode. Inverting the axes makes weights fully stationary:
    tokens all_to_all over data (MB-scale payloads), the F contraction
    psums over model (token-sized partials). Requires E % data == 0.
    """
    mesh = parallel.mesh
    m_axis = parallel.model_axis
    d_axes = tuple(parallel.data_axes)
    M = parallel.model_size()
    DP = parallel.data_size()
    E, k = cfg.num_experts, cfg.experts_per_token
    assert E % DP == 0, f"experts {E} must divide data axes {DP}"
    E_local = E // DP
    B, S, D = x.shape
    batch_shardable = B % DP == 0
    seq_shardable = S % DP == 0
    # tokens: sharded over data (batch if divisible, else seq), REPLICATED
    # over model — every model rank in a data column computes the same
    # routing and holds the same tokens (the F-TP requirement).
    x_spec = P(d_axes if batch_shardable else None,
               d_axes if (not batch_shardable and seq_shardable) else None,
               None)
    T_local = (B // (DP if batch_shardable else 1)) * (
        S // (DP if (not batch_shardable and seq_shardable) else 1))
    cap_send = max(8, int(T_local * k / DP * CAPACITY_FACTOR))
    cap_expert = max(8, int(T_local * k * CAPACITY_FACTOR ** 2 / E_local))

    w_specs = {
        "router": P(None, None),
        "w_gate": P(d_axes, None, m_axis),
        "w_up": P(d_axes, None, m_axis),
        "w_down": P(d_axes, m_axis, None),
    }
    shared_spec = {"w_gate": P(None, m_axis), "w_up": P(None, m_axis),
                   "w_down": P(m_axis, None)}
    d_name = d_axes if len(d_axes) > 1 else d_axes[0]

    def local_fn(x_l, router_w, w_gate, w_up, w_down, shared):
        Bl, Sl, _ = x_l.shape
        xf = x_l.reshape(-1, D)
        Tl = xf.shape[0]
        weights, idx, aux = _route(xf.astype(jnp.float32), router_w, k)
        aux = lax.pmean(aux, (*d_axes, m_axis))
        flat_idx = idx.reshape(-1)
        dest = flat_idx // E_local                 # destination DATA shard
        payload = jnp.concatenate(
            [jnp.repeat(xf, k, axis=0),
             (flat_idx % E_local)[:, None].astype(x_l.dtype),
             jnp.ones((Tl * k, 1), x_l.dtype)], axis=-1)
        send, slot, kept = _bucket_by(dest, payload, DP, cap_send)
        recv = lax.all_to_all(send, d_name, split_axis=0, concat_axis=0,
                              tiled=False)
        rflat = recv.reshape(DP * cap_send, D + 2)
        r_x = rflat[:, :D]
        r_eid = jnp.round(rflat[:, D].astype(jnp.float32)).astype(jnp.int32)
        r_valid = rflat[:, D + 1].astype(jnp.float32) > 0.5
        r_eid = jnp.where(r_valid, r_eid, E_local)
        xe_all, eslot, ekept = _bucket_by(r_eid, r_x, E_local + 1, cap_expert)
        xe = xe_all[:E_local]
        # expert FFN with F TP-sharded over model: local partials + psum
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
        h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
        y_part = jnp.einsum("ecf,efd->ecd", h, w_down)
        ye = lax.psum(y_part, m_axis)              # [E_local, cap, D] full
        safe_es = jnp.minimum(eslot, cap_expert - 1)
        y_r = ye[jnp.minimum(r_eid, E_local - 1), safe_es]
        y_r = y_r * (r_valid & ekept & (eslot < cap_expert))[:, None].astype(y_r.dtype)
        back = lax.all_to_all(y_r.reshape(DP, cap_send, D), d_name,
                              split_axis=0, concat_axis=0, tiled=False)
        safe_slot = jnp.minimum(slot, cap_send - 1)
        y_slots = back[dest, safe_slot]
        y_slots = y_slots * kept[:, None].astype(y_slots.dtype)
        w_flat = weights.reshape(-1)[:, None].astype(y_slots.dtype)
        y = (y_slots * w_flat).reshape(Tl, k, D).sum(axis=1)
        if shared is not None:
            # shared experts: clean TP over model (partials psum'd) — no
            # gather, unlike the baseline storage-sharded path
            hs = jax.nn.silu(xf @ shared["w_gate"]) * (xf @ shared["w_up"])
            y = y + lax.psum((hs @ shared["w_down"]).astype(jnp.float32),
                             m_axis)
        return y.reshape(Bl, Sl, D).astype(x_l.dtype), aux

    shared = p.get("shared")
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["w_gate"],
                  w_specs["w_up"], w_specs["w_down"],
                  {k_: shared_spec[k_] for k_ in shared}
                  if shared is not None else None),
        out_specs=(x_spec, P()),
        check_vma=False,
    )
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)


def moe_block(cfg, p, x, parallel: Optional[ParallelConfig]):
    if parallel is not None and parallel.mesh is not None:
        if (parallel.moe_expert_axis == "data"
                and cfg.num_experts % max(parallel.data_size(), 1) == 0):
            return moe_ep_over_data(cfg, p, x, parallel)
        if cfg.num_experts >= parallel.model_size():
            return moe_ep(cfg, p, x, parallel)
    return moe_dense_ref(cfg, p, x)
