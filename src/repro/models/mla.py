"""Multi-head Latent Attention (DeepSeek-V2).

Prefill/train use the *expanded* form (latent → per-head K/V, flash path);
decode uses the *absorbed* form: scores are computed directly against the
compressed latent cache (kv_lora + rope dims per token), so the decode
memory term streams ~576 B/token instead of 128 heads × 256 dims.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models.layers import (
    dense_init, rmsnorm, rope_table, apply_rope, attend, _cache_insert,
    _cache_insert_chunk,
)


def mla_params(key, cfg, num_layers=None):
    d = cfg.d_model
    H = cfg.num_heads
    nope, rope_d, v_hd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qk_hd = nope + rope_d
    ks = jax.random.split(key, 9)
    L = () if num_layers is None else (num_layers,)
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_dq": dense_init(ks[0], (*L, d, cfg.q_lora_rank), dt, d),
        "q_ln": jnp.ones((*L, cfg.q_lora_rank), dt),
        "w_uq": dense_init(ks[1], (*L, cfg.q_lora_rank, H * qk_hd), dt, cfg.q_lora_rank),
        "w_dkv": dense_init(ks[2], (*L, d, cfg.kv_lora_rank), dt, d),
        "kv_ln": jnp.ones((*L, cfg.kv_lora_rank), dt),
        "w_kr": dense_init(ks[3], (*L, d, rope_d), dt, d),
        "w_uk": dense_init(ks[4], (*L, cfg.kv_lora_rank, H * nope), dt, cfg.kv_lora_rank),
        "w_uv": dense_init(ks[5], (*L, cfg.kv_lora_rank, H * v_hd), dt, cfg.kv_lora_rank),
        "wo": dense_init(ks[6], (*L, H * v_hd, d), dt, H * v_hd),
    }


def _project_q(cfg, p, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = rmsnorm({"scale": p["q_ln"]}, x @ p["w_dq"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, S, H, nope + rope_d)
    q = shard(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_table(positions, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _project_kv_latent(cfg, p, x, positions):
    ckv = rmsnorm({"scale": p["kv_ln"]}, x @ p["w_dkv"], cfg.norm_eps)
    kr = x @ p["w_kr"]  # [B, S, rope_d], shared across heads
    cos, sin = rope_table(positions, cfg.qk_rope_head_dim, cfg.rope_theta)
    kr = apply_rope(kr[:, :, None, :], cos, sin)[:, :, 0, :]
    return ckv, kr


def mla_prefill(cfg, p, x, positions, want_cache: bool):
    """Expanded-form attention; optionally returns the latent cache."""
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope_d, v_hd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _project_q(cfg, p, x, positions)
    ckv, kr = _project_kv_latent(cfg, p, x, positions)
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, nope)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, v_hd)
    k_nope = shard(k_nope, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, rope_d))], axis=-1)
    o = attend(q, k, v, causal=True)
    out = o.reshape(B, S, H * v_hd) @ p["wo"]
    cache = {"ckv": ckv, "kr": kr} if want_cache else None
    return shard(out, "batch", "seq", None), cache


def mla_extend(cfg, p, x, cache, pos):
    """Absorbed-form chunk continuation against the latent cache.

    x: [B,C,D]; cache: {"ckv": [B,S,kv_lora], "kr": [B,S,rope_d]};
    pos: [B] valid cached tokens. Chunk query j attends to the cached
    prefix plus chunk positions <= j; the chunk's latents are scattered in
    at pos..pos+C-1. This is ``mla_decode`` generalised to C tokens — the
    serving engine's prompt-tail path (O(log S) chunks instead of S serial
    decodes).
    """
    B, C, _ = x.shape
    H = cfg.num_heads
    nope, rope_d, v_hd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(nope + rope_d)
    positions = pos[:, None] + jnp.arange(C)[None, :]
    q_nope, q_rope = _project_q(cfg, p, x, positions)           # [B,C,H,*]
    ckv_new, kr_new = _project_kv_latent(cfg, p, x, positions)  # [B,C,lora/rope]

    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, H, nope)
    q_lat = jnp.einsum("bchn,lhn->bchl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))                # [B,C,H,kv_lora]

    ckv_c = shard(cache["ckv"], "batch", "cache_seq", None)
    kr_c = shard(cache["kr"], "batch", "cache_seq", None)
    S = ckv_c.shape[1]
    s = jnp.einsum("bchl,bsl->bhcs", q_lat, ckv_c.astype(jnp.float32))
    s = s + jnp.einsum("bchr,bsr->bhcs", q_rope.astype(jnp.float32),
                       kr_c.astype(jnp.float32))
    s = s * scale
    valid = jnp.arange(S)[None, :] < pos[:, None]               # [B,S]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    s_new = jnp.einsum("bchl,bjl->bhcj", q_lat, ckv_new.astype(jnp.float32))
    s_new = s_new + jnp.einsum("bchr,bjr->bhcj", q_rope.astype(jnp.float32),
                               kr_new.astype(jnp.float32))
    s_new = s_new * scale
    tri = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]
    s_new = jnp.where(tri[None, None], s_new, -1e30)
    m = jnp.maximum(s.max(-1), s_new.max(-1))                   # [B,H,C]
    pr = jnp.exp(s - m[..., None])
    pr_new = jnp.exp(s_new - m[..., None])
    l = pr.sum(-1) + pr_new.sum(-1)
    out_lat = jnp.einsum("bhcs,bsl->bhcl", pr, ckv_c.astype(jnp.float32))
    out_lat = out_lat + jnp.einsum("bhcj,bjl->bhcl", pr_new,
                                   ckv_new.astype(jnp.float32))
    out_lat = out_lat / l[..., None]
    w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, H, v_hd)
    o = jnp.einsum("bhcl,lhv->bchv", out_lat, w_uv.astype(jnp.float32))
    out = o.reshape(B, C, H * v_hd).astype(x.dtype) @ p["wo"]
    new_cache = {
        "ckv": shard(_cache_insert_chunk(ckv_c, ckv_new, pos),
                     "batch", "cache_seq", None),
        "kr": shard(_cache_insert_chunk(kr_c, kr_new, pos),
                    "batch", "cache_seq", None),
    }
    return shard(out, "batch", "seq", None), new_cache


def mla_decode(cfg, p, x, cache, pos):
    """Absorbed-form decode against the latent cache.

    cache: {"ckv": [B,S,kv_lora], "kr": [B,S,rope_d]}; pos: [B] valid length.
    """
    B, _, _ = x.shape
    H = cfg.num_heads
    nope, rope_d, v_hd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(nope + rope_d)
    q_nope, q_rope = _project_q(cfg, p, x, pos[:, None])
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]            # [B,H,*]
    ckv_new, kr_new = _project_kv_latent(cfg, p, x, pos[:, None])
    ckv_new, kr_new = ckv_new[:, 0], kr_new[:, 0]

    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, H, nope)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))            # [B,H,kv_lora]

    ckv_c = shard(cache["ckv"], "batch", "cache_seq", None)
    kr_c = shard(cache["kr"], "batch", "cache_seq", None)
    S = ckv_c.shape[1]
    s = jnp.einsum("bhl,bsl->bhs", q_lat, ckv_c.astype(jnp.float32))
    s = s + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                       kr_c.astype(jnp.float32))
    s = s * scale
    valid = jnp.arange(S)[None, :] < pos[:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    # current token's own K/V
    s_new = (jnp.einsum("bhl,bl->bh", q_lat, ckv_new.astype(jnp.float32))
             + jnp.einsum("bhr,br->bh", q_rope.astype(jnp.float32),
                          kr_new.astype(jnp.float32))) * scale
    m = jnp.maximum(s.max(-1), s_new)
    pr = jnp.exp(s - m[..., None])
    pr_new = jnp.exp(s_new - m)
    l = pr.sum(-1) + pr_new
    out_lat = jnp.einsum("bhs,bsl->bhl", pr, ckv_c.astype(jnp.float32))
    out_lat = out_lat + pr_new[..., None] * ckv_new.astype(jnp.float32)[:, None, :]
    out_lat = out_lat / l[..., None]
    w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, H, v_hd)
    o = jnp.einsum("bhl,lhv->bhv", out_lat, w_uv.astype(jnp.float32))
    out = o.reshape(B, 1, H * v_hd).astype(x.dtype) @ p["wo"]
    new_cache = {
        "ckv": shard(_cache_insert(ckv_c, ckv_new, pos), "batch", "cache_seq", None),
        "kr": shard(_cache_insert(kr_c, kr_new, pos), "batch", "cache_seq", None),
    }
    return shard(out, "batch", None, None), new_cache
