"""RWKV6 (Finch) — attention-free time-mix with data-dependent decay.

Recurrence (per head, state ``S`` in R^{hd x hd}):
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T
    out_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

Train/prefill use a *chunked* linear-attention form: intra-chunk pairwise
decays become two MXU matmuls; inter-chunk state is carried with
``lax.scan``. Decode is the O(1) recurrent update (this is why rwkv6 runs
the ``long_500k`` cell: no KV cache, constant state).

Numerical note: per-token log-decay is clamped to [LOG_W_MIN, LOG_W_MAX]
so that the intra-chunk ``exp(-cumsum)`` factor stays inside fp32 range for
CHUNK tokens (|LOG_W_MIN|·CHUNK < 88). This bounds how fast a channel can
forget within one chunk — a documented deviation from unclamped Finch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models.layers import dense_init, rmsnorm, scan_chunk_for

CHUNK = 32
LOG_W_MIN = -1.5   # per-token; CHUNK * 1.5 = 48 << 88 (fp32 exp overflow)
LOG_W_MAX = -1e-6

DDLERP_RANK = 32   # low-rank data-dependence of the decay (Finch's token-shift LoRA)


def rwkv6_params(key, cfg, num_layers=None):
    d = cfg.d_model
    H = cfg.num_heads
    hd = cfg.resolved_head_dim
    assert H * hd == d, "rwkv6 time-mix assumes heads*head_dim == d_model"
    ks = jax.random.split(key, 16)
    L = () if num_layers is None else (num_layers,)
    dt = jnp.dtype(cfg.dtype)
    r = DDLERP_RANK
    p = {
        # time-mix projections
        "w_r": dense_init(ks[0], (*L, d, d), dt, d),
        "w_k": dense_init(ks[1], (*L, d, d), dt, d),
        "w_v": dense_init(ks[2], (*L, d, d), dt, d),
        "w_g": dense_init(ks[3], (*L, d, d), dt, d),
        "w_o": dense_init(ks[4], (*L, d, d), dt, d),
        # static token-shift interpolation weights per stream
        "mu_r": jnp.full((*L, d), 0.5, dt),
        "mu_k": jnp.full((*L, d), 0.5, dt),
        "mu_v": jnp.full((*L, d), 0.5, dt),
        "mu_g": jnp.full((*L, d), 0.5, dt),
        "mu_w": jnp.full((*L, d), 0.5, dt),
        # data-dependent decay: LoRA on the shifted stream
        "w_decay_a": dense_init(ks[5], (*L, d, r), dt, d),
        "w_decay_b": dense_init(ks[6], (*L, r, d), dt, r),
        "decay_base": jnp.full((*L, d), -1.0, jnp.float32),  # w ~ exp(-softplus)
        "bonus_u": dense_init(ks[7], (*L, H, hd), jnp.float32, hd),
        "ln_x": jnp.ones((*L, d), dt),  # per-head group-norm scale on the wkv out
        # channel-mix
        "cm_k": dense_init(ks[8], (*L, d, cfg.d_ff), dt, d),
        "cm_v": dense_init(ks[9], (*L, cfg.d_ff, d), dt, cfg.d_ff),
        "cm_r": dense_init(ks[10], (*L, d, d), dt, d),
        "cm_mu_k": jnp.full((*L, d), 0.5, dt),
        "cm_mu_r": jnp.full((*L, d), 0.5, dt),
        # pre-norms
        "ln1": jnp.ones((*L, d), dt),
        "ln2": jnp.ones((*L, d), dt),
    }
    return p


def chunk_for(S: int) -> int:
    """WKV chunk for a segment of length S; ``rwkv6_block`` with state0
    from a prior segment is the exact sequential continuation."""
    return scan_chunk_for(S, CHUNK)


def _token_shift(x, prev):
    """x: [B,S,D]; prev: [B,D] (last token of the previous segment)."""
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _lerp(x, shifted, mu):
    return x + (shifted - x) * mu


def _log_decay(p, xw):
    """Data-dependent per-channel log decay in [LOG_W_MIN, LOG_W_MAX]."""
    lora = jnp.tanh(xw @ p["w_decay_a"]) @ p["w_decay_b"]
    raw = p["decay_base"].astype(jnp.float32) + lora.astype(jnp.float32)
    logw = -jax.nn.softplus(raw)          # <= 0
    return jnp.clip(logw, LOG_W_MIN, LOG_W_MAX)


def wkv6_chunked(r, k, v, logw, u, state0, chunk: int = CHUNK):
    """Chunked WKV6 scan.

    r/k/v: [B,S,H,hd]; logw: [B,S,H,hd]; u: [H,hd]; state0: [B,H,hd,hd].
    Returns (out [B,S,H,hd], state [B,H,hd,hd]). fp32 inside.
    """
    B, S, H, hd = r.shape
    assert S % chunk == 0, f"S={S} % chunk={chunk} != 0"
    n = S // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(B, n, chunk, H, hd)
    kc = k.astype(f32).reshape(B, n, chunk, H, hd)
    vc = v.astype(f32).reshape(B, n, chunk, H, hd)
    wc = logw.astype(f32).reshape(B, n, chunk, H, hd)
    # scan over chunks (time-major)
    rc, kc, vc, wc = (jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, wc))

    tri_lo = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)   # strictly lower

    def body(S0, xs):
        r_i, k_i, v_i, w_i = xs                       # [B,C,H,hd]
        c = jnp.cumsum(w_i, axis=1)                   # inclusive cumsum of log w
        c_prev = c - w_i                              # cumsum up to t-1
        A = r_i * jnp.exp(c_prev)                     # queries with decay-to-start
        Bm = k_i * jnp.exp(-c)                        # keys with inverse decay
        # intra-chunk scores: [B,H,C,C], strictly causal (j < t)
        s = jnp.einsum("bthd,bjhd->bhtj", A, Bm) * tri_lo[None, None]
        intra = jnp.einsum("bhtj,bjhd->bthd", s, v_i)
        # diagonal (current-token bonus u)
        diag = jnp.einsum("bthd,bthd->bth", r_i * u[None, None], k_i)
        intra = intra + diag[..., None] * v_i
        # inter-chunk: state contribution
        inter = jnp.einsum("bthk,bhkv->bthv", A, S0)
        # state update: S_C = diag(exp(c_last)) S0 + sum_j (k_j exp(c_last - c_j)) v_j^T
        c_last = c[:, -1:, :, :]                      # [B,1,H,hd]
        k_dec = k_i * jnp.exp(c_last - c)
        S1 = jnp.exp(c_last[:, 0])[..., None] * S0 + jnp.einsum(
            "bthk,bthv->bhkv", k_dec, v_i)
        return S1, intra + inter

    state, out = lax.scan(body, state0.astype(f32), (rc, kc, vc, wc))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)
    return out, state


def wkv6_decode(r, k, v, logw, u, state):
    """Single-token recurrent step. r/k/v/logw: [B,H,hd]; state: [B,H,hd,hd]."""
    f32 = jnp.float32
    r, k, v, w = (a.astype(f32) for a in (r, k, v, logw))
    rk_u = jnp.einsum("bhd,bhd->bh", r * u[None], k)
    out = jnp.einsum("bhk,bhkv->bhv", r, state) + rk_u[..., None] * v
    new_state = jnp.exp(w)[..., None] * state + jnp.einsum("bhk,bhv->bhkv", k, v)
    return out, new_state


def _group_norm(x, scale, eps):
    """Per-head RMS norm of the wkv output. x: [B,S,H,hd]; scale: [D]."""
    B, S, H, hd = x.shape
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps)
    return (y.reshape(B, S, H * hd) * scale.astype(jnp.float32))


def time_mix(cfg, p, x, tm_state, wkv_state):
    """RWKV6 time-mix block.

    x: [B,S,D]; tm_state: [B,D] last-token carry; wkv_state: [B,H,hd,hd].
    Returns (out [B,S,D], new_tm_state, new_wkv_state).
    """
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    sx = _token_shift(x, tm_state)
    xr = _lerp(x, sx, p["mu_r"])
    xk = _lerp(x, sx, p["mu_k"])
    xv = _lerp(x, sx, p["mu_v"])
    xg = _lerp(x, sx, p["mu_g"])
    xw = _lerp(x, sx, p["mu_w"])
    r = shard((xr @ p["w_r"]).reshape(B, S, H, hd), "batch", None, "state_heads", None)
    k = shard((xk @ p["w_k"]).reshape(B, S, H, hd), "batch", None, "state_heads", None)
    v = shard((xv @ p["w_v"]).reshape(B, S, H, hd), "batch", None, "state_heads", None)
    g = jax.nn.silu(xg @ p["w_g"])
    logw = _log_decay(p, xw).reshape(B, S, H, hd)
    u = p["bonus_u"].astype(jnp.float32)

    if S == 1:
        out, new_wkv = wkv6_decode(r[:, 0], k[:, 0], v[:, 0], logw[:, 0], u, wkv_state)
        out = out[:, None]  # [B,1,H,hd]
    else:
        out, new_wkv = wkv6_chunked(r, k, v, logw, u, wkv_state, chunk=chunk_for(S))
    out = _group_norm(out, p["ln_x"], cfg.norm_eps).astype(x.dtype)
    out = (out * g) @ p["w_o"]
    return shard(out, "batch", "seq", None), x[:, -1, :], new_wkv


def channel_mix(cfg, p, x, cm_state):
    """RWKV squared-relu channel mix. cm_state: [B,D] last-token carry."""
    sx = _token_shift(x, cm_state)
    xk = _lerp(x, sx, p["cm_mu_k"])
    xr = _lerp(x, sx, p["cm_mu_r"])
    h = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    h = shard(h, "batch", None, "ffn")
    out = jax.nn.sigmoid(xr @ p["cm_r"]) * (h @ p["cm_v"])
    return shard(out, "batch", "seq", None), x[:, -1, :]


def rwkv6_state_init(cfg, batch: int):
    """Recurrent state pytree (replaces the KV cache for this family)."""
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    return {
        "wkv": jnp.zeros((cfg.num_layers, batch, H, hd, hd), jnp.float32),
        "tm": jnp.zeros((cfg.num_layers, batch, cfg.d_model), jnp.float32),
        "cm": jnp.zeros((cfg.num_layers, batch, cfg.d_model), jnp.float32),
    }


def rwkv6_block(cfg, p, x, state_slice):
    """One RWKV6 layer (pre-norm time-mix + channel-mix)."""
    tm_s, cm_s, wkv_s = state_slice["tm"], state_slice["cm"], state_slice["wkv"]
    h, new_tm, new_wkv = time_mix(cfg, p, rmsnorm({"scale": p["ln1"]}, x, cfg.norm_eps),
                                  tm_s, wkv_s)
    x = x + h
    h, new_cm = channel_mix(cfg, p, rmsnorm({"scale": p["ln2"]}, x, cfg.norm_eps), cm_s)
    x = x + h
    return x, {"tm": new_tm.astype(jnp.float32), "cm": new_cm.astype(jnp.float32),
               "wkv": new_wkv}
