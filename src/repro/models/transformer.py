"""Model assembly for the 10 assigned architectures.

One functional forward per family, a unified parameter tree layout, and the
three entry points every downstream layer consumes:

    loss_fn(params, batch)                     -> scalar loss      (train_4k)
    prefill_fn(params, inputs)                 -> (logits, cache)  (prefill_32k)
    decode_fn(params, inputs, cache)           -> (logits, cache)  (decode_32k/long_500k)

Layer stacks are scanned (``lax.scan`` over a leading L dim) so the HLO
stays compact at 60+ layers; remat wraps the scanned body for training.
Families:
  dense   llama3-8b, llama3.2-1b, qwen3-14b, deepseek-7b
  moe     phi3.5-moe (GQA+MoE), deepseek-v2 (MLA+MoE, 2 shared experts)
  ssm     rwkv6 (attention-free, recurrent state)
  hybrid  zamba2 (13 groups: shared-attn block w/ per-group LoRA + 6 Mamba2)
  encdec  seamless-m4t (bidirectional encoder over stubbed audio frames)
  vlm     paligemma (stubbed SigLIP patches as a bidirectional prefix)

Simplifications vs. the exact HF checkpoints (documented in DESIGN.md):
deepseek-v2 uses MoE in *all* layers (real: dense layer 0); zamba2 groups
its 81 layers as 13x(shared attn + 6 mamba) + 3 tail mamba layers.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelConfig, shard
from repro.models import layers as Lyr
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv6 as RWKV
from repro.models import mamba2 as M2
from repro.models.layers import rmsnorm, cross_entropy


# =====================================================================
# parameter construction
# =====================================================================
def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.dtype)
    p: dict[str, Any] = {"embed": Lyr.embedding_params(ks[0], cfg)}
    L = cfg.num_layers
    d = cfg.d_model

    if cfg.family == "ssm":
        p["layers"] = RWKV.rwkv6_params(ks[1], cfg, num_layers=L)
        p["final_norm"] = jnp.ones((d,), dt)
        return p

    if cfg.family == "hybrid":
        groups, per, tail = _zamba_grouping(cfg)
        p["mamba"] = M2.mamba2_params(ks[1], cfg, num_layers=groups * per)
        if tail:
            p["mamba_tail"] = M2.mamba2_params(ks[2], cfg, num_layers=tail)
        # one shared attention(+mlp) block + per-group LoRA deltas on q/k/v
        shared = {
            "ln1": jnp.ones((d,), dt),
            "attn": Lyr.attention_params(ks[3], cfg),
            "ln2": jnp.ones((d,), dt),
            "mlp": Lyr.mlp_params(ks[4], cfg),
        }
        r = cfg.shared_attn_lora_rank
        kl = jax.random.split(ks[5], 2)
        shared["lora_a"] = Lyr.dense_init(kl[0], (groups, d, r), dt, d)
        shared["lora_b"] = jnp.zeros((groups, r, 3 * d), dt)  # zero-init delta
        p["shared"] = shared
        p["final_norm"] = jnp.ones((d,), dt)
        return p

    # attention trunk families (dense / moe / encdec / vlm)
    trunk = {
        "ln1": jnp.ones((L, d), dt),
        "ln2": jnp.ones((L, d), dt),
    }
    if cfg.use_mla:
        trunk["attn"] = MLA.mla_params(ks[1], cfg, num_layers=L)
    else:
        trunk["attn"] = Lyr.attention_params(ks[1], cfg, num_layers=L)
    if cfg.is_moe:
        trunk["moe"] = MOE.moe_params(ks[2], cfg, num_layers=L)
    else:
        trunk["mlp"] = Lyr.mlp_params(ks[2], cfg, num_layers=L)
    p["layers"] = trunk
    p["final_norm"] = jnp.ones((d,), dt)

    if cfg.family == "encdec":
        Le = cfg.encoder_layers
        p["encoder"] = {
            "ln1": jnp.ones((Le, d), dt),
            "attn": Lyr.attention_params(ks[3], cfg, num_layers=Le),
            "ln2": jnp.ones((Le, d), dt),
            "mlp": Lyr.mlp_params(ks[4], cfg, num_layers=Le),
            "final_norm": jnp.ones((d,), dt),
        }
        p["cross"] = {
            "ln": jnp.ones((L, d), dt),
            "attn": Lyr.attention_params(ks[5], cfg, num_layers=L),
        }
        # audio frontend stub: a projection from precomputed frame features
        p["frontend_proj"] = Lyr.dense_init(ks[6], (d, d), dt, d)
    if cfg.family == "vlm":
        p["frontend_proj"] = Lyr.dense_init(ks[6], (d, d), dt, d)
    return p


def _zamba_grouping(cfg) -> tuple[int, int, int]:
    """(num_groups, mamba_layers_per_group, tail_layers) for the hybrid."""
    per = cfg.shared_attn_every
    groups = cfg.num_layers // per
    tail = cfg.num_layers - groups * per
    return groups, per, tail


# =====================================================================
# attention-trunk forward (dense / moe / encdec / vlm)
# =====================================================================
def _trunk_layer(cfg, parallel, p, x, positions, *, prefix_len=0, cache=None,
                 pos=None, cross=None, enc_out=None, causal=True,
                 table=None, full_seq=0):
    """One decoder layer. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm({"scale": p["ln1"]}, x, cfg.norm_eps)
    if cfg.use_mla:
        if cache is not None and pos is not None:      # continuation
            if x.shape[1] == 1:
                o, new_cache = MLA.mla_decode(cfg, p["attn"], h, cache, pos)
            else:
                o, new_cache = MLA.mla_extend(cfg, p["attn"], h, cache, pos)
        else:
            o, new_cache = MLA.mla_prefill(cfg, p["attn"], h, positions,
                                           want_cache=cache is not None)
    else:
        o, new_cache = Lyr.attention_block(
            cfg, p["attn"], h, positions=positions, causal=causal,
            prefix_len=prefix_len, cache=cache, pos=pos,
            table=table, full_seq=full_seq)
    x = x + o
    if cross is not None:
        h = rmsnorm({"scale": cross["ln"]}, x, cfg.norm_eps)
        o, _ = Lyr.attention_block(cfg, cross["attn"], h, positions=positions,
                                   causal=False, cross_kv=enc_out)
        x = x + o
    h = rmsnorm({"scale": p["ln2"]}, x, cfg.norm_eps)
    if cfg.is_moe:
        o, aux = MOE.moe_block(cfg, p["moe"], h, parallel)
    else:
        o = Lyr.mlp(p["mlp"], h)
    return x + o, new_cache, aux


def _scan_trunk(cfg, parallel, trunk, x, positions, *, prefix_len=0,
                caches=None, pos=None, cross=None, enc_kv=None, causal=True,
                remat=False, table=None, full_seq=0):
    """Scan the L-stacked trunk. ``caches``/``enc_kv`` carry a leading L dim.

    ``table`` (paged mode) is shared by every layer — the block table maps a
    slot's logical positions to physical pages once, while each layer owns
    its own page pool slice of the scanned cache — so it rides the closure,
    not the scan carry."""
    def body(carry, xs):
        x, aux = carry
        p_l, cache_l, cross_l, enc_l = xs
        x, new_cache, aux_l = _trunk_layer(
            cfg, parallel, p_l, x, positions, prefix_len=prefix_len,
            cache=cache_l, pos=pos, cross=cross_l, enc_out=enc_l,
            causal=causal, table=table, full_seq=full_seq)
        return (x, aux + aux_l), new_cache

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (trunk, caches, cross, enc_kv)
    (x, aux), new_caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux


def _encoder_forward(cfg, parallel, p, frames):
    """Bidirectional encoder over (stubbed) frontend embeddings."""
    x = frames @ p["frontend_proj"] if "frontend_proj" in p else frames
    enc = p["encoder"]
    positions = jnp.arange(x.shape[1])

    def body(x, p_l):
        h = rmsnorm({"scale": p_l["ln1"]}, x, cfg.norm_eps)
        o, _ = Lyr.attention_block(cfg, p_l["attn"], h, positions=positions,
                                   causal=False)
        x = x + o
        h = rmsnorm({"scale": p_l["ln2"]}, x, cfg.norm_eps)
        return x + Lyr.mlp(p_l["mlp"], h), None

    x, _ = lax.scan(body, x, {k: enc[k] for k in ("ln1", "attn", "ln2", "mlp")})
    return rmsnorm({"scale": enc["final_norm"]}, x, cfg.norm_eps)


def _cross_kv(cfg, cross_p, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    B, S, _ = enc_out.shape
    hd, KVH = cfg.resolved_head_dim, cfg.num_kv_heads

    def per_layer(attn_l):
        k = (enc_out @ attn_l["wk"]).reshape(B, S, KVH, hd)
        v = (enc_out @ attn_l["wv"]).reshape(B, S, KVH, hd)
        return k, v

    return jax.vmap(per_layer)(cross_p["attn"])  # [L,B,S,KVH,hd] x2


# =====================================================================
# rwkv6 forward
# =====================================================================
def _rwkv_forward(cfg, p, x, state):
    def body(x, xs):
        p_l, st_l = xs
        return RWKV.rwkv6_block(cfg, p_l, x, st_l)

    x, new_state = lax.scan(body, x, (p["layers"], state))
    return x, new_state


# =====================================================================
# zamba2 (hybrid) forward
# =====================================================================
def _hybrid_forward(cfg, parallel, p, x, positions, *, state, attn_cache=None,
                    pos=None, remat=False):
    """13 groups of (shared attn + 6 mamba) + tail mamba layers.

    state: mamba2 state pytree with leading [groups*per] (+ separate tail);
    attn_cache: {'k','v'} with leading [groups] or None (training w/o cache).
    """
    groups, per, tail = _zamba_grouping(cfg)
    shared = p["shared"]

    def group_body(carry, xs):
        x = carry
        lora_a, lora_b, mamba_g, st_g, cache_g = xs
        # shared attention with per-group LoRA delta folded into q/k/v
        h = rmsnorm({"scale": shared["ln1"]}, x, cfg.norm_eps)
        delta = (h @ lora_a) @ lora_b                     # [B,S,3D]
        dq, dk, dv = jnp.split(delta, 3, axis=-1)
        o, new_cache = Lyr.attention_block(
            cfg, shared["attn"], h, positions=positions, causal=True,
            cache=cache_g, pos=pos, qkv_delta=(dq, dk, dv))
        x = x + o
        h = rmsnorm({"scale": shared["ln2"]}, x, cfg.norm_eps)
        x = x + Lyr.mlp(shared["mlp"], h)

        # inner scan over the group's mamba layers
        def mb(x, xs2):
            p_l, st_l = xs2
            return M2.mamba2_block(cfg, p_l, x, st_l)
        x, new_st = lax.scan(mb, x, (mamba_g, st_g))
        return x, (new_st, new_cache)

    if remat:
        group_body = jax.checkpoint(group_body,
                                    policy=jax.checkpoint_policies.nothing_saveable)

    mamba_grouped = jax.tree.map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), p["mamba"])
    st_grouped = jax.tree.map(
        lambda a: a.reshape(groups, per, *a.shape[1:]), state["body"])
    x, (new_st, new_caches) = lax.scan(
        group_body, x,
        (shared["lora_a"], shared["lora_b"], mamba_grouped, st_grouped,
         attn_cache))
    new_state = {"body": jax.tree.map(
        lambda a: a.reshape(groups * per, *a.shape[2:]), new_st)}
    if tail:
        def mb(x, xs2):
            p_l, st_l = xs2
            return M2.mamba2_block(cfg, p_l, x, st_l)
        x, new_tail = lax.scan(mb, x, (p["mamba_tail"], state["tail"]))
        new_state["tail"] = new_tail
    return x, new_state, new_caches


def hybrid_state_init(cfg, batch: int):
    groups, per, tail = _zamba_grouping(cfg)
    st = {"body": M2.mamba2_state_init(cfg, batch, groups * per)}
    if tail:
        st["tail"] = M2.mamba2_state_init(cfg, batch, tail)
    return st


# =====================================================================
# public entry points
# =====================================================================
def loss_fn(cfg: ModelConfig, parallel: Optional[ParallelConfig], params,
            batch: dict) -> jnp.ndarray:
    """Next-token CE loss. batch: tokens, labels (+frames/patches for stubs)."""
    remat = bool(parallel and parallel.remat)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = Lyr.embed(params["embed"], tokens, cfg)
    x = shard(x, "batch", "seq", None)
    positions = jnp.arange(S)
    aux = jnp.zeros((), jnp.float32)
    prefix_len = 0

    if cfg.family == "ssm":
        state = RWKV.rwkv6_state_init(cfg, B)
        x, _ = _rwkv_forward(cfg, params, x, state)
    elif cfg.family == "hybrid":
        groups, per, tail = _zamba_grouping(cfg)
        state = hybrid_state_init(cfg, B)
        cache0 = _stacked_cache(cfg, groups, B, S, cfg.dtype, train=True)
        x, _, _ = _hybrid_forward(cfg, parallel, params, x, positions,
                                  state=state, attn_cache=cache0, remat=remat)
    elif cfg.family == "encdec":
        enc_out = _encoder_forward(cfg, parallel, params, batch["frames"])
        enc_kv = _cross_kv(cfg, params["cross"], enc_out)
        cross = {"ln": params["cross"]["ln"], "attn": params["cross"]["attn"]}
        x, _, aux = _scan_trunk(cfg, parallel, params["layers"], x, positions,
                                cross=cross, enc_kv=enc_kv, remat=remat)
    else:
        if cfg.family == "vlm":
            patches = batch["patches"] @ params["frontend_proj"]
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
            prefix_len = patches.shape[1]
            positions = jnp.arange(x.shape[1])
        x, _, aux = _scan_trunk(cfg, parallel, params["layers"], x, positions,
                                prefix_len=prefix_len, remat=remat)

    x = rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    if prefix_len:
        x = x[:, prefix_len:]
    logit = Lyr.logits(params["embed"], x, cfg)
    loss = cross_entropy(logit, batch["labels"], batch.get("loss_mask"))
    return loss + 0.01 * aux


def _stacked_cache(cfg, L, B, S, dtype, train=False):
    hd, KVH = cfg.resolved_head_dim, cfg.num_kv_heads
    if cfg.use_mla:
        return {"ckv": jnp.zeros((L, B, S, cfg.kv_lora_rank), jnp.dtype(dtype)),
                "kr": jnp.zeros((L, B, S, cfg.qk_rope_head_dim), jnp.dtype(dtype))}
    if train:
        # training never reads the cache; attention_block still threads it
        return None
    if str(dtype) == "int8":
        # quantized cache (§Perf H3): per-(token, kv-head) absmax scales;
        # the cache structure itself signals quantization downstream
        # (attention_block checks for the 'k_scale' key).
        return {"k": jnp.zeros((L, B, S, KVH, hd), jnp.int8),
                "k_scale": jnp.zeros((L, B, S, KVH), jnp.float32),
                "v": jnp.zeros((L, B, S, KVH, hd), jnp.int8),
                "v_scale": jnp.zeros((L, B, S, KVH), jnp.float32)}
    return {"k": jnp.zeros((L, B, S, KVH, hd), jnp.dtype(dtype)),
            "v": jnp.zeros((L, B, S, KVH, hd), jnp.dtype(dtype))}


def prefill_fn(cfg: ModelConfig, parallel: Optional[ParallelConfig], params,
               inputs: dict):
    """Prefill: run the full prompt, return (last-token logits, decode cache)."""
    tokens = inputs["tokens"]
    B, S = tokens.shape
    x = Lyr.embed(params["embed"], tokens, cfg)
    positions = jnp.arange(S)
    prefix_len = 0

    if cfg.family == "ssm":
        state = RWKV.rwkv6_state_init(cfg, B)
        x, new_state = _rwkv_forward(cfg, params, x, state)
        cache = {"state": new_state, "pos": jnp.full((B,), S, jnp.int32)}
    elif cfg.family == "hybrid":
        groups, _, _ = _zamba_grouping(cfg)
        state = hybrid_state_init(cfg, B)
        cache0 = None
        x, new_state, new_caches = _hybrid_forward(
            cfg, parallel, params, x, positions, state=state,
            attn_cache=_prefill_cache_placeholder(cfg, groups), remat=False)
        cache = {"state": new_state, "attn": new_caches,
                 "pos": jnp.full((B,), S, jnp.int32)}
    elif cfg.family == "encdec":
        enc_out = _encoder_forward(cfg, parallel, params, inputs["frames"])
        enc_kv = _cross_kv(cfg, params["cross"], enc_out)
        cross = {"ln": params["cross"]["ln"], "attn": params["cross"]["attn"]}
        cache0 = _prefill_cache_placeholder(cfg, cfg.num_layers)
        x, new_caches, _ = _scan_trunk(cfg, parallel, params["layers"], x,
                                       positions, caches=cache0, cross=cross,
                                       enc_kv=enc_kv)
        cache = {"kv": new_caches, "enc_kv": enc_kv,
                 "pos": jnp.full((B,), S, jnp.int32)}
    else:
        if cfg.family == "vlm":
            patches = inputs["patches"] @ params["frontend_proj"]
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
            prefix_len = patches.shape[1]
            positions = jnp.arange(x.shape[1])
        cache0 = _prefill_cache_placeholder(cfg, cfg.num_layers)
        x, new_caches, _ = _scan_trunk(cfg, parallel, params["layers"], x,
                                       positions, prefix_len=prefix_len,
                                       caches=cache0)
        cache = {"kv": new_caches,
                 "pos": jnp.full((B,), x.shape[1], jnp.int32)}

    x = rmsnorm({"scale": params["final_norm"]}, x[:, -1:], cfg.norm_eps)
    logit = Lyr.logits(params["embed"], x, cfg)
    return logit[:, 0], cache


def _prefill_cache_placeholder(cfg, L):
    """Sentinel telling attention layers to emit their K/V (cache write)."""
    # scan needs a pytree with a leading L dim; zeros of size 0 along seq work
    # as "emit cache" markers: attention_block only checks `cache is not None`
    # and Sq>1 -> writes fresh K/V ignoring the placeholder content.
    if cfg.use_mla:
        return {"ckv": jnp.zeros((L, 0)), "kr": jnp.zeros((L, 0))}
    return {"k": jnp.zeros((L, 0)), "v": jnp.zeros((L, 0))}


def extend_fn(cfg: ModelConfig, parallel: Optional[ParallelConfig], params,
              inputs: dict, cache: dict):
    """Continue a prefill from an existing decode cache with a [B,C] chunk.

    ``cache`` is a fixed-shape decode cache (as built by ``make_decode_cache``
    and populated by a prior prefill/extend/decode); ``cache["pos"]`` [B]
    gives each row's valid length, which may differ per row. The chunk's
    tokens occupy positions pos..pos+C-1: attention families scatter the
    chunk's K/V (or MLA latents) in at those offsets and attend to prefix +
    chunk causally; recurrent families (SSM / hybrid Mamba / RWKV) simply
    advance their carried state, which IS the sequential continuation.
    Returns (last-token logits [B,V], updated cache with pos += C).

    This is what lets the serving engine admit a prompt tail in O(log S)
    compiled calls (descending power-of-2 chunks) instead of up to S serial
    B=1 decodes, while keeping the compile cache bounded: the cache shape is
    fixed, so only C varies.
    """
    tokens = inputs["tokens"]          # [B, C] int32
    B, C = tokens.shape
    pos = cache["pos"]                 # [B] valid lengths (per-row)
    table = cache.get("table")         # paged mode: [B, p] block table
    span = cache.get("span")           # paged mode: static max_seq marker
    full_seq = span.shape[0] if span is not None else 0
    x = Lyr.embed(params["embed"], tokens, cfg)
    positions = pos[:, None] + jnp.arange(C)[None, :]   # [B, C]

    if cfg.family == "ssm":
        x, new_state = _rwkv_forward(cfg, params, x, cache["state"])
        new_cache = {"state": new_state, "pos": pos + C}
    elif cfg.family == "hybrid":
        x, new_state, new_attn = _hybrid_forward(
            cfg, parallel, params, x, positions, state=cache["state"],
            attn_cache=cache["attn"], pos=pos)
        new_cache = {"state": new_state, "attn": new_attn, "pos": pos + C}
    elif cfg.family == "encdec":
        cross = {"ln": params["cross"]["ln"], "attn": params["cross"]["attn"]}
        x, new_kv, _ = _scan_trunk(cfg, parallel, params["layers"], x,
                                   positions, caches=cache["kv"], pos=pos,
                                   cross=cross, enc_kv=cache["enc_kv"],
                                   table=table, full_seq=full_seq)
        new_cache = {"kv": new_kv, "enc_kv": cache["enc_kv"], "pos": pos + C}
    else:
        # dense / moe / vlm: any prefix (VLM patches, prior prompt chunks)
        # is already in the cache; the chunk itself is text-only.
        x, new_kv, _ = _scan_trunk(cfg, parallel, params["layers"], x,
                                   positions, caches=cache["kv"], pos=pos,
                                   table=table, full_seq=full_seq)
        new_cache = {"kv": new_kv, "pos": pos + C}
    if table is not None:
        new_cache["table"], new_cache["span"] = table, span

    x = rmsnorm({"scale": params["final_norm"]}, x[:, -1:], cfg.norm_eps)
    logit = Lyr.logits(params["embed"], x, cfg)
    return logit[:, 0], new_cache


def decode_fn(cfg: ModelConfig, parallel: Optional[ParallelConfig], params,
              inputs: dict, cache: dict):
    """One decode step: new token against the cache. Returns (logits, cache)."""
    token = inputs["token"]            # [B] int32
    B = token.shape[0]
    pos = cache["pos"]                 # [B] valid lengths
    table = cache.get("table")         # paged mode: [B, p] block table
    span = cache.get("span")           # paged mode: static max_seq marker
    full_seq = span.shape[0] if span is not None else 0
    x = Lyr.embed(params["embed"], token[:, None], cfg)
    positions = pos[:, None]

    if cfg.family == "ssm":
        x, new_state = _rwkv_forward(cfg, params, x, cache["state"])
        new_cache = {"state": new_state, "pos": pos + 1}
    elif cfg.family == "hybrid":
        x, new_state, new_attn = _hybrid_forward(
            cfg, parallel, params, x, positions, state=cache["state"],
            attn_cache=cache["attn"], pos=pos)
        new_cache = {"state": new_state, "attn": new_attn, "pos": pos + 1}
    elif cfg.family == "encdec":
        cross = {"ln": params["cross"]["ln"], "attn": params["cross"]["attn"]}
        x, new_kv, _ = _scan_trunk(cfg, parallel, params["layers"], x,
                                   positions, caches=cache["kv"], pos=pos,
                                   cross=cross, enc_kv=cache["enc_kv"],
                                   table=table, full_seq=full_seq)
        new_cache = {"kv": new_kv, "enc_kv": cache["enc_kv"], "pos": pos + 1}
    else:
        x, new_kv, _ = _scan_trunk(cfg, parallel, params["layers"], x,
                                   positions, caches=cache["kv"], pos=pos,
                                   table=table, full_seq=full_seq)
        new_cache = {"kv": new_kv, "pos": pos + 1}
    if table is not None:
        new_cache["table"], new_cache["span"] = table, span

    x = rmsnorm({"scale": params["final_norm"]}, x, cfg.norm_eps)
    logit = Lyr.logits(params["embed"], x, cfg)
    return logit[:, 0], new_cache


def quantize_decode_cache(cache: dict) -> dict:
    """bf16/f32 GQA decode cache -> int8 + scales (§Perf H3).

    Applies to the ``kv`` part only (MLA latents / SSM states unchanged).
    """
    from repro.models.layers import quantize_kv

    def q_tree(kv):
        # leaves: [L, B, S, KVH, hd] — quantize along hd per (token, head)
        k, v = kv["k"], kv["v"]
        qk, sk = jax.vmap(jax.vmap(quantize_kv, in_axes=1, out_axes=1))(k)
        qv, sv = jax.vmap(jax.vmap(quantize_kv, in_axes=1, out_axes=1))(v)
        return {"k": qk, "k_scale": sk, "v": qv, "v_scale": sv}

    def q_pool(kv):
        # paged pools: [L, P, page, KVH, hd] — quantize_kv is shape-generic
        # over leading dims, so pages quantize exactly like token rows
        qk, sk = quantize_kv(kv["k"])
        qv, sv = quantize_kv(kv["v"])
        return {"k": qk, "k_scale": sk, "v": qv, "v_scale": sv}

    out = dict(cache)
    if "kv" in cache and cache["kv"] is not None and "k" in cache["kv"]:
        out["kv"] = (q_pool if "table" in cache else q_tree)(cache["kv"])
    return out


def make_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Allocate (or spec) the decode-time cache for an arch at a given shape."""
    dtype = dtype or cfg.dtype
    B, S = batch, max_seq
    pos = jnp.zeros((B,), jnp.int32)
    if cfg.family == "ssm":
        return {"state": RWKV.rwkv6_state_init(cfg, B), "pos": pos}
    if cfg.family == "hybrid":
        groups, _, _ = _zamba_grouping(cfg)
        return {"state": hybrid_state_init(cfg, B),
                "attn": _stacked_cache(cfg, groups, B, S, dtype), "pos": pos}
    if cfg.family == "encdec":
        hd, KVH = cfg.resolved_head_dim, cfg.num_kv_heads
        Se = cfg.num_prefix_embeddings
        enc_kv = (jnp.zeros((cfg.num_layers, B, Se, KVH, hd), jnp.dtype(dtype)),
                  jnp.zeros((cfg.num_layers, B, Se, KVH, hd), jnp.dtype(dtype)))
        return {"kv": _stacked_cache(cfg, cfg.num_layers, B, S, dtype),
                "enc_kv": enc_kv, "pos": pos}
    return {"kv": _stacked_cache(cfg, cfg.num_layers, B, S, dtype), "pos": pos}


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """Paged KV applies to the GQA attention-trunk families (dense / moe /
    vlm / encdec self-attention, incl. the int8 cache). MLA latents and the
    recurrent families (ssm / hybrid) carry O(1)-per-token state, not a
    max_seq cache — there is nothing dead to stop attending over, so they
    pass through on the dense layout untouched."""
    return cfg.family not in ("ssm", "hybrid") and not cfg.use_mla


def make_paged_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                            page_size: int = 16,
                            num_pages: Optional[int] = None, dtype=None):
    """Paged (block-table) decode cache for the GQA attention-trunk families.

    Layout (see ``layers.paged_view``): each layer's K/V leaf is a shared
    pool ``[L, P, page, KVH, hd]`` of ``P = num_pages`` physical pages;
    one block table ``[B, maxP]`` (shared by all layers — every layer
    stores the same logical positions) maps a slot's logical pages to
    physical ones, sentinel ``P`` marking unmapped entries. ``span`` is a
    zero-length-S marker leaf whose *shape* carries the static logical
    max_seq into jit (the paged softmax pads its denominator to it for
    bitwise parity with the dense layout). ``num_pages`` defaults to the
    dense equivalent capacity ``batch * max_seq / page_size``; giving an
    engine the same byte budget but more slots than the dense layout could
    hold is the paged throughput story.
    """
    if page_size & (page_size - 1) or page_size <= 0:
        raise ValueError(f"page_size {page_size} must be a power of two")
    if max_seq % page_size:
        raise ValueError(f"max_seq {max_seq} not a multiple of page_size")
    if not supports_paged_cache(cfg):
        raise ValueError(f"family {cfg.family!r} (use_mla={cfg.use_mla}) "
                         "has no paged layout — use make_decode_cache")
    dtype = dtype or cfg.dtype
    B, L = batch, cfg.num_layers
    hd, KVH = cfg.resolved_head_dim, cfg.num_kv_heads
    maxP = max_seq // page_size
    P = num_pages if num_pages is not None else B * maxP
    if str(dtype) == "int8":
        kv = {"k": jnp.zeros((L, P, page_size, KVH, hd), jnp.int8),
              "k_scale": jnp.zeros((L, P, page_size, KVH), jnp.float32),
              "v": jnp.zeros((L, P, page_size, KVH, hd), jnp.int8),
              "v_scale": jnp.zeros((L, P, page_size, KVH), jnp.float32)}
    else:
        kv = {"k": jnp.zeros((L, P, page_size, KVH, hd), jnp.dtype(dtype)),
              "v": jnp.zeros((L, P, page_size, KVH, hd), jnp.dtype(dtype))}
    cache = {"kv": kv,
             "table": jnp.full((B, maxP), P, jnp.int32),
             "span": jnp.zeros((max_seq,), jnp.int8),
             "pos": jnp.zeros((B,), jnp.int32)}
    if cfg.family == "encdec":
        Se = cfg.num_prefix_embeddings
        cache["enc_kv"] = (
            jnp.zeros((L, B, Se, KVH, hd), jnp.dtype(dtype)),
            jnp.zeros((L, B, Se, KVH, hd), jnp.dtype(dtype)))
    return cache
