"""Shared transformer building blocks (pure-functional JAX).

Conventions:
  * params are nested dicts of jnp arrays; stacked layers carry a leading
    ``L`` dimension and are consumed with ``lax.scan``.
  * activations/params are annotated with *logical* axes via
    ``repro.distributed.shard`` — identity unless rules are installed.
  * softmax/norm accumulate in fp32 regardless of the param dtype.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import current_rules, shard

# attention falls back from one-shot to KV-chunked (flash-style) above this.
# §Perf: the one-shot path materialises [B,H,Sq,Sk] score tensors, which
# GSPMD cannot reshard across the seq<->heads transition (it falls back to
# full replication) — lowering the threshold is hillclimb H1.
FLASH_SEQ_THRESHOLD = 8192
KV_CHUNK = 512


def set_flash_threshold(n: int) -> None:
    """Tune the one-shot -> chunked attention switchover (dry-run knob)."""
    global FLASH_SEQ_THRESHOLD
    FLASH_SEQ_THRESHOLD = n


def scan_chunk_for(S: int, chunk: int) -> int:
    """Largest supported scan chunk dividing S (``chunk``, then 8, then 1).

    Shared by the recurrent families' chunked scans (rwkv6 / mamba2); any
    segment length works, which is what lets a prefill *continue* from a
    carried state — the serving engine's chunked prefill-from-cache path
    feeds power-of-2 segments through this.
    """
    return chunk if S % chunk == 0 else (8 if S % 8 == 0 else 1)


def _norm_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = shape[0] if fan_in is None else fan_in
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------- RMSNorm
def rmsnorm_params(key, dim, dtype, num_layers=None):
    shape = (dim,) if num_layers is None else (num_layers, dim)
    return {"scale": _norm_init(key, shape, dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_table(positions, head_dim: int, theta: float):
    """positions: int array [...]; returns (cos, sin) of shape [..., head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., S, H, D]; cos/sin: [..., S, D/2] broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------- attention
def attention_params(key, cfg, num_layers=None):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KVH = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    L = () if num_layers is None else (num_layers,)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": dense_init(ks[0], (*L, d, H * hd), dt, d),
        "wk": dense_init(ks[1], (*L, d, KVH * hd), dt, d),
        "wv": dense_init(ks[2], (*L, d, KVH * hd), dt, d),
        "wo": dense_init(ks[3], (*L, H * hd, d), dt, H * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = _norm_init(ks[4], (*L, hd), dt)
        p["k_norm"] = _norm_init(ks[5], (*L, hd), dt)
    return p


def _qk_normalize(x, scale, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _causal_mask(q_pos, k_pos, prefix_len: int = 0):
    """True where attention is allowed. prefix positions attend bidirectionally."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if prefix_len:
        m = jnp.logical_or(m, (k_pos < prefix_len)[..., None, :])
    return m


def multihead_attention(q, k, v, *, causal: bool, q_offset=0, prefix_len: int = 0):
    """q: [B,Sq,H,hd]; k/v: [B,Sk,KVH,hd]. One-shot (S^2) path for short seqs."""
    B, Sq, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        q_pos = jnp.arange(Sq) + q_offset
        k_pos = jnp.arange(k.shape[1])
        mask = _causal_mask(q_pos, k_pos, prefix_len)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def flash_attention_xla(q, k, v, *, causal: bool, q_offset=0, prefix_len: int = 0,
                        kv_chunk: int = KV_CHUNK):
    """KV-chunked online-softmax attention (no S×S materialisation).

    Pure-XLA analogue of the Pallas kernel in ``repro.kernels.flash_attention``
    — used for shapes too long for the one-shot path. Differentiable.
    """
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    vd = v.shape[-1]                       # may differ from hd (MLA: 192/128)
    G = H // KVH
    while kv_chunk > 1 and Sk % kv_chunk:  # halve until it divides Sk
        kv_chunk //= 2
    nchunk = Sk // kv_chunk
    scale = 1.0 / math.sqrt(hd)
    qg = (q.reshape(B, Sq, KVH, G, hd) * scale).astype(jnp.float32)
    q_pos = jnp.arange(Sq) + q_offset

    kc = jnp.moveaxis(k.reshape(B, nchunk, kv_chunk, KVH, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nchunk, kv_chunk, KVH, vd), 1, 0)

    def body(carry, chunk):
        m, l, acc = carry
        k_i, v_i, idx = chunk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_i.astype(jnp.float32))
        if causal:
            k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
            mask = _causal_mask(q_pos, k_pos, prefix_len)
            s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    vd = v.shape[-1]
    m0 = jnp.full((B, KVH, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, Sq, vd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, jnp.arange(nchunk)))
    o = acc / l[..., None]
    o = jnp.moveaxis(o, -2, 1).reshape(B, Sq, H, vd)
    return o.astype(q.dtype)


def extend_attention(q, k_cache, v_cache, k_new, v_new, pos, *,
                     pad_sum_to: Optional[int] = None):
    """Chunk attention against a [B,S,KVH,hd] cache (prefill continuation).

    q: [B,C,H,hd]; k_new/v_new: [B,C,KVH,hd] — the chunk's own K/V;
    ``pos``: [B] int32 valid cached tokens per sequence. Query ``j`` of the
    chunk attends to the cached prefix (< pos) plus chunk positions <= j.
    The C=1 case is ``decode_attention``'s math with an explicit chunk axis;
    C>1 is what lets the serving engine admit a prompt tail in O(log S)
    compiled calls instead of S serial decodes.

    ``pad_sum_to``: when the cache arg is a *paged view* narrower than the
    logical max_seq, the softmax denominator must still reduce over the full
    width or its reduction tree (and hence its low-order bits) drifts from
    the dense path. Padding the probability tensor with exact zeros up to
    ``pad_sum_to`` before the sum restores bitwise identity: masked entries
    underflow to exact 0.0 and IEEE addition of 0.0 is the identity, while
    XLA sees the same reduction shape as the dense call. ``None`` keeps the
    original (dense-anchor) HLO byte-for-byte.
    """
    B, C, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = (q.reshape(B, C, KVH, G, hd) * scale).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < pos[:, None]                  # [B,S]
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    s_new = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k_new.astype(jnp.float32))
    tri = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]         # [C,C]
    s_new = jnp.where(tri[None, None, None], s_new, -1e30)
    m = jnp.maximum(s.max(axis=-1), s_new.max(axis=-1))            # [B,KVH,G,C]
    p = jnp.exp(s - m[..., None])
    p_new = jnp.exp(s_new - m[..., None])
    if pad_sum_to is not None and pad_sum_to > S:
        p_sum = jnp.pad(p, ((0, 0),) * 4 + ((0, pad_sum_to - S),)).sum(axis=-1)
    else:
        p_sum = p.sum(axis=-1)
    l = p_sum + p_new.sum(axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v_cache.astype(jnp.float32))
    o = o + jnp.einsum("bkgqj,bjkd->bkgqd", p_new, v_new.astype(jnp.float32))
    o = o / l[..., None]
    o = jnp.moveaxis(o, 3, 1).reshape(B, C, H, v_cache.shape[-1])
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, k_new, v_new, pos, *,
                     pad_sum_to: Optional[int] = None):
    """Single-token attention against a [B,S,KVH,hd] cache.

    ``pos``: [B] int32 — number of valid cached tokens per sequence; the new
    token's K/V participate via explicit concat-free accumulation. Softmax
    reductions over a sharded cache-sequence dim lower to all-reduces
    (flash-decoding across the mesh). ``pad_sum_to``: see
    ``extend_attention`` — bitwise parity for narrowed paged views.
    """
    B, _, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qg = (q.reshape(B, KVH, G, hd) * scale).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    s_new = jnp.einsum("bkgd,bkd->bkg", qg, k_new.astype(jnp.float32))
    m = jnp.maximum(s.max(axis=-1), s_new)
    p = jnp.exp(s - m[..., None])
    p_new = jnp.exp(s_new - m)
    if pad_sum_to is not None and pad_sum_to > S:
        p_sum = jnp.pad(p, ((0, 0),) * 3 + ((0, pad_sum_to - S),)).sum(axis=-1)
    else:
        p_sum = p.sum(axis=-1)
    l = p_sum + p_new
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    o = o + p_new[..., None] * v_new.astype(jnp.float32)[:, :, None, :]
    o = (o / l[..., None]).reshape(B, 1, H, hd)
    return o.astype(q.dtype)


def attend(q, k, v, *, causal=True, q_offset=0, prefix_len=0):
    if q.shape[1] <= FLASH_SEQ_THRESHOLD and k.shape[1] <= FLASH_SEQ_THRESHOLD:
        return multihead_attention(q, k, v, causal=causal, q_offset=q_offset,
                                   prefix_len=prefix_len)
    return flash_attention_xla(q, k, v, causal=causal, q_offset=q_offset,
                               prefix_len=prefix_len)


def attention_block(cfg, p, x, *, positions, causal=True, prefix_len=0,
                    cache=None, pos=None, cross_kv=None, qkv_delta=None,
                    table=None, full_seq=0):
    """Full attention sub-block: projections + rope + attend (+ cache update).

    Returns (out, new_cache). ``cache`` is a dict(k=[B,S,KVH,hd], v=...) for
    decode; ``cross_kv`` short-circuits K/V to precomputed encoder K/V;
    ``qkv_delta`` adds (dq, dk, dv) [B,S,*] post-projection (zamba2 LoRA).

    ``pos is not None`` marks a *continuation* against a populated fixed-size
    cache: Sq == 1 is the single-token decode step, Sq > 1 is a chunked
    prefill continuation (``extend``) — the chunk attends to the cached
    prefix plus itself causally, and its K/V are scattered in at
    pos..pos+Sq-1. ``pos is None`` with a cache is the fresh-prefill path
    (emit K/V, ignore the placeholder cache content).

    ``table is not None`` switches the continuation paths to the paged
    layout: cache leaves are page pools ``[P, page, KVH, ...]`` addressed
    through the block table (see ``paged_view``). The attention math runs
    over the gathered ``table.shape[1] * page``-token view with the softmax
    denominator padded to ``full_seq`` — bitwise identical to the dense
    path while touching only the pages the table names.
    """
    hd = cfg.resolved_head_dim
    H, KVH = cfg.num_heads, cfg.num_kv_heads
    B, Sq, _ = x.shape
    cont = cache is not None and pos is not None
    decode = cont and Sq == 1
    extend = cont and Sq > 1
    paged = cont and table is not None

    q_p, k_p, v_p = x @ p["wq"], None, None
    if cross_kv is None:
        k_p = x @ p["wk"]
        v_p = x @ p["wv"]
    if qkv_delta is not None:
        dq, dk, dv = qkv_delta
        q_p = q_p + dq.astype(q_p.dtype)
        k_p = k_p + dk.astype(k_p.dtype)
        v_p = v_p + dv.astype(v_p.dtype)
    q = shard(q_p.reshape(B, Sq, H, hd), "batch", None, "heads", None)
    if cross_kv is None:
        k = k_p.reshape(B, Sq, KVH, hd)
        v = v_p.reshape(B, Sq, KVH, hd)
        # (§Perf H6, REFUTED: repeating KV heads to H when TP > KVH was
        # predicted to recover head sharding of the score tensors, but it
        # added 35 GB of collective-permute resharding — see EXPERIMENTS.md)
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = _qk_normalize(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = _qk_normalize(k, p["k_norm"], cfg.norm_eps)

    use_rope = cross_kv is None and not (cfg.family == "encdec" and causal is False)
    if use_rope:
        cos, sin = rope_table(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        if cross_kv is None:
            k = apply_rope(k, cos, sin)

    new_cache = cache
    if decode:
        if cross_kv is not None:
            o = attend_cross_decode(q, k, v, cfg)
        elif paged:
            if "k_scale" in cache:
                kc = paged_view(cache["k"], table)
                vc = paged_view(cache["v"], table)
                ks_ = paged_view(cache["k_scale"], table)
                vs_ = paged_view(cache["v_scale"], table)
                kd = kc.astype(jnp.float32) * ks_[..., None]
                vd = vc.astype(jnp.float32) * vs_[..., None]
                o = decode_attention(q, kd.astype(q.dtype), vd.astype(q.dtype),
                                     k[:, 0], v[:, 0], pos,
                                     pad_sum_to=full_seq)
                kq, ksc = quantize_kv(k[:, 0])
                vq, vsc = quantize_kv(v[:, 0])
                new_cache = {
                    "k": _paged_cache_insert(cache["k"], kq, table, pos),
                    "k_scale": _paged_cache_insert(cache["k_scale"], ksc,
                                                   table, pos),
                    "v": _paged_cache_insert(cache["v"], vq, table, pos),
                    "v_scale": _paged_cache_insert(cache["v_scale"], vsc,
                                                   table, pos)}
            else:
                kc = paged_view(cache["k"], table)
                vc = paged_view(cache["v"], table)
                o = decode_attention(q, kc, vc, k[:, 0], v[:, 0], pos,
                                     pad_sum_to=full_seq)
                new_cache = {
                    "k": _paged_cache_insert(cache["k"], k[:, 0], table, pos),
                    "v": _paged_cache_insert(cache["v"], v[:, 0], table, pos)}
        elif "k_scale" in cache:
            # int8 cache (§Perf H3): dequantize for the attention math (the
            # Pallas decode kernel fuses this into the HBM->VMEM stream on
            # TPU), re-quantize the new token on insert.
            ks_ = shard(cache["k_scale"], "batch", "cache_seq", None)
            vs_ = shard(cache["v_scale"], "batch", "cache_seq", None)
            kc = shard(cache["k"], "batch", "cache_seq", "cache_kv_heads", None)
            vc = shard(cache["v"], "batch", "cache_seq", "cache_kv_heads", None)
            kd = kc.astype(jnp.float32) * ks_[..., None]
            vd = vc.astype(jnp.float32) * vs_[..., None]
            o = decode_attention(q, kd.astype(q.dtype), vd.astype(q.dtype),
                                 k[:, 0], v[:, 0], pos)
            kq, ksc = quantize_kv(k[:, 0])
            vq, vsc = quantize_kv(v[:, 0])
            new_cache = {
                "k": _cache_insert(kc, kq, pos),
                "k_scale": _cache_insert(ks_, ksc, pos),
                "v": _cache_insert(vc, vq, pos),
                "v_scale": _cache_insert(vs_, vsc, pos)}
        else:
            kc = shard(cache["k"], "batch", "cache_seq", "cache_kv_heads", None)
            vc = shard(cache["v"], "batch", "cache_seq", "cache_kv_heads", None)
            o = decode_attention(q, kc, vc, k[:, 0], v[:, 0], pos)
            kc = _cache_insert(kc, k[:, 0], pos)
            vc = _cache_insert(vc, v[:, 0], pos)
            new_cache = {"k": shard(kc, "batch", "cache_seq", "cache_kv_heads", None),
                         "v": shard(vc, "batch", "cache_seq", "cache_kv_heads", None)}
    elif extend:
        if paged:
            if "k_scale" in cache:
                kc = paged_view(cache["k"], table)
                vc = paged_view(cache["v"], table)
                ks_ = paged_view(cache["k_scale"], table)
                vs_ = paged_view(cache["v_scale"], table)
                kd = kc.astype(jnp.float32) * ks_[..., None]
                vd = vc.astype(jnp.float32) * vs_[..., None]
                o = extend_attention(q, kd.astype(q.dtype), vd.astype(q.dtype),
                                     k, v, pos, pad_sum_to=full_seq)
                kq, ksc = quantize_kv(k)
                vq, vsc = quantize_kv(v)
                new_cache = {
                    "k": _paged_cache_insert_chunk(cache["k"], kq, table, pos),
                    "k_scale": _paged_cache_insert_chunk(cache["k_scale"],
                                                         ksc, table, pos),
                    "v": _paged_cache_insert_chunk(cache["v"], vq, table, pos),
                    "v_scale": _paged_cache_insert_chunk(cache["v_scale"],
                                                         vsc, table, pos)}
            else:
                kc = paged_view(cache["k"], table)
                vc = paged_view(cache["v"], table)
                o = extend_attention(q, kc, vc, k, v, pos,
                                     pad_sum_to=full_seq)
                new_cache = {
                    "k": _paged_cache_insert_chunk(cache["k"], k, table, pos),
                    "v": _paged_cache_insert_chunk(cache["v"], v, table, pos)}
        elif "k_scale" in cache:
            ks_ = shard(cache["k_scale"], "batch", "cache_seq", None)
            vs_ = shard(cache["v_scale"], "batch", "cache_seq", None)
            kc = shard(cache["k"], "batch", "cache_seq", "cache_kv_heads", None)
            vc = shard(cache["v"], "batch", "cache_seq", "cache_kv_heads", None)
            kd = kc.astype(jnp.float32) * ks_[..., None]
            vd = vc.astype(jnp.float32) * vs_[..., None]
            o = extend_attention(q, kd.astype(q.dtype), vd.astype(q.dtype),
                                 k, v, pos)
            kq, ksc = quantize_kv(k)           # shape-generic: [B,C,KVH,hd]
            vq, vsc = quantize_kv(v)
            new_cache = {
                "k": _cache_insert_chunk(kc, kq, pos),
                "k_scale": _cache_insert_chunk(ks_, ksc, pos),
                "v": _cache_insert_chunk(vc, vq, pos),
                "v_scale": _cache_insert_chunk(vs_, vsc, pos)}
        else:
            kc = shard(cache["k"], "batch", "cache_seq", "cache_kv_heads", None)
            vc = shard(cache["v"], "batch", "cache_seq", "cache_kv_heads", None)
            o = extend_attention(q, kc, vc, k, v, pos)
            kc = _cache_insert_chunk(kc, k, pos)
            vc = _cache_insert_chunk(vc, v, pos)
            new_cache = {"k": shard(kc, "batch", "cache_seq", "cache_kv_heads", None),
                         "v": shard(vc, "batch", "cache_seq", "cache_kv_heads", None)}
    else:
        o = attend(q, k, v, causal=causal, prefix_len=prefix_len)
        if cache is not None:  # prefill writes the cache
            new_cache = {"k": k, "v": v}
    o = o.reshape(B, Sq, H * hd)
    out = o @ p["wo"]
    return shard(out, "batch", "seq", None), new_cache


def attend_cross_decode(q, k, v, cfg):
    B, _, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = (q.reshape(B, KVH, G, hd) / math.sqrt(hd)).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def _cache_insert(cache, new, pos):
    """cache: [B,S,...]; new: [B,...]; pos: [B] — per-sequence scatter."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(new.astype(cache.dtype))


def _cache_insert_chunk(cache, new, pos):
    """cache: [B,S,...]; new: [B,C,...]; pos: [B] — write a C-token chunk at
    per-sequence offsets pos..pos+C-1."""
    B, C = new.shape[0], new.shape[1]
    rows = jnp.arange(B)[:, None]
    cols = pos[:, None] + jnp.arange(C)[None, :]
    return cache.at[rows, cols].set(new.astype(cache.dtype))


# --------------------------------------------------- paged (block-table) KV
# Page-pool layout: a cache leaf is a shared pool ``[P, page, KVH, ...]`` of
# P physical pages of ``page`` tokens each (page a power of two), owned by
# sequences through a block table ``[B, maxP]`` of physical page ids. The
# sentinel id ``P`` (== pool size, one past the last page) marks unmapped
# table entries: scatters with ``mode="drop"`` discard writes through it,
# and gathers clamp it to P-1 — junk that the ``pos`` validity mask already
# hides, exactly as the dense path hides its own stale rows. Logical token
# position t of row b lives at ``pool[table[b, t // page], t % page]``.
# Freed pages return to the allocator (host side, serving.engine) and are
# re-mapped to other rows — attention only ever reads the pages a table
# names, so a short sequence stops paying for the dead tail of max_seq.

def paged_view(pool, table):
    """Gather a dense [B, W, ...] view of the pages ``table`` names.

    pool: [P, page, KVH, ...]; table: [B, p] int32 -> view [B, p*page, ...].
    W = p*page is the *narrowed* width the caller sliced the table to;
    sentinel/junk entries clamp to real pages and rely on the ``pos`` mask.
    The gathered live bits are identical to the dense cache's, so running
    the dense attention math over this view (with ``pad_sum_to``) is
    bitwise the dense result.
    """
    B, p = table.shape
    idx = jnp.minimum(table, pool.shape[0] - 1)
    v = pool[idx]                                  # [B, p, page, ...]
    return v.reshape(B, p * pool.shape[1], *pool.shape[2:])


def _paged_cache_insert(pool, new, table, pos):
    """Paged counterpart of ``_cache_insert``: one token per row.

    pool: [P, page, ...]; new: [B, ...]; table: [B, p]; pos: [B] logical
    offsets. Rows whose table entry is the sentinel (or whose pos falls
    outside the sliced table width) drop their write — that is how masked
    rows and freed slots stay untouched without a select over the pool.
    """
    page = pool.shape[1]
    pidx = jnp.clip(pos // page, 0, table.shape[1] - 1)
    phys = jnp.take_along_axis(table, pidx[:, None], axis=1)[:, 0]
    return pool.at[phys, pos % page].set(new.astype(pool.dtype), mode="drop")


def _paged_cache_insert_chunk(pool, new, table, pos):
    """Paged counterpart of ``_cache_insert_chunk``: a C-token chunk per row
    at logical offsets pos..pos+C-1 (chunks may straddle page boundaries)."""
    C = new.shape[1]
    page = pool.shape[1]
    cols = pos[:, None] + jnp.arange(C)[None, :]          # [B, C] logical
    pidx = jnp.clip(cols // page, 0, table.shape[1] - 1)
    phys = jnp.take_along_axis(table, pidx, axis=1)       # [B, C] physical
    return pool.at[phys, cols % page].set(new.astype(pool.dtype), mode="drop")


def quantize_kv(x):
    """Per-(batch, kv-head) absmax int8 quantization of K or V tokens.

    x: [..., KVH, hd] -> (q int8 [..., KVH, hd], scale f32 [..., KVH]).
    Shape-generic over leading dims: one token ([B,KVH,hd]) for decode,
    a chunk ([B,C,KVH,hd]) for the extend path.
    """
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(x32).max(axis=-1), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


# ------------------------------------------------------------------ MLP
def mlp_params(key, cfg, num_layers=None, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    L = () if num_layers is None else (num_layers,)
    dt = jnp.dtype(cfg.dtype)
    return {
        "w_gate": dense_init(ks[0], (*L, d, f), dt, d),
        "w_up": dense_init(ks[1], (*L, d, f), dt, d),
        "w_down": dense_init(ks[2], (*L, f, d), dt, f),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", None, "ffn")
    return shard(h @ p["w_down"], "batch", "seq", None)


# ------------------------------------------------------------ embeddings
def embedding_params(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 2)
    p = {"embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt, cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dt, cfg.d_model)
    return p


def embed(p, tokens, cfg):
    e = shard(p["embed"], "vocab", None)
    x = jnp.take(e, tokens, axis=0)
    if cfg.family == "vlm":  # gemma normalisation
        x = x * math.sqrt(cfg.d_model)
    return x.astype(jnp.dtype(cfg.dtype))


def logits(p, x, cfg):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    out = x @ w
    return shard(out, "batch", None, "vocab")


def cross_entropy(logit, labels, mask=None):
    """Vocab-parallel-safe CE (§Perf H7).

    ``take_along_axis`` over a vocab-sharded logit forces GSPMD to gather
    the full fp32 logits (8.6 GB/microbatch on llama3-8b/train_4k). The
    one-hot multiply-reduce keeps every op elementwise/local in the vocab
    dim; only the reduced [B, S] tensors cross shards.
    """
    logit = logit.astype(jnp.float32)
    lse = jax.nn.logsumexp(logit, axis=-1)
    onehot = jax.nn.one_hot(labels, logit.shape[-1], dtype=logit.dtype)
    gold = jnp.sum(logit * onehot, axis=-1)
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
