from repro.models.api import Model, build

__all__ = ["Model", "build"]
