"""Public model API: one ``Model`` bundle per architecture.

``build(cfg, parallel)`` returns a bundle exposing:

    init_params(key)                      -> params pytree
    loss_fn(params, batch)                -> scalar
    prefill_fn(params, inputs)            -> (logits, cache)
    extend_fn(params, inputs, cache)      -> (logits, cache)
    decode_fn(params, inputs, cache)      -> (logits, cache)
    input_specs(shape)                    -> dict of ShapeDtypeStruct
    cache_specs(shape)                    -> cache pytree of ShapeDtypeStruct
    param_specs()                         -> params pytree of ShapeDtypeStruct

``extend_fn`` continues a prefill from an existing fixed-shape decode cache:
inputs carry a [B, C] token chunk, ``cache["pos"]`` gives each row's valid
length, and the chunk lands at positions pos..pos+C-1 — uniform across every
cache family (GQA KV, MLA latents, SSM/RWKV recurrent state, hybrid,
enc-dec/VLM prefix caches). It is the primitive behind the serving engine's
chunked batched admission.

``input_specs``/``cache_specs``/``param_specs`` never allocate — they are
what the multi-pod dry-run lowers against. Modality frontends ([audio]/
[vlm]) are STUBS: ``input_specs`` carries precomputed frame/patch
embeddings, per the assignment.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ParallelConfig
from repro.models import transformer as T


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    parallel: Optional[ParallelConfig]
    init_params: Callable
    loss_fn: Callable
    prefill_fn: Callable
    extend_fn: Callable
    decode_fn: Callable

    # ---------------- shape-only views (dry-run) ----------------
    def param_specs(self):
        return jax.eval_shape(self.init_params, jax.random.key(0))

    def input_specs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f = lambda sh, dt=jnp.int32: jax.ShapeDtypeStruct(sh, dt)
        emb = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            specs = {"tokens": f((B, S)), "labels": f((B, S))}
            if cfg.family == "encdec":
                specs["frames"] = f((B, cfg.num_prefix_embeddings, cfg.d_model), emb)
            if cfg.family == "vlm":
                specs["patches"] = f((B, cfg.num_prefix_embeddings, cfg.d_model), emb)
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": f((B, S))}
            if cfg.family == "encdec":
                specs["frames"] = f((B, cfg.num_prefix_embeddings, cfg.d_model), emb)
            if cfg.family == "vlm":
                specs["patches"] = f((B, cfg.num_prefix_embeddings, cfg.d_model), emb)
            return specs
        return {"token": f((B,))}  # decode

    def cache_specs(self, shape: ShapeConfig, kv_dtype: Optional[str] = None):
        return jax.eval_shape(
            functools.partial(T.make_decode_cache, self.cfg, shape.global_batch,
                              shape.seq_len, dtype=kv_dtype))


def build(cfg: ModelConfig, parallel: Optional[ParallelConfig] = None) -> Model:
    return Model(
        cfg=cfg,
        parallel=parallel,
        init_params=functools.partial(T.init_params, cfg),
        loss_fn=functools.partial(T.loss_fn, cfg, parallel),
        prefill_fn=functools.partial(T.prefill_fn, cfg, parallel),
        extend_fn=functools.partial(T.extend_fn, cfg, parallel),
        decode_fn=functools.partial(T.decode_fn, cfg, parallel),
    )
