"""Mamba2 (SSD) block — used by the zamba2-7b hybrid backbone.

Per head ``h`` with scalar decay, state ``S`` in R^{P x N} (P=head_dim,
N=d_state):
    a_t = exp(-dt_t * A_h)                      (dt_t = softplus(raw), A_h > 0)
    S_t = a_t S_{t-1} + (dt_t x_t) B_t^T
    y_t = S_t C_t + D_h x_t

Train/prefill use the chunked SSD form (two matmuls per chunk + scanned
state carry); decode is the O(1) recurrent update. The causal depthwise
conv (width 4) over x/B/C carries its last ``width-1`` inputs as decode
state. Per-token log-decay is clamped to LOG_A_MIN so the intra-chunk
exp(-cumsum) stays in fp32 range (see rwkv6.py for the same reasoning).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from repro.models.layers import dense_init, rmsnorm, scan_chunk_for

CHUNK = 32
LOG_A_MIN = -1.5


def set_ssd_chunk(n: int) -> None:
    """Tune the SSD chunk (§Perf H9): the inter-chunk state ([B,H,P,N] per
    layer) round-trips once per chunk, so state traffic scales with S/chunk
    while the intra-chunk O(C²) tile stays VMEM-sized well past C=128."""
    global CHUNK
    CHUNK = n


def chunk_for(S: int) -> int:
    """SSD chunk for a segment of length S; ``mamba2_block`` with the
    carried (ssm, conv) state is the exact sequential continuation."""
    return scan_chunk_for(S, CHUNK)


def mamba2_params(key, cfg, num_layers=None):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    H = d_in // P
    W = cfg.ssm_conv_width
    ks = jax.random.split(key, 6)
    L = () if num_layers is None else (num_layers,)
    dt = jnp.dtype(cfg.dtype)
    conv_dim = d_in + 2 * N
    return {
        # in_proj -> [z (gate, d_in), xBC (conv stream), dt (H)]
        "w_in": dense_init(ks[0], (*L, d, 2 * d_in + 2 * N + H), dt, d),
        "conv_w": dense_init(ks[1], (*L, W, conv_dim), dt, W),
        "conv_b": jnp.zeros((*L, conv_dim), dt),
        "A_log": jnp.zeros((*L, H), jnp.float32),          # A = exp(A_log) > 0
        "dt_bias": jnp.zeros((*L, H), jnp.float32),
        "D": jnp.ones((*L, H), jnp.float32),
        "ssm_norm": jnp.ones((*L, d_in), dt),
        "w_out": dense_init(ks[2], (*L, d_in, d), dt, d_in),
        "ln": jnp.ones((*L, d), dt),
    }


def _causal_conv(x, w, b, conv_state):
    """Depthwise causal conv. x: [B,S,C]; w: [W,C]; conv_state: [B,W-1,C]."""
    W = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else conv_state
    return jax.nn.silu(out + b[None, None]), new_state


def ssd_chunked(x, dt_v, Bm, Cm, A, state0, chunk: int = CHUNK):
    """Chunked SSD scan.

    x: [B,S,H,P]; dt_v: [B,S,H]; Bm/Cm: [B,S,N]; A: [H]; state0: [B,H,P,N].
    Returns (y [B,S,H,P], state [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, f"S={S} % chunk={chunk}"
    n = S // chunk
    f32 = jnp.float32
    loga = jnp.clip(-dt_v.astype(f32) * A[None, None].astype(f32), LOG_A_MIN, 0.0)
    xd = x.astype(f32) * dt_v.astype(f32)[..., None]            # dt-weighted input

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(Bsz, n, chunk, *a.shape[2:]), 1, 0)

    xc, lc, bc, cc = map(to_chunks, (xd, loga, Bm.astype(f32), Cm.astype(f32)))
    tri = jnp.tril(jnp.ones((chunk, chunk), f32))                # inclusive causal

    def body(S0, xs):
        x_i, l_i, b_i, c_i = xs          # [B,C,H,P], [B,C,H], [B,C,N], [B,C,N]
        cum = jnp.cumsum(l_i, axis=1)    # [B,C,H] inclusive
        # intra-chunk: y_t += sum_{j<=t} exp(cum_t - cum_j) (C_t.B_j) xd_j
        gram = jnp.einsum("btn,bjn->btj", c_i, b_i)              # [B,C,C]
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # [B,C,C,H]
        s = gram[..., None] * dec * tri[None, :, :, None]
        intra = jnp.einsum("btjh,bjhp->bthp", s, x_i)
        # inter-chunk: y_t += exp(cum_t) C_t . S0
        inter = jnp.einsum("bth,bhpn,btn->bthp", jnp.exp(cum), S0, c_i)
        # state: S1 = exp(cum_last) S0 + sum_j exp(cum_last - cum_j) xd_j b_j^T
        last = cum[:, -1:, :]                                    # [B,1,H]
        kdec = jnp.exp(last - cum)                               # [B,C,H]
        S1 = jnp.exp(last[:, 0])[..., None, None] * S0 + jnp.einsum(
            "bjh,bjhp,bjn->bhpn", kdec, x_i, b_i)
        return S1, intra + inter

    state, y = lax.scan(body, state0.astype(f32), (xc, lc, bc, cc))
    y = jnp.moveaxis(y, 0, 1).reshape(Bsz, S, H, P)
    return y, state


def ssd_decode(x, dt_v, Bm, Cm, A, state):
    """One-token SSD update. x: [B,H,P]; dt_v: [B,H]; Bm/Cm: [B,N]."""
    f32 = jnp.float32
    loga = jnp.clip(-dt_v.astype(f32) * A[None].astype(f32), LOG_A_MIN, 0.0)
    xd = x.astype(f32) * dt_v.astype(f32)[..., None]
    new_state = jnp.exp(loga)[..., None, None] * state + jnp.einsum(
        "bhp,bn->bhpn", xd, Bm.astype(f32))
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y, new_state


def mamba2_state_init(cfg, batch: int, num_layers: int):
    d_in = cfg.ssm_expand * cfg.d_model
    N, P, W = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv_width
    H = d_in // P
    return {
        "ssm": jnp.zeros((num_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((num_layers, batch, W - 1, d_in + 2 * N), jnp.float32),
    }


def mamba2_block(cfg, p, x, state_slice):
    """Pre-norm Mamba2 block. x: [B,S,D]; state_slice: {'ssm','conv'} per layer."""
    B, S, D = x.shape
    d_in = cfg.ssm_expand * D
    N, P = cfg.ssm_state, cfg.ssm_head_dim
    H = d_in // P
    h = rmsnorm({"scale": p["ln"]}, x, cfg.norm_eps)
    proj = h @ p["w_in"]
    z, xbc, dt_raw = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state_slice["conv"])
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    A = jnp.exp(p["A_log"])
    xs = shard(xs.reshape(B, S, H, P), "batch", None, "state_heads", None)
    if S == 1:
        y, new_ssm = ssd_decode(xs[:, 0], dt_v[:, 0], Bm[:, 0], Cm[:, 0], A,
                                state_slice["ssm"])
        y = y[:, None]
    else:
        y, new_ssm = ssd_chunked(xs, dt_v, Bm, Cm, A, state_slice["ssm"],
                                 chunk=chunk_for(S))
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm({"scale": p["ssm_norm"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["w_out"]
    return x + shard(out, "batch", "seq", None), {
        "ssm": new_ssm, "conv": new_conv.astype(jnp.float32)}
