"""Compiled-artifact analysis: trip-count-aware HLO costs, roofline terms."""
from repro.analysis.hlo import HloCost, analyze

__all__ = ["HloCost", "analyze"]
