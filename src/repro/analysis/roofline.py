"""Roofline term assembly from dry-run artifacts (assignment §ROOFLINE).

Per (arch × shape × mesh) cell, from the compiled per-device program:

    compute    = flops_dev / peak_FLOPs_chip            [s]
    memory     = hbm_bytes_dev / hbm_bw_chip            [s]
    collective = collective_bytes_dev / link_bw_chip    [s]

Hardware constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI, per TPU v5e chip.

``flops_dev`` / ``collective_bytes_dev`` come from the trip-count-aware
HLO analysis stored by dryrun.py (``hlo_cost``). ``hbm_bytes_dev`` uses
the dot-operand traffic from the same walk as an HBM proxy, floored by
the analytic weight/cache stream for the cell (whichever is larger —
dot operands under-count elementwise traffic; the analytic floor
captures the weight/KV streaming that defines decode).

MODEL_FLOPS (useful compute) = 6·N_active·tokens for train, 2·N_active·
tokens (+ attention term) for prefill/decode; the useful-compute ratio
MODEL_FLOPS / (flops_dev × chips) flags remat/redundancy waste.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
BYTES_PARAM = 2


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: dominant term (perfect overlap assumption)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / roofline step time ∈ (0, 1]."""
        chips = max(self.chips, 1)
        useful_s = self.model_flops / (chips * PEAK_FLOPS)
        return useful_s / max(self.step_time_s, 1e-30)

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "compute_s": self.compute_s,
            "memory_s": self.memory_s, "collective_s": self.collective_s,
            "dominant": self.dominant, "step_time_s": self.step_time_s,
            "model_flops": self.model_flops,
            "hlo_flops_total": self.hlo_flops_total,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs per step: 6·N_active·D (train) / 2·N_active·D (+attn)."""
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return cfg.flops_per_token(shape.seq_len, "train") * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return cfg.flops_per_token(shape.seq_len, "prefill") * tokens
    tokens = shape.global_batch                      # decode: 1 new token/seq
    return cfg.flops_per_token(shape.seq_len, "decode") * tokens


def analytic_memory_floor(cfg: ModelConfig, shape: ShapeConfig,
                          chips: int, *, microbatches: int = 4,
                          model_axis: int = 16,
                          kv_bytes_per_el: int = 2) -> float:
    """Per-device HBM bytes floor: weight stream + KV/state stream.

    Train shards params over the whole mesh (FSDP) and streams them
    fwd+bwd+remat ≈ 3 passes per microbatch. Serving shards params over
    the model axis only (replicated across data) — the weight stream per
    decode step divides by TP, not by the whole mesh. Decode additionally
    streams the cache shard once per token.
    """
    if shape.kind == "train":
        w_dev = cfg.param_count() * BYTES_PARAM / max(chips, 1)
        return 3.0 * microbatches * w_dev
    w_dev = cfg.param_count() * BYTES_PARAM / max(model_axis, 1)
    if shape.kind == "prefill":
        return w_dev
    cache_dev = (cfg.kv_bytes_per_token(kv_bytes_per_el) * shape.seq_len
                 * shape.global_batch / max(chips, 1))
    return w_dev + cache_dev


def paged_decode_memory_s(cfg: ModelConfig, mean_len: float, batch: int,
                          max_seq: int, *, chips: int = 1,
                          model_axis: int = 16,
                          kv_bytes_per_el: int = 2) -> tuple[float, float]:
    """Projected per-step decode memory time (dense, paged) in seconds.

    Dense decode streams the full ``max_seq`` cache row per slot; paged
    decode streams only the live pages — bytes scale with ``mean_len``
    (rounded up to whole pages is a second-order term at page 16). The
    ratio dense/paged is the roofline ceiling on the paged decode win at
    a given ``max_seq / mean_len`` overprovisioning ratio; the measured
    sweep in benchmarks/bench_kernels.py sits under it.
    """
    w_dev = cfg.param_count() * BYTES_PARAM / max(model_axis, 1)
    per_tok = cfg.kv_bytes_per_token(kv_bytes_per_el)
    dense = w_dev + per_tok * max_seq * batch / max(chips, 1)
    paged = w_dev + per_tok * mean_len * batch / max(chips, 1)
    return dense / HBM_BW, paged / HBM_BW


def load_cell(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def terms_from_report(rep: dict) -> RooflineTerms:
    arch, shape_name = rep["arch"], rep["shape"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = rep.get("mesh", {})
    chips = 1
    for v in mesh.values():
        chips *= int(v)
    hc = rep.get("hlo_cost", {}) or {}
    flops_dev = float(hc.get("flops", 0.0))
    coll_dev = float(hc.get("collective_bytes", 0.0))
    dot_bytes_dev = float(hc.get("dot_bytes", 0.0))
    mb = rep.get("num_microbatches") or 4
    kv_el = 1 if rep.get("kv_cache_dtype") == "int8" else 2
    mem_floor = analytic_memory_floor(
        cfg, shape, chips, microbatches=mb,
        model_axis=int(mesh.get("model", 16)), kv_bytes_per_el=kv_el)
    if shape.kind == "decode":
        # decode runs through the Pallas split-K kernel on TPU: HBM traffic
        # is weights-once + cache-once at the STORED dtype (the XLA graph's
        # fp32-upcast dot operands are a lowering artifact the kernel's
        # fused dequant eliminates — validated in tests/test_kv_int8.py).
        mem_dev = mem_floor
    else:
        mem_dev = max(dot_bytes_dev, mem_floor)
    mf = model_flops(cfg, shape)
    total_hlo = flops_dev * chips
    return RooflineTerms(
        arch=arch, shape=shape_name,
        mesh="x".join(str(v) for v in mesh.values()),
        chips=chips,
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=mem_dev / HBM_BW,
        collective_s=coll_dev / LINK_BW,
        model_flops=mf,
        hlo_flops_total=total_hlo,
        useful_ratio=mf / total_hlo if total_hlo else 0.0,
    )


def load_table(dryrun_dir: str, *, pod: str = "pod1",
               tag: str = "") -> list:
    out = []
    for fname in sorted(os.listdir(dryrun_dir)):
        if not fname.endswith(".json"):
            continue
        parts = fname[:-5].split("__")
        if len(parts) < 3 or parts[2] != pod:
            continue
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        rep = load_cell(os.path.join(dryrun_dir, fname))
        if rep.get("ok"):
            out.append(terms_from_report(rep))
    return out
