"""Trip-count-aware optimized-HLO cost analyzer.

XLA's built-in ``compiled.cost_analysis()`` counts every while-loop body
ONCE (verified empirically: a 10-iteration scanned matmul reports 1x the
body FLOPs). Our models scan over layers and microbatches, so the built-in
numbers undercount by 1-2 orders of magnitude. This module re-derives
costs from ``compiled.as_text()`` with loop trip counts applied:

  * parse the module into named computations;
  * recover each while loop's trip count from the integer constant in its
    condition computation (scan lowers to ``iter < K``);
  * walk the call graph from ENTRY, multiplying by trip counts; and
  * accumulate, per visited op weighted by its multiplier:
      - dot FLOPs        2 x prod(result_shape) x prod(contracting dims)
      - dot bytes        lhs + rhs + result       (HBM-traffic proxy)
      - collective bytes operand sizes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute

All shapes in optimized HLO are post-SPMD (per-device), so every total is
per-device — exactly what the roofline terms need.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "s64": 8, "u64": 8, "u16": 2, "s16": 2,
          "pred": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
          "c64": 8, "c128": 16}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")   # nested () in args
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_TUPLE_OP = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*\(")
_CALL_ATTR = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_COLLECTIVE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_DOT = re.compile(r"\b(?:dot|dot_general[\w.]*)\(")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _BYTES.get(dtype, 4)


@dataclass
class Op:
    name: str
    dtype: str
    dims: str
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    ops: dict = field(default_factory=dict)       # register -> Op
    lines: list = field(default_factory=list)


@dataclass
class HloCost:
    flops: float = 0.0
    dot_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = field(default_factory=dict)
    while_trips: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {"flops": self.flops, "dot_bytes": self.dot_bytes,
                "collective_bytes": self.collective_bytes,
                "collective_by_kind": dict(self.collective_by_kind),
                "while_trips": dict(self.while_trips)}


def parse_computations(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(2),
                                  is_entry=bool(m.group(1)))
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            cur.lines.append(line)
            m = _OP_LINE.match(line)
            if m:
                cur.ops[m.group(1)] = Op(m.group(1), m.group(2),
                                         m.group(3), line)
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the condition ≈ the loop bound."""
    best = 1
    for line in cond.lines:
        for c in _CONST_INT.findall(line):
            best = max(best, int(c))
    return best


def _operand_names(line: str) -> list:
    """Register names inside the op's first argument list."""
    m = _OPERANDS.search(line[line.find("=") + 1:])
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def analyze(text: str) -> HloCost:
    comps = parse_computations(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost()
    cost = HloCost()

    def visit(comp: Computation, mult: float, depth: int = 0):
        if depth > 50:
            return
        for line in comp.lines:
            # --- while loops: recurse into the body with the trip count
            if " while(" in line:
                m = re.search(r"condition=%?([\w.\-]+).*body=%?([\w.\-]+)",
                              line)
                if not m:
                    m2 = re.search(r"body=%?([\w.\-]+).*condition=%?([\w.\-]+)",
                                   line)
                    if not m2:
                        continue
                    body_n, cond_n = m2.group(1), m2.group(2)
                else:
                    cond_n, body_n = m.group(1), m.group(2)
                trips = _trip_count(comps[cond_n]) if cond_n in comps else 1
                cost.while_trips[body_n] = trips
                if body_n in comps:
                    visit(comps[body_n], mult * trips, depth + 1)
                continue
            # --- collectives (count -start once, skip -done)
            mc = _COLLECTIVE.search(line)
            if mc and "-done" not in line:
                kind = mc.group(1)
                nbytes = 0
                for op_name in _operand_names(line):
                    op = comp.ops.get(op_name)
                    if op is not None:
                        nbytes += _shape_bytes(op.dtype, op.dims)
                cost.collective_bytes += nbytes * mult
                cost.collective_by_kind[kind] = \
                    cost.collective_by_kind.get(kind, 0.0) + nbytes * mult
            # --- dots
            if _DOT.search(line):
                mo = _OP_LINE.match(line)
                if mo:
                    out_elems = _shape_elems(mo.group(3))
                    out_bytes = _shape_bytes(mo.group(2), mo.group(3))
                    ops_n = _operand_names(line)
                    lhs = comp.ops.get(ops_n[0]) if ops_n else None
                    rhs = comp.ops.get(ops_n[1]) if len(ops_n) > 1 else None
                    k = 1
                    mcn = _CONTRACT.search(line)
                    if mcn and lhs is not None:
                        ldims = [int(x) for x in lhs.dims.split(",") if x]
                        for ci in mcn.group(1).split(","):
                            if ci and int(ci) < len(ldims):
                                k *= ldims[int(ci)]
                    cost.flops += 2.0 * out_elems * k * mult
                    nb = out_bytes
                    for o in (lhs, rhs):
                        if o is not None:
                            nb += _shape_bytes(o.dtype, o.dims)
                    cost.dot_bytes += nb * mult
            # --- nested calls (fusion kLoop/kOutput, call, conditional)
            for mcall in _CALL_ATTR.finditer(line):
                if "body=" in mcall.group(0) or "condition=" in mcall.group(0):
                    continue        # whiles handled above
                for name in re.findall(r"[\w.\-]+", mcall.group(1)):
                    if name in comps:
                        visit(comps[name], mult, depth + 1)

    visit(entry, 1.0)
    return cost
