"""Atomic async sharded checkpoints with manifest + restart."""
from repro.checkpoint.store import CheckpointStore

__all__ = ["CheckpointStore"]
