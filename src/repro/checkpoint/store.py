"""Atomic, async, sharded checkpointing with manifest + restart.

Fault-tolerance substrate for 1000+-node posture:

  * **request transcripts** — ``TranscriptSnapshot`` is the serving-side
    checkpoint record: everything needed to resume a preempted in-flight
    request on *another* engine with a bit-identical continuation
    (prompt, generated tokens, and the sampling seed that keys the
    stream). ``save_transcripts``/``load_transcripts`` persist a site's
    drained work atomically (tmp + rename), the same protocol the
    parameter checkpoints use;

  * **atomic** — a checkpoint directory is staged as ``step_N.tmp`` and
    ``os.rename``d into place only after every leaf file and the manifest
    have been fsync'd; readers can never observe a torn checkpoint;
  * **async** — ``save_async`` snapshots device arrays to host (blocking
    only on device→host copy) and writes in a background thread so the
    train loop overlaps I/O with the next steps;
  * **sharded** — each leaf is saved as its own ``.npy`` under a
    tree-path-derived name; at restore time leaves are re-sharded to the
    *current* mesh (elastic re-mesh after a pod/site loss just restores
    with a different ParallelConfig — distributed/elastic.py);
  * **manifest** — JSON with step, leaf paths/shapes/dtypes and a fleet
    config hash; ``latest_step`` scans it for restart;
  * retention — keep the newest ``keep`` checkpoints.

On a real multi-host fleet each host writes its addressable shards and
the manifest is committed by host 0 after a barrier; this container is
single-process so the code path is the degenerate one-host case (the
layout and atomicity protocol are the same).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TranscriptSnapshot:
    """A preempted request's resumable state — the serving checkpoint.

    Carries the full transcript (prompt + every token generated so far)
    plus the sampling ``seed`` that keys the request's stream. Resuming
    replays the transcript through the prefill-from-cache path and
    continues sampling at token index ``len(tokens)`` under the carried
    seed, so the continuation is bit-identical to the uninterrupted run
    — on *any* engine serving the same model, whatever that engine's own
    seed is. ``attempts`` is the failover retry budget consumed so far.
    """
    rid: int
    prompt: np.ndarray            # [S] int32 token ids
    tokens: list                  # tokens generated before preemption
    max_new_tokens: int
    temperature: float
    seed: int                     # sampling seed that keys this stream
    arrival_s: float = 0.0
    prefill_done_s: Optional[float] = None   # original TTFT is preserved
    attempts: int = 0
    deadline_s: Optional[float] = None

    @classmethod
    def from_request(cls, req: Any, seed: int) -> "TranscriptSnapshot":
        """Snapshot a live ``serving.engine.Request`` (duck-typed)."""
        return cls(rid=int(req.rid),
                   prompt=np.asarray(req.prompt, np.int32),
                   tokens=list(req.tokens),
                   max_new_tokens=int(req.max_new_tokens),
                   temperature=float(req.temperature),
                   seed=int(seed),
                   arrival_s=float(req.arrival_s),
                   prefill_done_s=req.prefill_done_s,
                   attempts=int(req.attempts),
                   deadline_s=req.deadline_s)

    def to_json(self) -> dict:
        return {"rid": int(self.rid),
                "prompt": np.asarray(self.prompt).tolist(),
                "tokens": [int(t) for t in self.tokens],
                "max_new_tokens": int(self.max_new_tokens),
                "temperature": float(self.temperature),
                "seed": int(self.seed),
                "arrival_s": float(self.arrival_s),
                "prefill_done_s": self.prefill_done_s,
                "attempts": int(self.attempts),
                "deadline_s": self.deadline_s}

    @classmethod
    def from_json(cls, d: dict) -> "TranscriptSnapshot":
        return cls(rid=int(d["rid"]),
                   prompt=np.asarray(d["prompt"], np.int32),
                   tokens=[int(t) for t in d["tokens"]],
                   max_new_tokens=int(d["max_new_tokens"]),
                   temperature=float(d["temperature"]),
                   seed=int(d["seed"]),
                   arrival_s=float(d.get("arrival_s", 0.0)),
                   prefill_done_s=d.get("prefill_done_s"),
                   attempts=int(d.get("attempts", 0)),
                   deadline_s=d.get("deadline_s"))


def save_transcripts(path: str, snaps: list, extra: Optional[dict] = None) -> str:
    """Atomically persist a drained site's transcript snapshots."""
    d = os.path.dirname(os.path.abspath(path))
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"extra": extra or {},
                   "transcripts": [s.to_json() for s in snaps]}, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def load_transcripts(path: str) -> tuple[list, dict]:
    with open(path) as f:
        d = json.load(f)
    return ([TranscriptSnapshot.from_json(s) for s in d["transcripts"]],
            d.get("extra", {}))


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path) or "leaf"
        out.append((name, leaf))
    return out, treedef


@dataclass
class CheckpointStore:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ----------------------------------------------------------- write
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        """Synchronous atomic save. Returns the committed directory."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None) -> None:
        """Device→host copy now; file I/O in a background thread."""
        self.wait()                                   # one in flight at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        t = threading.Thread(target=self._write,
                             args=(step, host_tree, extra or {}), daemon=True)
        t.start()
        self._pending = t

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree, extra: dict) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        named, _ = _flatten_with_names(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": []}
        for i, (name, leaf) in enumerate(named):
            fname = f"{i:04d}_{name[:80]}.npy"
            path = os.path.join(tmp, fname)
            arr = np.asarray(leaf)
            true_dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or true_dtype == "bfloat16":
                # ml_dtypes (bf16/fp8) round-trip as raw uint views
                arr = arr.view(f"u{arr.dtype.itemsize}")
            with open(path, "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append(
                {"file": fname, "shape": list(np.shape(leaf)),
                 "dtype": true_dtype})
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                         # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ----------------------------------------------------------- read
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d,
                                               "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``. Returns (tree, extra).

        ``shardings``: optional pytree of NamedShardings matching ``like``
        — leaves are device_put onto the *current* mesh (elastic restore).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        named, treedef = _flatten_with_names(like)
        if len(named) != len(manifest["leaves"]):
            raise ValueError(
                f"leaf count mismatch: ckpt {len(manifest['leaves'])} vs "
                f"model {len(named)}")
        leaves = []
        for (name, leaf), meta in zip(named, manifest["leaves"]):
            arr = np.load(os.path.join(d, meta["file"]))
            want_dtype = np.asarray(leaf).dtype if hasattr(leaf, "dtype") \
                else arr.dtype
            if arr.dtype.kind == "u" and str(want_dtype) != str(arr.dtype):
                arr = arr.view(want_dtype)        # bf16/fp8 raw-uint round-trip
            if list(arr.shape) != list(np.shape(leaf)):
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {np.shape(leaf)}")
            leaves.append(arr.astype(want_dtype))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else
                jnp.asarray(x), tree, shardings)
        else:
            tree = jax.tree.map(jnp.asarray, tree)
        return tree, manifest.get("extra", {})
