"""Site-local serving engine: KV-cache slots + batched continuous batching.

This is the per-site engine the paper assumes (vLLM in their testbed) —
built here in JAX because Heron needs a real serving substrate to route
into. Design:

  * a fixed pool of ``max_batch`` cache *slots*; each slot owns one
    sequence's decode cache (KV / recurrent state, family-specific pytree);
  * **continuous batching**: new requests are admitted into free slots via
    a *batched admission pipeline* (below); every engine step runs ONE
    batched decode over all slots (fixed shapes → one compiled program);
  * finished sequences retire their slot immediately via ``release_slot``
    — no batch barriers;
  * per-request TTFT / TBT / E2E metrics (means and p50/p99 tails) against
    the class SLOs, which is what Heron's goodput accounting consumes.

Batched admission pipeline (the burst path — a site absorbing a drained
neighbour's traffic sees all of its requests at once):

  1. waiting requests are grouped by the largest power-of-2 prefix of
     their prompt (*bucket*) and prefilled TOGETHER — one compiled
     ``prefill`` call per (bucket, pow2-padded batch) shape;
  2. each prompt's tail (prompt minus bucket) runs through DESCENDING
     power-of-2 chunks of ``Model.extend_fn`` — prefill continued from the
     engine cache at per-row offsets. Tails are the binary digits of the
     remaining length, so a round admits every slot that has the current
     chunk-size bit set: O(log S) compiled calls shared across the whole
     admission group, instead of up to S/2 serial B=1 decodes per request;
  3. a per-step admission token budget (``admit_token_budget``) bounds how
     many prompt tokens one ``step()`` may prefill, so already-live slots'
     TBT cannot balloon under a thundering herd (at least one request is
     always admitted so oversized prompts cannot starve).

The extend calls run at the engine's fixed batch with a row mask (masked
rows keep their old cache bits), so the compile cache stays
O(log max_seq) extend entries + O(log max_seq) x O(log max_batch) prefill
entries + one decode entry. Right-padding prompts instead would corrupt
recurrent/SSM states and shift last-token logits, so it is deliberately
not used. ``admit_mode="serial"`` keeps the old one-request-at-a-time
path (pow2-prefix prefill + B=1 decode tail) as the equivalence
reference.

Paged KV cache (``paged=True``)
-------------------------------
The dense layout burns a full ``max_seq`` cache row per slot and every
attention call streams all of it, live or dead. The paged layout
(``models.transformer.make_paged_decode_cache``; GQA attention-trunk
families — dense / moe / vlm / enc-dec self-attention, incl. int8; MLA
and recurrent families silently stay dense, their state is O(1) per
token) replaces rows with a shared pool of ``num_pages`` pages of
``page_size`` tokens addressed through a per-slot block table:

  * pages for a request's whole contract (prompt + max_new_tokens - 1)
    are reserved at admission from a host-side free list — admission
    REJECTS a request that could never fit the pool and simply *waits*
    when the pool is temporarily exhausted; nothing live is ever evicted
    to make room, and the admission error path returns every reserved
    page (no leak);
  * freed pages (``release_slot``, preempt, finish) recycle to any later
    request — fragmentation is impossible by construction since pages
    are interchangeable;
  * decode runs over the block table SLICED to the smallest power-of-2
    page count covering the live slots, so short sequences stop paying
    attention bandwidth for the dead tail of ``max_seq`` — the compile
    cache stays O(log max_seq/page_size) decode variants (admission
    extends run at the full table width, keyed only by chunk length,
    exactly like the dense path);
  * ``num_pages`` defaults to dense-equivalent capacity
    (``max_batch * max_seq / page_size``) but the two knobs decouple:
    the same byte budget can back far more *slots* than the dense
    layout could hold when typical sequences are short — that is where
    the paged burst-TTFT win comes from;
  * the paged attention math is BITWISE the dense math (the gathered
    page view carries identical live bits; the softmax denominator pads
    to max_seq — see ``models.layers.paged_view``), so token streams
    are bit-identical across ``paged`` on/off and every ``admit_mode``.

Async admission (``admit_mode="async"``)
----------------------------------------
"batched" still runs a whole admission wave to completion before the
step's decode — a burst stalls in-flight decodes for the full wave.
"async" splits admission across steps and interleaves it with decode:

  1. a persistent pending set carries each admitted-but-unfinished
     prompt tail (slot -> consumed offset) across steps, its slot's
     pages already reserved (the allocation buffer) while its cache
     fills chunk by chunk (the insertion buffer) — double-buffered in
     the JAX async-dispatch sense: the host schedules the next chunk's
     pages and inserts while the device still runs the previous
     dispatch, and the decode for live slots queues behind them without
     a host sync;
  2. a token-budget arbiter (``admit_token_budget``, default
     ``max_seq`` tokens per step) spends each step's budget on, in
     order: one guaranteed descending-pow-2 extend chunk for the oldest
     tails (no starvation), new-request bucket prefills, then leftover
     budget on more tail chunks — so fresh bursts never stall in-flight
     decodes for more than a bounded slice of work;
  3. the step's decode then runs over live slots with pending slots
     row-masked (dense: cache select; paged: their table rows sentinel
     out, so their in-flight pages are untouched).

``admit_mode="serial"`` still guarantees: exact one-request-at-a-time
admission order, one prefill + B=1 decode tail per request, and the
pinned reference token stream — "batched" and "async" are REQUIRED to
reproduce it bit-identically (per-(seed, rid, token-index) sampling keys
make streams independent of admission interleaving), which is what the
equivalence tests pin.

Sampling policy: every token draw uses a key derived from (engine seed,
request id, token index) — see ``serving.sampling.fold_keys`` — so a
request's token stream is bit-identical regardless of admission order,
batching, or slot placement. (Previous engines split one engine-global
key per step, which made streams depend on batch composition.) Per-row
temperatures still let greedy (t == 0) and sampled requests coexist in
one batched decode.

Cache insertion is family-agnostic: every cache leaf is [B]-batched at
axis 0 (1-D leaves like ``pos``) or axis 1 (stacked [L, B, ...] leaves),
so one ``dynamic_update_slice`` rule covers GQA/MLA/SSM/hybrid/enc-dec.

Request lifecycle (fault tolerance)
-----------------------------------
A request moves through::

    queued --admit--> admitted/live --finish--> completed
       |                  |   |
       | (watermark /     |   +--deadline--> timed_out
       |  oversize /      +--preempt--> snapshot --resume--> queued (again)
       |  deadline)                          |
       +--> rejected / timed_out             +--(budget spent)--> failed

Heron's premise is that sites *lose power mid-decode*. ``preempt(slots)``
snapshots each in-flight request's full transcript (prompt + generated
tokens) into a ``checkpoint.store.TranscriptSnapshot`` and frees the
slot; ``drain()`` is the site-death path (every live slot plus the
waiting queue). ``resume(snapshot)`` re-admits the transcript — the
whole prompt+generated prefix replays through the admission pipeline's
prefill-from-cache chunks, and sampling continues at token index
``len(tokens)``. Because every draw is keyed by (seed, rid, token-index)
and the snapshot carries the seed that keyed the stream, a preempted-
and-resumed request's token stream is **bit-identical** to the
uninterrupted run — on any engine serving the same model, regardless of
that engine's own seed. That identity is this module's pinned
correctness anchor (tests/test_faults.py), and it is also what makes
cross-site failover accounting honest: recovered tokens are real tokens
the user would have received anyway, never a divergent re-generation.

Backpressure and brownout: ``queue_watermark`` rejects new submissions
beyond a queue depth (fail fast under overload); ``set_brownout(frac)``
enters power-brownout mode — admissions shed their ``max_new_tokens``
to ``ceil(frac * requested)`` (graceful degradation instead of drops)
and the per-step admission token budget scales by ``frac``. Requests
may carry a ``deadline_s`` (absolute, engine clock) after which they
time out whether queued or live, and a ``not_before_s`` backoff gate
(see ``retry_backoff``) so failover retries don't thundering-herd a
surviving site. ``EngineMetrics`` keeps the watchdog ledger: lost vs
recovered vs duplicated tokens, preemptions, resumes, timeouts, shed
tokens — ``reconcile()`` checks the books balance.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import TranscriptSnapshot
from repro.configs.base import ModelConfig
from repro.stats import percentile
from repro.models.api import Model
from repro.serving.sampling import fold_idx, fold_keys, sample_batch


def retry_backoff(attempts: int, *, base: float = 0.05,
                  cap: float = 2.0) -> float:
    """Capped exponential backoff delay before retry ``attempts`` (1-based):
    ``min(base * 2**(attempts-1), cap)``. Deterministic (no jitter) so
    chaos runs replay exactly; the per-request sampling keys make jitter
    unnecessary for correctness."""
    return min(base * (2.0 ** max(attempts - 1, 0)), cap)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32 token ids
    max_new_tokens: int                 # TOTAL tokens (incl. resumed prefix)
    arrival_s: float = 0.0
    temperature: float = 0.0
    # fault-tolerance lifecycle
    seed: Optional[int] = None          # sampling-seed override; a resumed
    #                                     request carries its origin seed so
    #                                     its stream survives engine changes
    deadline_s: Optional[float] = None  # absolute deadline (engine clock)
    not_before_s: float = 0.0           # backoff gate for (re-)admission
    attempts: int = 0                   # admission/failover attempts so far
    resumed_from: int = 0               # tokens carried in from a snapshot
    # filled by the engine
    tokens: list = field(default_factory=list)
    prefill_done_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done_s is None:
            return None
        return self.prefill_done_s - self.arrival_s

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    @property
    def tbt(self) -> Optional[float]:
        if self.finish_s is None or len(self.tokens) < 2:
            return None
        return (self.finish_s - self.prefill_done_s) / max(len(self.tokens) - 1, 1)


def _insert_leaf(engine_leaf, req_leaf, slot: int):
    """Write a single-sequence cache leaf into slot ``slot``."""
    req_leaf = req_leaf.astype(engine_leaf.dtype)
    if engine_leaf.ndim == 1:                       # e.g. pos: [B]
        return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, (slot,))
    # stacked leaves: [L, B, ...] — batch at axis 1, write at origin elsewhere
    start = (0, slot) + (0,) * (engine_leaf.ndim - 2)
    return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, start)


@jax.jit
def insert_cache(engine_cache, req_cache, slot):
    """Insert a B=1 request cache into the engine's slot ``slot``."""
    return jax.tree.map(lambda e, r: _insert_leaf(e, r, slot),
                        engine_cache, req_cache)


@jax.jit
def insert_cache_rows(engine_cache, group_cache, slots):
    """Scatter a batched prefill cache into engine slots: row ``r`` of
    ``group_cache`` lands in slot ``slots[r]``, one compiled call (and one
    functional cache copy) per (bucket, batch) shape for the whole group.
    Out-of-range slot ids drop their row — how pow2 padding rows and their
    garbage prefill results are discarded."""
    def ins(e, g):
        g = g.astype(e.dtype)
        if e.ndim == 1:
            return e.at[slots].set(g, mode="drop")
        idx = (slice(None), slots) + tuple(slice(0, d) for d in g.shape[2:])
        return e.at[idx].set(g, mode="drop")

    return jax.tree.map(ins, engine_cache, group_cache)


@jax.jit
def insert_cache_pages(pool_kv, group_kv, page_map):
    """Scatter a batched DENSE prefill's kv cache ([L, kb, S, ...] leaves)
    into the engine's paged pools ([L, P, page, ...] leaves): row ``r``'s
    tokens land in physical pages ``page_map[r]`` ([kb, npages] int32,
    with ``npages = ceil(S / page)``). Sentinel entries (>= P) drop their
    page — how pow2 padding rows AND table entries beyond a slot's
    reservation are discarded. One compiled call per (bucket, batch)
    shape, exactly like the dense ``insert_cache_rows``."""
    def ins(pool, g):
        g = g.astype(pool.dtype)
        page = pool.shape[2]
        L, kb, S = g.shape[:3]
        npr = page_map.shape[1]
        if npr * page > S:
            g = jnp.pad(g, ((0, 0), (0, 0), (0, npr * page - S))
                        + ((0, 0),) * (g.ndim - 3))
        g = g.reshape(L, kb, npr, page, *g.shape[3:])
        return pool.at[:, page_map].set(g, mode="drop")

    return jax.tree.map(ins, pool_kv, group_kv)


# shared percentile helper (core.stats): empty samples report NaN, not a
# fake-perfect 0.0 — an engine that completed nothing has no tail
_pct = percentile


@dataclass
class EngineMetrics:
    completed: list
    rejected: list = field(default_factory=list)
    timed_out: list = field(default_factory=list)
    steps: int = 0
    prefills: int = 0          # requests admitted (one prefill each, logically)
    prefill_calls: int = 0     # compiled model dispatches spent on admission
    # watchdog ledger (preempt/resume fault tolerance)
    submitted: int = 0         # requests accepted into the queue
    preemptions: int = 0       # live slots snapshotted + freed
    evicted: int = 0           # snapshots handed out (preempted + drained)
    resumed: int = 0           # snapshots re-admitted on this engine
    recovered_tokens: int = 0  # tokens carried into a resume (not re-sampled)
    lost_tokens: int = 0       # generated tokens discarded (timeout/failure)
    duplicated_tokens: int = 0 # tokens re-emitted past a delivery high-water
    #                            mark — MUST stay 0; nonzero means a request
    #                            was resumed behind its own stream
    shed_tokens: int = 0       # max_new_tokens haircut under brownout
    # decode-utilization counters (async admission overlap accounting)
    decode_steps: int = 0      # decode dispatches with >= 1 live row
    extend_chunks: int = 0     # masked extend-chunk dispatches (admission
    #                            tails interleaved between decode steps)
    admit_stall_steps: int = 0 # steps that did admission work with ZERO
    #                            live decode rows — pure stalls the async
    #                            pipeline exists to shrink

    def summary(self) -> dict:
        ttfts = [r.ttft for r in self.completed if r.ttft is not None]
        e2es = [r.e2e for r in self.completed if r.e2e is not None]
        tbts = [r.tbt for r in self.completed if r.tbt is not None]
        f = lambda xs: float(np.mean(xs)) if xs else 0.0
        out = {"num_completed": len(self.completed), "steps": self.steps,
               "prefills": self.prefills, "prefill_calls": self.prefill_calls,
               "rejected": len(self.rejected),
               "timed_out": len(self.timed_out),
               "submitted": self.submitted,
               "preemptions": self.preemptions,
               "resumed": self.resumed,
               "served_tokens": sum(len(r.tokens) for r in self.completed),
               "recovered_tokens": self.recovered_tokens,
               "lost_tokens": self.lost_tokens,
               "duplicated_tokens": self.duplicated_tokens,
               "shed_tokens": self.shed_tokens,
               "decode_steps": self.decode_steps,
               "extend_chunks": self.extend_chunks,
               "admit_stall_steps": self.admit_stall_steps,
               "mean_ttft": f(ttfts), "mean_tbt": f(tbts), "mean_e2e": f(e2es)}
        # tail percentiles: what the goodput accounting and the serving
        # bench consume — burst admission shows up in p99, not the mean
        for name, xs in (("ttft", ttfts), ("tbt", tbts), ("e2e", e2es)):
            out[f"p50_{name}"] = _pct(xs, 50)
            out[f"p99_{name}"] = _pct(xs, 99)
        return out


class ServingEngine:
    """Continuous-batching engine over one model replica.

    ``admit_mode``: "batched" (default — grouped prefill + chunked extend
    tails), "serial" (the reference: one request at a time, B=1 decode
    tail) or "async" (admission split across steps and interleaved with
    decode under a token-budget arbiter). Token streams are bit-identical
    across all three.
    ``admit_token_budget``: max prompt tokens admitted per step (None =
    unlimited for batched/serial, ``max_seq`` for async); bounds TBT
    inflation for live slots under bursts.
    ``queue_watermark``: max waiting-queue depth before ``submit`` rejects
    (None = unbounded) — the fail-fast half of backpressure; the
    shed-to-shorter half is ``set_brownout``.
    ``paged=True`` swaps the dense per-slot cache rows for the shared
    page pool + block tables (see module docstring); ``page_size`` tokens
    per page, ``num_pages`` pool size (default: dense-equivalent
    capacity). Families without a paged layout (MLA, recurrent) silently
    stay dense.
    """

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_token: int = -1, seed: int = 0,
                 clock=None, admit_mode: str = "batched",
                 admit_token_budget: Optional[int] = None,
                 queue_watermark: Optional[int] = None,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None):
        if admit_mode not in ("batched", "serial", "async"):
            raise ValueError(f"admit_mode {admit_mode!r}")
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos = eos_token
        self.admit_mode = admit_mode
        self.admit_token_budget = admit_token_budget
        self.queue_watermark = queue_watermark
        self.seed = seed
        self.brownout = 1.0
        self._base_key = jax.random.key(seed)
        self._clock = clock or time.perf_counter
        self._has_deadlines = False

        from repro.models import transformer as T
        self.paged = bool(paged) and T.supports_paged_cache(self.cfg)
        if self.paged:
            if page_size < 1 or page_size & (page_size - 1):
                raise ValueError(f"page_size {page_size} not a power of 2")
            if max_seq % page_size:
                raise ValueError("max_seq must be a multiple of page_size")
            self.page_size = page_size
            self._maxP = max_seq // page_size
            self.num_pages = (num_pages if num_pages is not None
                              else max_batch * self._maxP)
            cache = T.make_paged_decode_cache(
                self.cfg, max_batch, max_seq, page_size=page_size,
                num_pages=self.num_pages)
            # the block table lives HOST-side (allocation is host work);
            # the span marker is injected per call — the device cache
            # carries only the pools + pos (+ enc_kv)
            self._span = cache.pop("span")
            cache.pop("table")
            self.cache = cache
            self._tbl = np.full((max_batch, self._maxP), self.num_pages,
                                np.int32)
            self._free_pages = list(range(self.num_pages - 1, -1, -1))
            self.slot_pages: list[list[int]] = [[] for _ in range(max_batch)]
            self._slot_len = np.zeros((max_batch,), np.int64)
        else:
            self.page_size = 0
            self.num_pages = 0
            self._maxP = 0
            self.cache = T.make_decode_cache(self.cfg, max_batch, max_seq)
        self.active: list[Optional[Request]] = [None] * max_batch
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.new_counts = [0] * max_batch
        # per-slot request base keys: fold_in(key(seed), rid), set at
        # admission; step() folds the token index on top (fold_idx), which
        # is bitwise fold_keys(base, rid, idx) — but lets a resumed request
        # carry its ORIGIN seed onto this engine (cross-engine identity)
        self._slot_keys = fold_keys(self._base_key,
                                    jnp.zeros((max_batch,), jnp.int32),
                                    jnp.zeros((max_batch,), jnp.int32))
        self.waiting: deque[Request] = deque()
        self.metrics = EngineMetrics(completed=[])
        # async mode: admitted-but-unfinished prompt tails carried across
        # steps (slot -> [req, full_prompt, consumed]); the slot's pages /
        # cache row are already reserved while the arbiter fills them
        self._pend: dict[int, list] = {}
        self._decode = jax.jit(model.decode_fn)
        self._prefill = jax.jit(model.prefill_fn)
        self._extend = jax.jit(self._masked_extend)
        self._extend_paged = jax.jit(self._masked_extend_paged)
        self._decode_masked = jax.jit(self._masked_decode)
        # zeros template for the serial-mode B=1 prompt-tail continuation;
        # built lazily — batched mode (the default) never needs it
        self._b1_cache = None

    # --------------------------------------------------------------- admit
    def submit(self, req: Request) -> bool:
        """Queue a request. Returns False (and records a rejection) when
        the queue is past ``queue_watermark`` — backpressure fails fast
        instead of letting deadlines rot in an unbounded queue."""
        self.metrics.submitted += 1
        if (self.queue_watermark is not None
                and len(self.waiting) >= self.queue_watermark):
            req.finish_s = self._clock()
            self.metrics.rejected.append(req)
            return False
        if req.deadline_s is not None:
            self._has_deadlines = True
        self.waiting.append(req)
        return True

    def _request_base_key(self, req: Request):
        """fold_in(key(seed), rid) — the request's stream base. A resumed
        request's carried ``seed`` overrides the engine seed, so the
        stream it continues is the one its origin engine started."""
        base = (self._base_key if req.seed is None
                else jax.random.key(req.seed))
        return jax.random.fold_in(base, req.rid)

    @staticmethod
    def _effective_prompt(req: Request) -> np.ndarray:
        """What admission must prefill: the prompt plus every token already
        generated before a preemption — replaying the transcript rebuilds
        the decode cache exactly as the uninterrupted run had it."""
        if not req.tokens:
            return np.asarray(req.prompt, np.int32)
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.tokens, np.int32)])

    def _masked_extend(self, params, tokens, mask, cache):
        """One extend chunk over the full engine cache; rows with
        ``mask[b] == False`` keep their old cache bits (so live decode
        slots and idle slots are untouched). Compiled once per chunk
        length — the engine batch is fixed."""
        logits, new_cache = self.model.extend_fn(params, {"tokens": tokens},
                                                 cache)

        def sel(new, old):
            m = mask if new.ndim <= 1 else mask.reshape(
                (1, new.shape[1]) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        return logits, jax.tree.map(sel, new_cache, cache)

    def _masked_extend_paged(self, params, tokens, mask, cache):
        """Paged twin of ``_masked_extend``: masked rows' block-table rows
        are swapped for the sentinel INSIDE the jit, so their page writes
        drop at the scatter (no post-hoc cache select over the shared
        pools — a pool page belongs to exactly one slot) and their ``pos``
        is restored. Runs at the FULL table width so the compile cache is
        keyed only by chunk length, like the dense path."""
        tbl = jnp.where(mask[:, None], cache["table"], self.num_pages)
        logits, new_cache = self.model.extend_fn(
            params, {"tokens": tokens}, {**cache, "table": tbl})
        new_cache["pos"] = jnp.where(mask, new_cache["pos"], cache["pos"])
        new_cache["table"] = cache["table"]
        return logits, new_cache

    def _masked_decode(self, params, inputs, mask, cache):
        """Async-mode decode with pending-admission rows masked out. Dense:
        masked rows keep their old cache bits (tree select, same rule as
        ``_masked_extend``). Paged: masked rows' table rows sentinel out so
        their in-flight pages are untouched, and their ``pos`` is
        restored."""
        if self.paged:
            tbl = jnp.where(mask[:, None], cache["table"], self.num_pages)
            logits, new_cache = self.model.decode_fn(
                params, inputs, {**cache, "table": tbl})
            new_cache["pos"] = jnp.where(mask, new_cache["pos"],
                                         cache["pos"])
            new_cache["table"] = cache["table"]
            return logits, new_cache
        logits, new_cache = self.model.decode_fn(params, inputs, cache)

        def sel(new, old):
            m = mask if new.ndim <= 1 else mask.reshape(
                (1, new.shape[1]) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        return logits, jax.tree.map(sel, new_cache, cache)

    # --------------------------------------------------------------- pages
    def _call_cache(self, width: int) -> dict:
        """Assemble the per-call paged cache: device pools + the host block
        table sliced to ``width`` pages + the span marker. The table is
        tiny ([B, width] int32) so re-uploading it per dispatch is noise
        next to the attention it saves."""
        return {**self.cache,
                "table": jnp.asarray(self._tbl[:, :width]),
                "span": self._span}

    @staticmethod
    def _strip_table(new_cache: dict) -> dict:
        """Drop the per-call table/span from a returned cache — the block
        table is host state; only pools + pos (+ enc_kv) persist."""
        new_cache.pop("table", None)
        new_cache.pop("span", None)
        return new_cache

    def _pages_needed(self, req: Request, prefix: int) -> int:
        """Pages covering the request's whole contract: prompt + prefix +
        (max_new_tokens - 1) cache positions — the final sampled token
        never writes KV. Reserved up front at admission so a request can
        never strand mid-decode out of pages."""
        total = prefix + len(req.prompt) + req.max_new_tokens - 1
        return -(-total // self.page_size)

    def _alloc_pages(self, slot: int, need: int) -> None:
        """Reserve ``need`` pages for ``slot`` from the free list and point
        the slot's block-table row at them (rest stays sentinel). Caller
        has already checked availability."""
        pages = [self._free_pages.pop() for _ in range(need)]
        self.slot_pages[slot] = pages
        self._tbl[slot, :] = self.num_pages
        self._tbl[slot, :need] = pages

    def _decode_width(self, live: list) -> int:
        """Smallest power-of-2 page count covering every live row's NEXT
        token write (undersized widths would clip the write into another
        slot's page). Dead/pending rows don't count — their table rows are
        sentinel at decode time. O(log max_seq/page) distinct widths."""
        need = 1
        for i in live:
            need = max(need, int(self._slot_len[i]) + 1)
        pages = -(-need // self.page_size)
        pw = 1
        while pw < pages:
            pw <<= 1
        return min(pw, self._maxP)

    def _insert_group_cache(self, gcache: dict, slots: np.ndarray) -> int:
        """Scatter a batched (or B=1) DENSE prefill cache into engine
        slots; paged mode routes the kv leaves through the page pools and
        everything else ([B]-batched pos, enc-dec cross KV) through the
        row scatter. Returns the per-row kv length inserted (0 when
        dense — only the paged length mirror needs it)."""
        if not self.paged:
            self.cache = insert_cache_rows(self.cache, gcache,
                                           jnp.asarray(slots))
            return 0
        kv_g = gcache["kv"]
        S_g = int(next(iter(jax.tree.leaves(kv_g))).shape[2])
        npr = -(-S_g // self.page_size)
        pm = np.full((len(slots), npr), self.num_pages, np.int32)
        for r, s in enumerate(slots):
            if 0 <= s < self.max_batch:
                # entries past the slot's reservation stay sentinel, so a
                # serial-tail cache (padded to max_seq) can't write stray
                # pages
                pm[r] = self._tbl[s, :npr]
        kv_new = insert_cache_pages(self.cache["kv"], kv_g, jnp.asarray(pm))
        rest_e = {k: v for k, v in self.cache.items() if k != "kv"}
        rest_g = {k: v for k, v in gcache.items() if k != "kv"}
        rest_new = insert_cache_rows(rest_e, rest_g, jnp.asarray(slots))
        self.cache = {**rest_new, "kv": kv_new}
        return S_g

    def _prefill_inputs(self, tokens: np.ndarray) -> dict:
        inputs: dict[str, Any] = {"tokens": jnp.asarray(tokens, jnp.int32)}
        B = tokens.shape[0]
        if self.cfg.family == "encdec":
            inputs["frames"] = jnp.zeros(
                (B, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (B, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return inputs

    def _finalize_admits(self, items: list, logits) -> None:
        """Sample first tokens for every request finalized by one model
        call and make their slots live — ONE batched (fold_keys,
        sample_batch) dispatch and one host sync for the whole group.
        Per-row keys make each row's draw bitwise identical to a B=1 call
        through the same pair, so grouping (and the decode step's own
        sampling) can never change a stream.

        items: [(slot, req, row)] with ``row`` indexing ``logits``.
        """
        if not items:
            return
        rows = jnp.asarray([row for _, _, row in items], jnp.int32)
        slot_arr = jnp.asarray([slot for slot, _, _ in items], jnp.int32)
        idxs = jnp.asarray([len(req.tokens) for _, req, _ in items],
                           jnp.int32)
        temps = jnp.asarray([req.temperature for _, req, _ in items],
                            jnp.float32)
        # slot base keys were pinned at admission (engine seed or the
        # request's carried seed); the token index is len(tokens) — 0 for
        # a fresh request, the resume point for a replayed transcript —
        # so a resumed stream continues exactly where it left off
        keys = fold_idx(self._slot_keys[slot_arr], idxs)
        toks = np.asarray(sample_batch(logits[rows], keys, temps))
        now = self._clock()
        live_slots, live_toks = [], []
        for j, (slot, req, _) in enumerate(items):
            tok = int(toks[j])
            req.tokens.append(tok)
            if req.prefill_done_s is None:
                # a resumed request keeps its ORIGINAL prefill time: TTFT
                # measures when the user first saw a token, not the replay
                req.prefill_done_s = now
            self.metrics.prefills += 1
            if len(req.tokens) >= req.max_new_tokens or tok == self.eos:
                # complete at admission: the prompt's last logits already
                # gave the only remaining requested (or an EOS) token —
                # the slot never goes live, no unrequested decode runs
                req.finish_s = now
                self.metrics.completed.append(req)
                self.release_slot(slot)
                continue
            self.active[slot] = req
            self.new_counts[slot] = len(req.tokens)
            live_slots.append(slot)
            live_toks.append(tok)
        if live_slots:
            self.last_token = self.last_token.at[jnp.asarray(live_slots)].set(
                jnp.asarray(live_toks, jnp.int32))

    def _sweep_waiting_deadlines(self, now: float) -> None:
        """Expire queued requests whose absolute deadline has passed —
        before admission, so a dead request never burns prefill compute."""
        keep: deque[Request] = deque()
        while self.waiting:
            req = self.waiting.popleft()
            if req.deadline_s is not None and now >= req.deadline_s:
                req.finish_s = now
                self.metrics.timed_out.append(req)
                self.metrics.lost_tokens += len(req.tokens)
            else:
                keep.append(req)
        self.waiting = keep

    def _admit(self) -> None:
        if not self.waiting:
            return
        now = self._clock()
        if self._has_deadlines:
            self._sweep_waiting_deadlines(now)
        free = [i for i, r in enumerate(self.active)
                if r is None and i not in self._pend]
        admits: list[tuple[int, Request]] = []
        held: list[Request] = []       # backoff-gated, keep queue order
        spent = 0
        budget = self.admit_token_budget
        if budget is not None and self.brownout < 1.0:
            # brownout scales how much prefill work one step may take on
            budget = max(1, int(budget * self.brownout))
        # VLM rows spend cache positions on the patch prefix too (enc-dec
        # frames live in the separate encoder cache, so they don't)
        prefix = (self.cfg.num_prefix_embeddings
                  if self.cfg.family == "vlm" else 0)
        while self.waiting and free:
            req = self.waiting[0]
            if req.not_before_s > now:
                # retry backoff: not eligible yet — hold WITHOUT blocking
                # the requests behind it (no head-of-line starvation)
                held.append(self.waiting.popleft())
                continue
            # effective prompt length: a resumed transcript replays
            # prompt + generated prefix through the prefill path
            S = len(req.prompt) + len(req.tokens)
            if req.max_new_tokens <= len(req.tokens):
                # degenerate but legal: nothing (left) to generate —
                # complete as-is, no slot, no prefill
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.completed.append(req)
                continue
            need = self._pages_needed(req, prefix) if self.paged else 0
            if (len(req.prompt) == 0 or
                    prefix + len(req.prompt) + req.max_new_tokens - 1
                    > self.max_seq or need > self.num_pages):
                # can never fit this engine's cache (or page pool): reject
                # without consuming a slot (burst-proof: the queue keeps
                # draining)
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.lost_tokens += len(req.tokens)
                self.metrics.rejected.append(req)
                continue
            if self.paged and need > len(self._free_pages):
                # pool temporarily exhausted: WAIT for live requests to
                # finish and recycle pages — never evict to make room.
                # Cannot deadlock: pages are only held by admitted
                # requests, which retire in bounded steps
                break
            if (admits and budget is not None and spent + S > budget):
                break  # budget spent; the rest waits for the next step
            self.waiting.popleft()
            if self.brownout < 1.0 and not req.tokens:
                # graceful degradation: fresh admissions under brownout
                # shed max_new_tokens instead of being dropped (resumed
                # transcripts keep their contract — shedding them would
                # break the bit-identity anchor)
                want = req.max_new_tokens
                shed_to = max(1, int(math.ceil(want * self.brownout)))
                if shed_to < want:
                    self.metrics.shed_tokens += want - shed_to
                    req.max_new_tokens = shed_to
            slot = free.pop(0)
            if self.paged:
                # recompute after any brownout shed (never more than the
                # pre-shed `need` the availability check cleared)
                self._alloc_pages(slot, self._pages_needed(req, prefix))
            admits.append((slot, req))
            spent += S
        if held:
            self.waiting.extendleft(reversed(held))
        if not admits:
            return
        for slot, req in admits:
            self._slot_keys = self._slot_keys.at[slot].set(
                self._request_base_key(req))
        try:
            if self.admit_mode == "serial":
                for slot, req in admits:
                    self._admit_serial(slot, req)
            else:
                self._admit_batched(admits)
        except Exception:
            # a failed admission must not strand its round-mates: anything
            # not yet live goes back to the FRONT of the queue with clean
            # state, so completed + rejected + waiting + active always
            # reconciles. (Serial mode attributes the failure and records
            # that one request as rejected; batched failures cannot be
            # attributed to a single request, so everything is retried.)
            # Membership is by identity: Request.__eq__ would compare
            # ndarray prompts and raise. A resumed request keeps its
            # carried transcript prefix — only tokens sampled during the
            # failed round are rolled back.
            self._rollback_admits(admits)
            raise

    def _admit_serial(self, slot: int, req: Request) -> None:
        """Reference path: pow2-prefix prefill + serial B=1 decode tail."""
        full = self._effective_prompt(req)
        S = len(full)
        bucket = 1 << (S.bit_length() - 1)
        logits, req_cache = self._prefill(
            self.params, self._prefill_inputs(full[None, :bucket]))
        self.metrics.prefill_calls += 1
        if bucket < S:
            # continue the prompt token-by-token at B=1: decode(prefill
            # of a prefix) is the exact sequential continuation, so the
            # final logits/cache match a full-length prefill
            if self._b1_cache is None:
                from repro.models import transformer as T
                self._b1_cache = T.make_decode_cache(self.cfg, 1, self.max_seq)
            req_cache = insert_cache(self._b1_cache, req_cache, 0)
            for tok in full[bucket:]:
                logits, req_cache = self._decode(
                    self.params, {"token": jnp.asarray([tok], jnp.int32)},
                    req_cache)
                self.metrics.prefill_calls += 1
        try:
            if self.paged:
                self._insert_group_cache(req_cache,
                                         np.asarray([slot], np.int32))
                # logical length = the request cache's pos (covers the VLM
                # patch prefix); NOT the kv length — the serial tail cache
                # is padded out to max_seq
                self._slot_len[slot] = int(req_cache["pos"][0])
            else:
                self.cache = insert_cache(self.cache, req_cache, slot)
            self._finalize_admits([(slot, req, 0)], logits)
        except Exception:
            self._reject_failed(slot, req)
            raise

    def _reject_failed(self, slot: int, req: Request) -> None:
        """Admission error path: release the slot and record the failing
        request as rejected, keeping the engine's accounting consistent
        (completed + rejected + waiting + active == submitted). A resumed
        request keeps its carried transcript prefix (and original TTFT)
        so a failover layer can still retry it elsewhere."""
        self.release_slot(slot)
        self.metrics.lost_tokens += max(0, len(req.tokens) - req.resumed_from)
        del req.tokens[req.resumed_from:]
        if req.resumed_from == 0:
            req.prefill_done_s = None
        req.finish_s = self._clock()
        self.metrics.rejected.append(req)

    def _dispatch_extend(self, toks, mask, takers: list, C: int):
        """One masked extend-chunk dispatch over the full engine batch
        (dense: cache-select mask; paged: sentinel table rows, full table
        width). Shared by the batched tail loop and the async arbiter."""
        if self.paged:
            logits, new_cache = self._extend_paged(
                self.params, jnp.asarray(toks), jnp.asarray(mask),
                self._call_cache(self._maxP))
            self.cache = self._strip_table(new_cache)
            for slot in takers:
                self._slot_len[slot] += C
        else:
            logits, self.cache = self._extend(
                self.params, jnp.asarray(toks), jnp.asarray(mask),
                self.cache)
        self.metrics.prefill_calls += 1
        self.metrics.extend_chunks += 1
        return logits

    def _admit_batched(self, admits: list) -> None:
        """Grouped prefill + shared descending-pow2 extend tails. Operates
        on the *effective* prompt (prompt + resumed transcript prefix), so
        a resumed request rides the same pipeline as a fresh one."""
        groups: dict[int, list] = {}
        for slot, req in admits:
            full = self._effective_prompt(req)
            bucket = 1 << (len(full).bit_length() - 1)
            groups.setdefault(bucket, []).append((slot, req, full))
        pend: dict[int, list] = {}          # slot -> [req, full, consumed]
        for bucket in sorted(groups, reverse=True):
            group = groups[bucket]
            kp = 1 << (len(group) - 1).bit_length()   # pow2-padded batch
            toks = np.zeros((kp, bucket), np.int32)
            # padding rows scatter to slot id max_batch -> dropped
            slots = np.full((kp,), self.max_batch, np.int32)
            for r, (slot, req, full) in enumerate(group):
                toks[r] = full[:bucket]
                slots[r] = slot
            logits, gcache = self._prefill(self.params,
                                           self._prefill_inputs(toks))
            self.metrics.prefill_calls += 1
            self._insert_group_cache(gcache, slots)
            if self.paged:
                # every row of the bucket group lands at the same logical
                # length: the group cache's pos (covers the VLM prefix)
                S_ins = int(gcache["pos"][0])
                for slot, _req, _full in group:
                    self._slot_len[slot] = S_ins
            fins = []
            for r, (slot, req, full) in enumerate(group):
                if bucket == len(full):
                    fins.append((slot, req, r))
                else:
                    pend[slot] = [req, full, bucket]
            self._finalize_admits(fins, logits)
        while pend:
            # chunk = the largest remaining binary digit across pending
            # rows; every row with that bit set advances this round
            C = max(1 << ((len(full) - cons).bit_length() - 1)
                    for req, full, cons in pend.values())
            toks = np.zeros((self.max_batch, C), np.int32)
            mask = np.zeros((self.max_batch,), bool)
            takers = []
            for slot, (req, full, cons) in pend.items():
                if (len(full) - cons) & C:
                    toks[slot] = full[cons:cons + C]
                    mask[slot] = True
                    takers.append(slot)
            logits = self._dispatch_extend(toks, mask, takers, C)
            fins = []
            for slot in takers:
                req, full, cons = pend[slot]
                cons += C
                if cons == len(full):
                    del pend[slot]
                    fins.append((slot, req, slot))
                else:
                    pend[slot][2] = cons
            self._finalize_admits(fins, logits)

    # --------------------------------------------------------------- async
    def _arbiter(self, budget: int, *, force: bool = False) -> int:
        """Spend up to ``budget`` prompt tokens advancing pending tails in
        descending-pow-2 chunks (every pending row with at least a full
        chunk remaining rides each dispatch). ``force`` guarantees one
        minimal chunk even on an exhausted budget, so tails can never
        starve behind a continuous arrival stream. Returns tokens spent."""
        spent = 0
        while self._pend:
            remaining = budget - spent
            if remaining < 1:
                if not (force and spent == 0):
                    break
                remaining = 1
            max_rem = max(len(full) - cons
                          for _req, full, cons in self._pend.values())
            cap = min(max_rem, remaining)
            C = 1 << (cap.bit_length() - 1)
            toks = np.zeros((self.max_batch, C), np.int32)
            mask = np.zeros((self.max_batch,), bool)
            takers = []
            for slot, (_req, full, cons) in self._pend.items():
                if len(full) - cons >= C:
                    toks[slot] = full[cons:cons + C]
                    mask[slot] = True
                    takers.append(slot)
            logits = self._dispatch_extend(toks, mask, takers, C)
            fins = []
            for slot in takers:
                entry = self._pend[slot]
                entry[2] += C
                if entry[2] == len(entry[1]):
                    del self._pend[slot]
                    fins.append((slot, entry[0], slot))
            self._finalize_admits(fins, logits)
            spent += C * len(takers)
        return spent

    def _admit_async(self) -> int:
        """One bounded slice of admission work: a guaranteed arbiter chunk
        for in-flight tails, new-request bucket prefills with the
        remaining budget (tails deferred to ``self._pend``), then leftover
        budget on more tail chunks. Returns prompt tokens spent (the
        step's admission-stall accounting)."""
        now = self._clock()
        if self._has_deadlines:
            self._sweep_waiting_deadlines(now)
        budget = self.admit_token_budget or self.max_seq
        if self.brownout < 1.0:
            budget = max(1, int(budget * self.brownout))
        spent = self._arbiter(budget, force=True)
        free = [i for i, r in enumerate(self.active)
                if r is None and i not in self._pend]
        admits: list[tuple[int, Request]] = []
        held: list[Request] = []
        prefix = (self.cfg.num_prefix_embeddings
                  if self.cfg.family == "vlm" else 0)
        while self.waiting and free:
            req = self.waiting[0]
            if req.not_before_s > now:
                held.append(self.waiting.popleft())
                continue
            S = len(req.prompt) + len(req.tokens)
            if req.max_new_tokens <= len(req.tokens):
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.completed.append(req)
                continue
            need = self._pages_needed(req, prefix) if self.paged else 0
            if (len(req.prompt) == 0 or
                    prefix + len(req.prompt) + req.max_new_tokens - 1
                    > self.max_seq or need > self.num_pages):
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.lost_tokens += len(req.tokens)
                self.metrics.rejected.append(req)
                continue
            if self.paged and need > len(self._free_pages):
                break  # wait for pages to recycle — never evict
            # admission charges only the bucket prefill this step; the
            # tail is deferred to the arbiter. Admit unconditionally when
            # the step has done no work yet (no starvation of oversized
            # prompts)
            bucket = 1 << (S.bit_length() - 1)
            if (admits or spent) and spent + bucket > budget:
                break
            self.waiting.popleft()
            if self.brownout < 1.0 and not req.tokens:
                want = req.max_new_tokens
                shed_to = max(1, int(math.ceil(want * self.brownout)))
                if shed_to < want:
                    self.metrics.shed_tokens += want - shed_to
                    req.max_new_tokens = shed_to
            slot = free.pop(0)
            if self.paged:
                self._alloc_pages(slot, self._pages_needed(req, prefix))
            admits.append((slot, req))
            spent += bucket
        if held:
            self.waiting.extendleft(reversed(held))
        if admits:
            for slot, req in admits:
                self._slot_keys = self._slot_keys.at[slot].set(
                    self._request_base_key(req))
            try:
                self._prefill_async(admits)
            except Exception:
                self._rollback_admits(admits)
                raise
        spent += self._arbiter(budget - spent)
        return spent

    def _prefill_async(self, admits: list) -> None:
        """Bucket-group prefills for a fresh async admission wave;
        full-bucket prompts finalize immediately, everything else lands in
        ``self._pend`` for the arbiter (pages/rows already reserved)."""
        groups: dict[int, list] = {}
        for slot, req in admits:
            full = self._effective_prompt(req)
            bucket = 1 << (len(full).bit_length() - 1)
            groups.setdefault(bucket, []).append((slot, req, full))
        for bucket in sorted(groups, reverse=True):
            group = groups[bucket]
            kp = 1 << (len(group) - 1).bit_length()
            toks = np.zeros((kp, bucket), np.int32)
            slots = np.full((kp,), self.max_batch, np.int32)
            for r, (slot, _req, full) in enumerate(group):
                toks[r] = full[:bucket]
                slots[r] = slot
            logits, gcache = self._prefill(self.params,
                                           self._prefill_inputs(toks))
            self.metrics.prefill_calls += 1
            self._insert_group_cache(gcache, slots)
            if self.paged:
                S_ins = int(gcache["pos"][0])
                for slot, _req, _full in group:
                    self._slot_len[slot] = S_ins
            fins = []
            for r, (slot, req, full) in enumerate(group):
                if bucket == len(full):
                    fins.append((slot, req, r))
                else:
                    self._pend[slot] = [req, full, bucket]
            self._finalize_admits(fins, logits)

    def _rollback_admits(self, admits: list) -> None:
        """Failed-round cleanup shared with ``_admit``: anything not yet
        settled (live, pending, completed or rejected) goes back to the
        front of the queue with clean state and its slot/pages released."""
        requeue = []
        for slot, req in admits:
            settled = (self.active[slot] is req
                       or (slot in self._pend
                           and self._pend[slot][0] is req)
                       or any(r is req for r in self.metrics.completed)
                       or any(r is req for r in self.metrics.rejected))
            if not settled:
                del req.tokens[req.resumed_from:]
                self.release_slot(slot)
                requeue.append(req)
        self.waiting.extendleft(reversed(requeue))

    # --------------------------------------------------------------- slots
    def release_slot(self, slot: int) -> None:
        """Family-agnostic slot retirement: clear the slot's bookkeeping
        and zero its cache position, so every family's valid-length reads
        mask out the stale cache rows. Used on sequence finish, preemption
        and admission error paths. Idempotent — releasing a free (or
        never-admitted) slot is a no-op; an out-of-range slot id raises."""
        if not 0 <= slot < self.max_batch:
            raise ValueError(
                f"slot {slot} out of range [0, {self.max_batch})")
        self.active[slot] = None
        self.new_counts[slot] = 0
        self._pend.pop(slot, None)
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)
        if self.paged and self.slot_pages[slot]:
            # recycle: the freed pages go back on the free list and the
            # block-table row goes all-sentinel, so any stale write into
            # this slot drops instead of corrupting the pages' next owner
            self._free_pages.extend(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self._tbl[slot, :] = self.num_pages
            self._slot_len[slot] = 0

    # ----------------------------------------------------- preempt / resume
    def preempt(self, slots: Optional[list] = None) -> list[TranscriptSnapshot]:
        """Snapshot in-flight requests and free their cache slots.

        ``slots=None`` preempts every live slot (the power-drop path).
        Each snapshot carries the full transcript and the seed that keys
        the request's sampling stream, so ``resume`` — here or on any
        other engine serving the same model — continues it bit-identically.
        """
        if slots is None:
            slots = ([i for i, r in enumerate(self.active) if r is not None]
                     + list(self._pend))
        snaps = []
        for slot in slots:
            req = self.active[slot]
            if req is None and slot in self._pend:
                # an async-pending admission owes its transcript too: the
                # prefix already inserted is abandoned (pages/rows freed)
                # and the resume replays it from the prompt
                req = self._pend[slot][0]
            if req is None:
                continue
            seed = req.seed if req.seed is not None else self.seed
            snaps.append(TranscriptSnapshot.from_request(req, seed=seed))
            self.metrics.preemptions += 1
            self.metrics.evicted += 1
            self.release_slot(slot)
        return snaps

    def drain(self) -> list[TranscriptSnapshot]:
        """Site-death path: preempt every live slot AND evict the waiting
        queue — everything this engine owes comes back as snapshots for a
        failover layer to carry to surviving sites."""
        snaps = self.preempt()
        while self.waiting:
            req = self.waiting.popleft()
            seed = req.seed if req.seed is not None else self.seed
            snaps.append(TranscriptSnapshot.from_request(req, seed=seed))
            self.metrics.evicted += 1
        return snaps

    def resume(self, snap: TranscriptSnapshot, *,
               not_before_s: float = 0.0) -> Optional[Request]:
        """Re-admit a preempted transcript. The carried seed keeps the
        stream's keys; the carried ``prefill_done_s`` keeps the original
        TTFT honest. Returns the queued Request, or None when the
        watermark rejected it (the caller keeps the snapshot and may retry
        elsewhere)."""
        req = Request(rid=snap.rid,
                      prompt=np.asarray(snap.prompt, np.int32),
                      max_new_tokens=snap.max_new_tokens,
                      arrival_s=snap.arrival_s,
                      temperature=snap.temperature,
                      seed=snap.seed,
                      deadline_s=snap.deadline_s,
                      not_before_s=not_before_s,
                      attempts=snap.attempts,
                      resumed_from=len(snap.tokens),
                      tokens=list(snap.tokens),
                      prefill_done_s=snap.prefill_done_s)
        if not self.submit(req):
            return None
        self.metrics.resumed += 1
        self.metrics.recovered_tokens += len(snap.tokens)
        return req

    def set_brownout(self, frac: float) -> None:
        """Enter (or leave, frac=1.0) brownout: fresh admissions shed
        ``max_new_tokens`` to ``ceil(frac * requested)`` and the per-step
        admission token budget scales by ``frac`` — graceful degradation
        under a power drop instead of wholesale drops."""
        self.brownout = float(min(max(frac, 0.0), 1.0))

    def reconcile(self) -> dict:
        """Watchdog: every submitted request must be in exactly one of
        completed / rejected / timed_out / waiting / active / evicted
        (handed out as a snapshot). Returns the books and a ``balanced``
        flag — an unbalanced ledger means the engine leaked a request."""
        m = self.metrics
        books = {"submitted": m.submitted,
                 "completed": len(m.completed),
                 "rejected": len(m.rejected),
                 "timed_out": len(m.timed_out),
                 "waiting": len(self.waiting),
                 "active": (sum(r is not None for r in self.active)
                            + len(self._pend)),
                 "evicted": m.evicted}
        books["balanced"] = (
            books["submitted"] == books["completed"] + books["rejected"]
            + books["timed_out"] + books["waiting"] + books["active"]
            + books["evicted"])
        # decode-utilization ledger: how well admission overlapped decode
        books["decode_utilization"] = {
            "decode_steps": m.decode_steps,
            "extend_chunks": m.extend_chunks,
            "admit_stall_steps": m.admit_stall_steps,
        }
        return books

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit waiting requests, run one batched decode. Returns #active."""
        if self._has_deadlines:
            now = self._clock()
            for i, r in enumerate(self.active):
                if (r is not None and r.deadline_s is not None
                        and now >= r.deadline_s):
                    r.finish_s = now
                    self.metrics.timed_out.append(r)
                    self.metrics.lost_tokens += len(r.tokens)
                    self.release_slot(i)
            for i in list(self._pend):
                r = self._pend[i][0]
                if r.deadline_s is not None and now >= r.deadline_s:
                    r.finish_s = now
                    self.metrics.timed_out.append(r)
                    self.metrics.lost_tokens += len(r.tokens)
                    self.release_slot(i)   # pops the pend entry too
        pc_before = self.metrics.prefill_calls
        if self.admit_mode == "async":
            self._admit_async()
        else:
            self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            if self.metrics.prefill_calls > pc_before:
                # admission ran with nothing to decode against — the stall
                # the async overlap exists to shrink
                self.metrics.admit_stall_steps += 1
            return 0
        if self.admit_mode == "async":
            # pending-admission rows are masked out of the decode (their
            # half-filled caches must not move)
            mask = np.zeros((self.max_batch,), bool)
            mask[live] = True
            if self.paged:
                logits, new_cache = self._decode_masked(
                    self.params, {"token": self.last_token},
                    jnp.asarray(mask),
                    self._call_cache(self._decode_width(live)))
                self.cache = self._strip_table(new_cache)
            else:
                logits, self.cache = self._decode_masked(
                    self.params, {"token": self.last_token},
                    jnp.asarray(mask), self.cache)
        elif self.paged:
            logits, new_cache = self._decode(
                self.params, {"token": self.last_token},
                self._call_cache(self._decode_width(live)))
            self.cache = self._strip_table(new_cache)
        else:
            logits, self.cache = self._decode(
                self.params, {"token": self.last_token}, self.cache)
        self.metrics.decode_steps += 1
        if self.paged:
            for i in live:
                self._slot_len[i] += 1
        temps = np.zeros(self.max_batch, np.float32)
        idxs = np.zeros(self.max_batch, np.int32)
        for i in live:
            temps[i] = self.active[i].temperature
            idxs[i] = len(self.active[i].tokens)
        # per-(request, token-index) keys + per-row temperatures: a row's
        # draw is independent of its batch-mates and its admission order.
        # Slot base keys were pinned at admission (fold_idx on top equals
        # fold_keys bitwise), so a resumed request keeps its origin stream
        keys = fold_idx(self._slot_keys, jnp.asarray(idxs))
        toks = sample_batch(logits, keys, jnp.asarray(temps))
        toks_np = np.asarray(toks)
        self.last_token = toks
        self.metrics.steps += 1
        now = self._clock()
        for i in live:
            req = self.active[i]
            req.tokens.append(int(toks_np[i]))
            self.new_counts[i] += 1
            done = (self.new_counts[i] >= req.max_new_tokens
                    or int(toks_np[i]) == self.eos)
            if done:
                req.finish_s = now
                self.metrics.completed.append(req)
                self.release_slot(i)
        return len([r for r in self.active if r is not None])

    def run(self, max_steps: int = 10_000) -> EngineMetrics:
        """Drain all waiting + active requests."""
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.waiting and not self._pend:
                break
        return self.metrics
