"""Site-local serving engine: KV-cache slots + batched continuous batching.

This is the per-site engine the paper assumes (vLLM in their testbed) —
built here in JAX because Heron needs a real serving substrate to route
into. Design:

  * a fixed pool of ``max_batch`` cache *slots*; each slot owns one
    sequence's decode cache (KV / recurrent state, family-specific pytree);
  * **continuous batching**: new requests are admitted into free slots via
    a *batched admission pipeline* (below); every engine step runs ONE
    batched decode over all slots (fixed shapes → one compiled program);
  * finished sequences retire their slot immediately via ``release_slot``
    — no batch barriers;
  * per-request TTFT / TBT / E2E metrics (means and p50/p99 tails) against
    the class SLOs, which is what Heron's goodput accounting consumes.

Batched admission pipeline (the burst path — a site absorbing a drained
neighbour's traffic sees all of its requests at once):

  1. waiting requests are grouped by the largest power-of-2 prefix of
     their prompt (*bucket*) and prefilled TOGETHER — one compiled
     ``prefill`` call per (bucket, pow2-padded batch) shape;
  2. each prompt's tail (prompt minus bucket) runs through DESCENDING
     power-of-2 chunks of ``Model.extend_fn`` — prefill continued from the
     engine cache at per-row offsets. Tails are the binary digits of the
     remaining length, so a round admits every slot that has the current
     chunk-size bit set: O(log S) compiled calls shared across the whole
     admission group, instead of up to S/2 serial B=1 decodes per request;
  3. a per-step admission token budget (``admit_token_budget``) bounds how
     many prompt tokens one ``step()`` may prefill, so already-live slots'
     TBT cannot balloon under a thundering herd (at least one request is
     always admitted so oversized prompts cannot starve).

The extend calls run at the engine's fixed batch with a row mask (masked
rows keep their old cache bits), so the compile cache stays
O(log max_seq) extend entries + O(log max_seq) x O(log max_batch) prefill
entries + one decode entry. Right-padding prompts instead would corrupt
recurrent/SSM states and shift last-token logits, so it is deliberately
not used. ``admit_mode="serial"`` keeps the old one-request-at-a-time
path (pow2-prefix prefill + B=1 decode tail) as the equivalence
reference.

Sampling policy: every token draw uses a key derived from (engine seed,
request id, token index) — see ``serving.sampling.fold_keys`` — so a
request's token stream is bit-identical regardless of admission order,
batching, or slot placement. (Previous engines split one engine-global
key per step, which made streams depend on batch composition.) Per-row
temperatures still let greedy (t == 0) and sampled requests coexist in
one batched decode.

Cache insertion is family-agnostic: every cache leaf is [B]-batched at
axis 0 (1-D leaves like ``pos``) or axis 1 (stacked [L, B, ...] leaves),
so one ``dynamic_update_slice`` rule covers GQA/MLA/SSM/hybrid/enc-dec.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.serving.sampling import fold_keys, sample_batch


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0
    temperature: float = 0.0
    # filled by the engine
    tokens: list = field(default_factory=list)
    prefill_done_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done_s is None:
            return None
        return self.prefill_done_s - self.arrival_s

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    @property
    def tbt(self) -> Optional[float]:
        if self.finish_s is None or len(self.tokens) < 2:
            return None
        return (self.finish_s - self.prefill_done_s) / max(len(self.tokens) - 1, 1)


def _insert_leaf(engine_leaf, req_leaf, slot: int):
    """Write a single-sequence cache leaf into slot ``slot``."""
    req_leaf = req_leaf.astype(engine_leaf.dtype)
    if engine_leaf.ndim == 1:                       # e.g. pos: [B]
        return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, (slot,))
    # stacked leaves: [L, B, ...] — batch at axis 1, write at origin elsewhere
    start = (0, slot) + (0,) * (engine_leaf.ndim - 2)
    return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, start)


@jax.jit
def insert_cache(engine_cache, req_cache, slot):
    """Insert a B=1 request cache into the engine's slot ``slot``."""
    return jax.tree.map(lambda e, r: _insert_leaf(e, r, slot),
                        engine_cache, req_cache)


@jax.jit
def insert_cache_rows(engine_cache, group_cache, slots):
    """Scatter a batched prefill cache into engine slots: row ``r`` of
    ``group_cache`` lands in slot ``slots[r]``, one compiled call (and one
    functional cache copy) per (bucket, batch) shape for the whole group.
    Out-of-range slot ids drop their row — how pow2 padding rows and their
    garbage prefill results are discarded."""
    def ins(e, g):
        g = g.astype(e.dtype)
        if e.ndim == 1:
            return e.at[slots].set(g, mode="drop")
        idx = (slice(None), slots) + tuple(slice(0, d) for d in g.shape[2:])
        return e.at[idx].set(g, mode="drop")

    return jax.tree.map(ins, engine_cache, group_cache)


def _pct(xs, q):
    return float(np.percentile(xs, q)) if xs else 0.0


@dataclass
class EngineMetrics:
    completed: list
    rejected: list = field(default_factory=list)
    steps: int = 0
    prefills: int = 0          # requests admitted (one prefill each, logically)
    prefill_calls: int = 0     # compiled model dispatches spent on admission

    def summary(self) -> dict:
        ttfts = [r.ttft for r in self.completed if r.ttft is not None]
        e2es = [r.e2e for r in self.completed if r.e2e is not None]
        tbts = [r.tbt for r in self.completed if r.tbt is not None]
        f = lambda xs: float(np.mean(xs)) if xs else 0.0
        out = {"num_completed": len(self.completed), "steps": self.steps,
               "prefills": self.prefills, "prefill_calls": self.prefill_calls,
               "rejected": len(self.rejected),
               "mean_ttft": f(ttfts), "mean_tbt": f(tbts), "mean_e2e": f(e2es)}
        # tail percentiles: what the goodput accounting and the serving
        # bench consume — burst admission shows up in p99, not the mean
        for name, xs in (("ttft", ttfts), ("tbt", tbts), ("e2e", e2es)):
            out[f"p50_{name}"] = _pct(xs, 50)
            out[f"p99_{name}"] = _pct(xs, 99)
        return out


class ServingEngine:
    """Continuous-batching engine over one model replica.

    ``admit_mode``: "batched" (default — grouped prefill + chunked extend
    tails) or "serial" (the reference: one request at a time, B=1 decode
    tail). Token streams are bit-identical between the two.
    ``admit_token_budget``: max prompt tokens admitted per step (None =
    unlimited); bounds TBT inflation for live slots under bursts.
    """

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_token: int = -1, seed: int = 0,
                 clock=None, admit_mode: str = "batched",
                 admit_token_budget: Optional[int] = None):
        if admit_mode not in ("batched", "serial"):
            raise ValueError(f"admit_mode {admit_mode!r}")
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos = eos_token
        self.admit_mode = admit_mode
        self.admit_token_budget = admit_token_budget
        self._base_key = jax.random.key(seed)
        self._clock = clock or time.perf_counter

        from repro.models import transformer as T
        self.cache = T.make_decode_cache(self.cfg, max_batch, max_seq)
        self.active: list[Optional[Request]] = [None] * max_batch
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.new_counts = [0] * max_batch
        self.waiting: deque[Request] = deque()
        self.metrics = EngineMetrics(completed=[])
        self._decode = jax.jit(model.decode_fn)
        self._prefill = jax.jit(model.prefill_fn)
        self._extend = jax.jit(self._masked_extend)
        # zeros template for the serial-mode B=1 prompt-tail continuation;
        # built lazily — batched mode (the default) never needs it
        self._b1_cache = None

    # --------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _masked_extend(self, params, tokens, mask, cache):
        """One extend chunk over the full engine cache; rows with
        ``mask[b] == False`` keep their old cache bits (so live decode
        slots and idle slots are untouched). Compiled once per chunk
        length — the engine batch is fixed."""
        logits, new_cache = self.model.extend_fn(params, {"tokens": tokens},
                                                 cache)

        def sel(new, old):
            m = mask if new.ndim <= 1 else mask.reshape(
                (1, new.shape[1]) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        return logits, jax.tree.map(sel, new_cache, cache)

    def _prefill_inputs(self, tokens: np.ndarray) -> dict:
        inputs: dict[str, Any] = {"tokens": jnp.asarray(tokens, jnp.int32)}
        B = tokens.shape[0]
        if self.cfg.family == "encdec":
            inputs["frames"] = jnp.zeros(
                (B, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (B, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return inputs

    def _finalize_admits(self, items: list, logits) -> None:
        """Sample first tokens for every request finalized by one model
        call and make their slots live — ONE batched (fold_keys,
        sample_batch) dispatch and one host sync for the whole group.
        Per-row keys make each row's draw bitwise identical to a B=1 call
        through the same pair, so grouping (and the decode step's own
        sampling) can never change a stream.

        items: [(slot, req, row)] with ``row`` indexing ``logits``.
        """
        if not items:
            return
        rows = jnp.asarray([row for _, _, row in items], jnp.int32)
        rids = jnp.asarray([req.rid for _, req, _ in items], jnp.int32)
        temps = jnp.asarray([req.temperature for _, req, _ in items],
                            jnp.float32)
        keys = fold_keys(self._base_key, rids, jnp.zeros_like(rids))
        toks = np.asarray(sample_batch(logits[rows], keys, temps))
        now = self._clock()
        live_slots, live_toks = [], []
        for j, (slot, req, _) in enumerate(items):
            tok = int(toks[j])
            req.tokens.append(tok)
            req.prefill_done_s = now
            self.metrics.prefills += 1
            if req.max_new_tokens <= 1 or tok == self.eos:
                # complete at admission: the prompt's last logits already
                # gave the only requested (or an EOS) token — the slot
                # never goes live, so no unrequested decode step runs
                req.finish_s = now
                self.metrics.completed.append(req)
                self.release_slot(slot)
                continue
            self.active[slot] = req
            self.new_counts[slot] = 1
            live_slots.append(slot)
            live_toks.append(tok)
        if live_slots:
            self.last_token = self.last_token.at[jnp.asarray(live_slots)].set(
                jnp.asarray(live_toks, jnp.int32))

    def _admit(self) -> None:
        if not self.waiting:
            return
        free = [i for i, r in enumerate(self.active) if r is None]
        admits: list[tuple[int, Request]] = []
        spent = 0
        # VLM rows spend cache positions on the patch prefix too (enc-dec
        # frames live in the separate encoder cache, so they don't)
        prefix = (self.cfg.num_prefix_embeddings
                  if self.cfg.family == "vlm" else 0)
        while self.waiting and free:
            req = self.waiting[0]
            S = len(req.prompt)
            if req.max_new_tokens <= 0:
                # degenerate but legal: nothing to generate — complete
                # with zero tokens, no slot, no prefill
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.completed.append(req)
                continue
            if S == 0 or prefix + S + req.max_new_tokens - 1 > self.max_seq:
                # can never fit this engine's cache: reject without
                # consuming a slot (burst-proof: the queue keeps draining)
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.rejected.append(req)
                continue
            if (admits and self.admit_token_budget is not None
                    and spent + S > self.admit_token_budget):
                break  # budget spent; the rest waits for the next step
            self.waiting.popleft()
            admits.append((free.pop(0), req))
            spent += S
        if not admits:
            return
        try:
            if self.admit_mode == "serial":
                for slot, req in admits:
                    self._admit_serial(slot, req)
            else:
                self._admit_batched(admits)
        except Exception:
            # a failed admission must not strand its round-mates: anything
            # not yet live goes back to the FRONT of the queue with clean
            # state, so completed + rejected + waiting + active always
            # reconciles. (Serial mode attributes the failure and records
            # that one request as rejected; batched failures cannot be
            # attributed to a single request, so everything is retried.)
            # Membership is by identity: Request.__eq__ would compare
            # ndarray prompts and raise.
            requeue = []
            for slot, req in admits:
                if (req.prefill_done_s is None
                        and all(r is not req for r in self.metrics.rejected)):
                    req.tokens.clear()
                    self.release_slot(slot)
                    requeue.append(req)
            self.waiting.extendleft(reversed(requeue))
            raise

    def _admit_serial(self, slot: int, req: Request) -> None:
        """Reference path: pow2-prefix prefill + serial B=1 decode tail."""
        S = len(req.prompt)
        bucket = 1 << (S.bit_length() - 1)
        logits, req_cache = self._prefill(
            self.params, self._prefill_inputs(req.prompt[None, :bucket]))
        self.metrics.prefill_calls += 1
        if bucket < S:
            # continue the prompt token-by-token at B=1: decode(prefill
            # of a prefix) is the exact sequential continuation, so the
            # final logits/cache match a full-length prefill
            if self._b1_cache is None:
                from repro.models import transformer as T
                self._b1_cache = T.make_decode_cache(self.cfg, 1, self.max_seq)
            req_cache = insert_cache(self._b1_cache, req_cache, 0)
            for tok in req.prompt[bucket:]:
                logits, req_cache = self._decode(
                    self.params, {"token": jnp.asarray([tok], jnp.int32)},
                    req_cache)
                self.metrics.prefill_calls += 1
        try:
            self.cache = insert_cache(self.cache, req_cache, slot)
            self._finalize_admits([(slot, req, 0)], logits)
        except Exception:
            self._reject_failed(slot, req)
            raise

    def _reject_failed(self, slot: int, req: Request) -> None:
        """Admission error path: release the slot and record the failing
        request as rejected, keeping the engine's accounting consistent
        (completed + rejected + waiting + active == submitted)."""
        self.release_slot(slot)
        req.tokens.clear()
        req.prefill_done_s = None
        req.finish_s = self._clock()
        self.metrics.rejected.append(req)

    def _admit_batched(self, admits: list) -> None:
        """Grouped prefill + shared descending-pow2 extend tails."""
        groups: dict[int, list] = {}
        for slot, req in admits:
            bucket = 1 << (len(req.prompt).bit_length() - 1)
            groups.setdefault(bucket, []).append((slot, req))
        pend: dict[int, list] = {}          # slot -> [req, consumed]
        for bucket in sorted(groups, reverse=True):
            group = groups[bucket]
            kp = 1 << (len(group) - 1).bit_length()   # pow2-padded batch
            toks = np.zeros((kp, bucket), np.int32)
            # padding rows scatter to slot id max_batch -> dropped
            slots = np.full((kp,), self.max_batch, np.int32)
            for r, (slot, req) in enumerate(group):
                toks[r] = req.prompt[:bucket]
                slots[r] = slot
            logits, gcache = self._prefill(self.params,
                                           self._prefill_inputs(toks))
            self.metrics.prefill_calls += 1
            self.cache = insert_cache_rows(self.cache, gcache,
                                           jnp.asarray(slots))
            fins = []
            for r, (slot, req) in enumerate(group):
                if bucket == len(req.prompt):
                    fins.append((slot, req, r))
                else:
                    pend[slot] = [req, bucket]
            self._finalize_admits(fins, logits)
        while pend:
            # chunk = the largest remaining binary digit across pending
            # rows; every row with that bit set advances this round
            C = max(1 << ((len(req.prompt) - cons).bit_length() - 1)
                    for req, cons in pend.values())
            toks = np.zeros((self.max_batch, C), np.int32)
            mask = np.zeros((self.max_batch,), bool)
            takers = []
            for slot, (req, cons) in pend.items():
                if (len(req.prompt) - cons) & C:
                    toks[slot] = req.prompt[cons:cons + C]
                    mask[slot] = True
                    takers.append(slot)
            logits, self.cache = self._extend(
                self.params, jnp.asarray(toks), jnp.asarray(mask), self.cache)
            self.metrics.prefill_calls += 1
            fins = []
            for slot in takers:
                req, cons = pend[slot]
                cons += C
                if cons == len(req.prompt):
                    del pend[slot]
                    fins.append((slot, req, slot))
                else:
                    pend[slot][1] = cons
            self._finalize_admits(fins, logits)

    # --------------------------------------------------------------- slots
    def release_slot(self, slot: int) -> None:
        """Family-agnostic slot retirement: clear the slot's bookkeeping
        and zero its cache position, so every family's valid-length reads
        mask out the stale cache rows. Used on sequence finish and by
        admission error paths."""
        self.active[slot] = None
        self.new_counts[slot] = 0
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit waiting requests, run one batched decode. Returns #active."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        logits, self.cache = self._decode(
            self.params, {"token": self.last_token}, self.cache)
        temps = np.zeros(self.max_batch, np.float32)
        rids = np.zeros(self.max_batch, np.int32)
        idxs = np.zeros(self.max_batch, np.int32)
        for i in live:
            temps[i] = self.active[i].temperature
            rids[i] = self.active[i].rid
            idxs[i] = len(self.active[i].tokens)
        # per-(request, token-index) keys + per-row temperatures: a row's
        # draw is independent of its batch-mates and its admission order
        keys = fold_keys(self._base_key, jnp.asarray(rids), jnp.asarray(idxs))
        toks = sample_batch(logits, keys, jnp.asarray(temps))
        toks_np = np.asarray(toks)
        self.last_token = toks
        self.metrics.steps += 1
        now = self._clock()
        for i in live:
            req = self.active[i]
            req.tokens.append(int(toks_np[i]))
            self.new_counts[i] += 1
            done = (self.new_counts[i] >= req.max_new_tokens
                    or int(toks_np[i]) == self.eos)
            if done:
                req.finish_s = now
                self.metrics.completed.append(req)
                self.release_slot(i)
        return len([r for r in self.active if r is not None])

    def run(self, max_steps: int = 10_000) -> EngineMetrics:
        """Drain all waiting + active requests."""
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.waiting:
                break
        return self.metrics
