"""Site-local serving engine: KV-cache slots + batched continuous batching.

This is the per-site engine the paper assumes (vLLM in their testbed) —
built here in JAX because Heron needs a real serving substrate to route
into. Design:

  * a fixed pool of ``max_batch`` cache *slots*; each slot owns one
    sequence's decode cache (KV / recurrent state, family-specific pytree);
  * **continuous batching**: new requests are admitted into free slots via
    a *batched admission pipeline* (below); every engine step runs ONE
    batched decode over all slots (fixed shapes → one compiled program);
  * finished sequences retire their slot immediately via ``release_slot``
    — no batch barriers;
  * per-request TTFT / TBT / E2E metrics (means and p50/p99 tails) against
    the class SLOs, which is what Heron's goodput accounting consumes.

Batched admission pipeline (the burst path — a site absorbing a drained
neighbour's traffic sees all of its requests at once):

  1. waiting requests are grouped by the largest power-of-2 prefix of
     their prompt (*bucket*) and prefilled TOGETHER — one compiled
     ``prefill`` call per (bucket, pow2-padded batch) shape;
  2. each prompt's tail (prompt minus bucket) runs through DESCENDING
     power-of-2 chunks of ``Model.extend_fn`` — prefill continued from the
     engine cache at per-row offsets. Tails are the binary digits of the
     remaining length, so a round admits every slot that has the current
     chunk-size bit set: O(log S) compiled calls shared across the whole
     admission group, instead of up to S/2 serial B=1 decodes per request;
  3. a per-step admission token budget (``admit_token_budget``) bounds how
     many prompt tokens one ``step()`` may prefill, so already-live slots'
     TBT cannot balloon under a thundering herd (at least one request is
     always admitted so oversized prompts cannot starve).

The extend calls run at the engine's fixed batch with a row mask (masked
rows keep their old cache bits), so the compile cache stays
O(log max_seq) extend entries + O(log max_seq) x O(log max_batch) prefill
entries + one decode entry. Right-padding prompts instead would corrupt
recurrent/SSM states and shift last-token logits, so it is deliberately
not used. ``admit_mode="serial"`` keeps the old one-request-at-a-time
path (pow2-prefix prefill + B=1 decode tail) as the equivalence
reference.

Sampling policy: every token draw uses a key derived from (engine seed,
request id, token index) — see ``serving.sampling.fold_keys`` — so a
request's token stream is bit-identical regardless of admission order,
batching, or slot placement. (Previous engines split one engine-global
key per step, which made streams depend on batch composition.) Per-row
temperatures still let greedy (t == 0) and sampled requests coexist in
one batched decode.

Cache insertion is family-agnostic: every cache leaf is [B]-batched at
axis 0 (1-D leaves like ``pos``) or axis 1 (stacked [L, B, ...] leaves),
so one ``dynamic_update_slice`` rule covers GQA/MLA/SSM/hybrid/enc-dec.

Request lifecycle (fault tolerance)
-----------------------------------
A request moves through::

    queued --admit--> admitted/live --finish--> completed
       |                  |   |
       | (watermark /     |   +--deadline--> timed_out
       |  oversize /      +--preempt--> snapshot --resume--> queued (again)
       |  deadline)                          |
       +--> rejected / timed_out             +--(budget spent)--> failed

Heron's premise is that sites *lose power mid-decode*. ``preempt(slots)``
snapshots each in-flight request's full transcript (prompt + generated
tokens) into a ``checkpoint.store.TranscriptSnapshot`` and frees the
slot; ``drain()`` is the site-death path (every live slot plus the
waiting queue). ``resume(snapshot)`` re-admits the transcript — the
whole prompt+generated prefix replays through the admission pipeline's
prefill-from-cache chunks, and sampling continues at token index
``len(tokens)``. Because every draw is keyed by (seed, rid, token-index)
and the snapshot carries the seed that keyed the stream, a preempted-
and-resumed request's token stream is **bit-identical** to the
uninterrupted run — on any engine serving the same model, regardless of
that engine's own seed. That identity is this module's pinned
correctness anchor (tests/test_faults.py), and it is also what makes
cross-site failover accounting honest: recovered tokens are real tokens
the user would have received anyway, never a divergent re-generation.

Backpressure and brownout: ``queue_watermark`` rejects new submissions
beyond a queue depth (fail fast under overload); ``set_brownout(frac)``
enters power-brownout mode — admissions shed their ``max_new_tokens``
to ``ceil(frac * requested)`` (graceful degradation instead of drops)
and the per-step admission token budget scales by ``frac``. Requests
may carry a ``deadline_s`` (absolute, engine clock) after which they
time out whether queued or live, and a ``not_before_s`` backoff gate
(see ``retry_backoff``) so failover retries don't thundering-herd a
surviving site. ``EngineMetrics`` keeps the watchdog ledger: lost vs
recovered vs duplicated tokens, preemptions, resumes, timeouts, shed
tokens — ``reconcile()`` checks the books balance.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import TranscriptSnapshot
from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.serving.sampling import fold_idx, fold_keys, sample_batch


def retry_backoff(attempts: int, *, base: float = 0.05,
                  cap: float = 2.0) -> float:
    """Capped exponential backoff delay before retry ``attempts`` (1-based):
    ``min(base * 2**(attempts-1), cap)``. Deterministic (no jitter) so
    chaos runs replay exactly; the per-request sampling keys make jitter
    unnecessary for correctness."""
    return min(base * (2.0 ** max(attempts - 1, 0)), cap)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32 token ids
    max_new_tokens: int                 # TOTAL tokens (incl. resumed prefix)
    arrival_s: float = 0.0
    temperature: float = 0.0
    # fault-tolerance lifecycle
    seed: Optional[int] = None          # sampling-seed override; a resumed
    #                                     request carries its origin seed so
    #                                     its stream survives engine changes
    deadline_s: Optional[float] = None  # absolute deadline (engine clock)
    not_before_s: float = 0.0           # backoff gate for (re-)admission
    attempts: int = 0                   # admission/failover attempts so far
    resumed_from: int = 0               # tokens carried in from a snapshot
    # filled by the engine
    tokens: list = field(default_factory=list)
    prefill_done_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done_s is None:
            return None
        return self.prefill_done_s - self.arrival_s

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    @property
    def tbt(self) -> Optional[float]:
        if self.finish_s is None or len(self.tokens) < 2:
            return None
        return (self.finish_s - self.prefill_done_s) / max(len(self.tokens) - 1, 1)


def _insert_leaf(engine_leaf, req_leaf, slot: int):
    """Write a single-sequence cache leaf into slot ``slot``."""
    req_leaf = req_leaf.astype(engine_leaf.dtype)
    if engine_leaf.ndim == 1:                       # e.g. pos: [B]
        return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, (slot,))
    # stacked leaves: [L, B, ...] — batch at axis 1, write at origin elsewhere
    start = (0, slot) + (0,) * (engine_leaf.ndim - 2)
    return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, start)


@jax.jit
def insert_cache(engine_cache, req_cache, slot):
    """Insert a B=1 request cache into the engine's slot ``slot``."""
    return jax.tree.map(lambda e, r: _insert_leaf(e, r, slot),
                        engine_cache, req_cache)


@jax.jit
def insert_cache_rows(engine_cache, group_cache, slots):
    """Scatter a batched prefill cache into engine slots: row ``r`` of
    ``group_cache`` lands in slot ``slots[r]``, one compiled call (and one
    functional cache copy) per (bucket, batch) shape for the whole group.
    Out-of-range slot ids drop their row — how pow2 padding rows and their
    garbage prefill results are discarded."""
    def ins(e, g):
        g = g.astype(e.dtype)
        if e.ndim == 1:
            return e.at[slots].set(g, mode="drop")
        idx = (slice(None), slots) + tuple(slice(0, d) for d in g.shape[2:])
        return e.at[idx].set(g, mode="drop")

    return jax.tree.map(ins, engine_cache, group_cache)


def _pct(xs, q):
    return float(np.percentile(xs, q)) if xs else 0.0


@dataclass
class EngineMetrics:
    completed: list
    rejected: list = field(default_factory=list)
    timed_out: list = field(default_factory=list)
    steps: int = 0
    prefills: int = 0          # requests admitted (one prefill each, logically)
    prefill_calls: int = 0     # compiled model dispatches spent on admission
    # watchdog ledger (preempt/resume fault tolerance)
    submitted: int = 0         # requests accepted into the queue
    preemptions: int = 0       # live slots snapshotted + freed
    evicted: int = 0           # snapshots handed out (preempted + drained)
    resumed: int = 0           # snapshots re-admitted on this engine
    recovered_tokens: int = 0  # tokens carried into a resume (not re-sampled)
    lost_tokens: int = 0       # generated tokens discarded (timeout/failure)
    duplicated_tokens: int = 0 # tokens re-emitted past a delivery high-water
    #                            mark — MUST stay 0; nonzero means a request
    #                            was resumed behind its own stream
    shed_tokens: int = 0       # max_new_tokens haircut under brownout

    def summary(self) -> dict:
        ttfts = [r.ttft for r in self.completed if r.ttft is not None]
        e2es = [r.e2e for r in self.completed if r.e2e is not None]
        tbts = [r.tbt for r in self.completed if r.tbt is not None]
        f = lambda xs: float(np.mean(xs)) if xs else 0.0
        out = {"num_completed": len(self.completed), "steps": self.steps,
               "prefills": self.prefills, "prefill_calls": self.prefill_calls,
               "rejected": len(self.rejected),
               "timed_out": len(self.timed_out),
               "submitted": self.submitted,
               "preemptions": self.preemptions,
               "resumed": self.resumed,
               "served_tokens": sum(len(r.tokens) for r in self.completed),
               "recovered_tokens": self.recovered_tokens,
               "lost_tokens": self.lost_tokens,
               "duplicated_tokens": self.duplicated_tokens,
               "shed_tokens": self.shed_tokens,
               "mean_ttft": f(ttfts), "mean_tbt": f(tbts), "mean_e2e": f(e2es)}
        # tail percentiles: what the goodput accounting and the serving
        # bench consume — burst admission shows up in p99, not the mean
        for name, xs in (("ttft", ttfts), ("tbt", tbts), ("e2e", e2es)):
            out[f"p50_{name}"] = _pct(xs, 50)
            out[f"p99_{name}"] = _pct(xs, 99)
        return out


class ServingEngine:
    """Continuous-batching engine over one model replica.

    ``admit_mode``: "batched" (default — grouped prefill + chunked extend
    tails) or "serial" (the reference: one request at a time, B=1 decode
    tail). Token streams are bit-identical between the two.
    ``admit_token_budget``: max prompt tokens admitted per step (None =
    unlimited); bounds TBT inflation for live slots under bursts.
    ``queue_watermark``: max waiting-queue depth before ``submit`` rejects
    (None = unbounded) — the fail-fast half of backpressure; the
    shed-to-shorter half is ``set_brownout``.
    """

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_token: int = -1, seed: int = 0,
                 clock=None, admit_mode: str = "batched",
                 admit_token_budget: Optional[int] = None,
                 queue_watermark: Optional[int] = None):
        if admit_mode not in ("batched", "serial"):
            raise ValueError(f"admit_mode {admit_mode!r}")
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos = eos_token
        self.admit_mode = admit_mode
        self.admit_token_budget = admit_token_budget
        self.queue_watermark = queue_watermark
        self.seed = seed
        self.brownout = 1.0
        self._base_key = jax.random.key(seed)
        self._clock = clock or time.perf_counter
        self._has_deadlines = False

        from repro.models import transformer as T
        self.cache = T.make_decode_cache(self.cfg, max_batch, max_seq)
        self.active: list[Optional[Request]] = [None] * max_batch
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.new_counts = [0] * max_batch
        # per-slot request base keys: fold_in(key(seed), rid), set at
        # admission; step() folds the token index on top (fold_idx), which
        # is bitwise fold_keys(base, rid, idx) — but lets a resumed request
        # carry its ORIGIN seed onto this engine (cross-engine identity)
        self._slot_keys = fold_keys(self._base_key,
                                    jnp.zeros((max_batch,), jnp.int32),
                                    jnp.zeros((max_batch,), jnp.int32))
        self.waiting: deque[Request] = deque()
        self.metrics = EngineMetrics(completed=[])
        self._decode = jax.jit(model.decode_fn)
        self._prefill = jax.jit(model.prefill_fn)
        self._extend = jax.jit(self._masked_extend)
        # zeros template for the serial-mode B=1 prompt-tail continuation;
        # built lazily — batched mode (the default) never needs it
        self._b1_cache = None

    # --------------------------------------------------------------- admit
    def submit(self, req: Request) -> bool:
        """Queue a request. Returns False (and records a rejection) when
        the queue is past ``queue_watermark`` — backpressure fails fast
        instead of letting deadlines rot in an unbounded queue."""
        self.metrics.submitted += 1
        if (self.queue_watermark is not None
                and len(self.waiting) >= self.queue_watermark):
            req.finish_s = self._clock()
            self.metrics.rejected.append(req)
            return False
        if req.deadline_s is not None:
            self._has_deadlines = True
        self.waiting.append(req)
        return True

    def _request_base_key(self, req: Request):
        """fold_in(key(seed), rid) — the request's stream base. A resumed
        request's carried ``seed`` overrides the engine seed, so the
        stream it continues is the one its origin engine started."""
        base = (self._base_key if req.seed is None
                else jax.random.key(req.seed))
        return jax.random.fold_in(base, req.rid)

    @staticmethod
    def _effective_prompt(req: Request) -> np.ndarray:
        """What admission must prefill: the prompt plus every token already
        generated before a preemption — replaying the transcript rebuilds
        the decode cache exactly as the uninterrupted run had it."""
        if not req.tokens:
            return np.asarray(req.prompt, np.int32)
        return np.concatenate([np.asarray(req.prompt, np.int32),
                               np.asarray(req.tokens, np.int32)])

    def _masked_extend(self, params, tokens, mask, cache):
        """One extend chunk over the full engine cache; rows with
        ``mask[b] == False`` keep their old cache bits (so live decode
        slots and idle slots are untouched). Compiled once per chunk
        length — the engine batch is fixed."""
        logits, new_cache = self.model.extend_fn(params, {"tokens": tokens},
                                                 cache)

        def sel(new, old):
            m = mask if new.ndim <= 1 else mask.reshape(
                (1, new.shape[1]) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        return logits, jax.tree.map(sel, new_cache, cache)

    def _prefill_inputs(self, tokens: np.ndarray) -> dict:
        inputs: dict[str, Any] = {"tokens": jnp.asarray(tokens, jnp.int32)}
        B = tokens.shape[0]
        if self.cfg.family == "encdec":
            inputs["frames"] = jnp.zeros(
                (B, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        if self.cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (B, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        return inputs

    def _finalize_admits(self, items: list, logits) -> None:
        """Sample first tokens for every request finalized by one model
        call and make their slots live — ONE batched (fold_keys,
        sample_batch) dispatch and one host sync for the whole group.
        Per-row keys make each row's draw bitwise identical to a B=1 call
        through the same pair, so grouping (and the decode step's own
        sampling) can never change a stream.

        items: [(slot, req, row)] with ``row`` indexing ``logits``.
        """
        if not items:
            return
        rows = jnp.asarray([row for _, _, row in items], jnp.int32)
        slot_arr = jnp.asarray([slot for slot, _, _ in items], jnp.int32)
        idxs = jnp.asarray([len(req.tokens) for _, req, _ in items],
                           jnp.int32)
        temps = jnp.asarray([req.temperature for _, req, _ in items],
                            jnp.float32)
        # slot base keys were pinned at admission (engine seed or the
        # request's carried seed); the token index is len(tokens) — 0 for
        # a fresh request, the resume point for a replayed transcript —
        # so a resumed stream continues exactly where it left off
        keys = fold_idx(self._slot_keys[slot_arr], idxs)
        toks = np.asarray(sample_batch(logits[rows], keys, temps))
        now = self._clock()
        live_slots, live_toks = [], []
        for j, (slot, req, _) in enumerate(items):
            tok = int(toks[j])
            req.tokens.append(tok)
            if req.prefill_done_s is None:
                # a resumed request keeps its ORIGINAL prefill time: TTFT
                # measures when the user first saw a token, not the replay
                req.prefill_done_s = now
            self.metrics.prefills += 1
            if len(req.tokens) >= req.max_new_tokens or tok == self.eos:
                # complete at admission: the prompt's last logits already
                # gave the only remaining requested (or an EOS) token —
                # the slot never goes live, no unrequested decode runs
                req.finish_s = now
                self.metrics.completed.append(req)
                self.release_slot(slot)
                continue
            self.active[slot] = req
            self.new_counts[slot] = len(req.tokens)
            live_slots.append(slot)
            live_toks.append(tok)
        if live_slots:
            self.last_token = self.last_token.at[jnp.asarray(live_slots)].set(
                jnp.asarray(live_toks, jnp.int32))

    def _sweep_waiting_deadlines(self, now: float) -> None:
        """Expire queued requests whose absolute deadline has passed —
        before admission, so a dead request never burns prefill compute."""
        keep: deque[Request] = deque()
        while self.waiting:
            req = self.waiting.popleft()
            if req.deadline_s is not None and now >= req.deadline_s:
                req.finish_s = now
                self.metrics.timed_out.append(req)
                self.metrics.lost_tokens += len(req.tokens)
            else:
                keep.append(req)
        self.waiting = keep

    def _admit(self) -> None:
        if not self.waiting:
            return
        now = self._clock()
        if self._has_deadlines:
            self._sweep_waiting_deadlines(now)
        free = [i for i, r in enumerate(self.active) if r is None]
        admits: list[tuple[int, Request]] = []
        held: list[Request] = []       # backoff-gated, keep queue order
        spent = 0
        budget = self.admit_token_budget
        if budget is not None and self.brownout < 1.0:
            # brownout scales how much prefill work one step may take on
            budget = max(1, int(budget * self.brownout))
        # VLM rows spend cache positions on the patch prefix too (enc-dec
        # frames live in the separate encoder cache, so they don't)
        prefix = (self.cfg.num_prefix_embeddings
                  if self.cfg.family == "vlm" else 0)
        while self.waiting and free:
            req = self.waiting[0]
            if req.not_before_s > now:
                # retry backoff: not eligible yet — hold WITHOUT blocking
                # the requests behind it (no head-of-line starvation)
                held.append(self.waiting.popleft())
                continue
            # effective prompt length: a resumed transcript replays
            # prompt + generated prefix through the prefill path
            S = len(req.prompt) + len(req.tokens)
            if req.max_new_tokens <= len(req.tokens):
                # degenerate but legal: nothing (left) to generate —
                # complete as-is, no slot, no prefill
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.completed.append(req)
                continue
            if (len(req.prompt) == 0 or
                    prefix + len(req.prompt) + req.max_new_tokens - 1
                    > self.max_seq):
                # can never fit this engine's cache: reject without
                # consuming a slot (burst-proof: the queue keeps draining)
                self.waiting.popleft()
                req.finish_s = self._clock()
                self.metrics.lost_tokens += len(req.tokens)
                self.metrics.rejected.append(req)
                continue
            if (admits and budget is not None and spent + S > budget):
                break  # budget spent; the rest waits for the next step
            self.waiting.popleft()
            if self.brownout < 1.0 and not req.tokens:
                # graceful degradation: fresh admissions under brownout
                # shed max_new_tokens instead of being dropped (resumed
                # transcripts keep their contract — shedding them would
                # break the bit-identity anchor)
                want = req.max_new_tokens
                shed_to = max(1, int(math.ceil(want * self.brownout)))
                if shed_to < want:
                    self.metrics.shed_tokens += want - shed_to
                    req.max_new_tokens = shed_to
            admits.append((free.pop(0), req))
            spent += S
        if held:
            self.waiting.extendleft(reversed(held))
        if not admits:
            return
        for slot, req in admits:
            self._slot_keys = self._slot_keys.at[slot].set(
                self._request_base_key(req))
        try:
            if self.admit_mode == "serial":
                for slot, req in admits:
                    self._admit_serial(slot, req)
            else:
                self._admit_batched(admits)
        except Exception:
            # a failed admission must not strand its round-mates: anything
            # not yet live goes back to the FRONT of the queue with clean
            # state, so completed + rejected + waiting + active always
            # reconciles. (Serial mode attributes the failure and records
            # that one request as rejected; batched failures cannot be
            # attributed to a single request, so everything is retried.)
            # Membership is by identity: Request.__eq__ would compare
            # ndarray prompts and raise. A resumed request keeps its
            # carried transcript prefix — only tokens sampled during the
            # failed round are rolled back.
            requeue = []
            for slot, req in admits:
                settled = (self.active[slot] is req
                           or any(r is req for r in self.metrics.completed)
                           or any(r is req for r in self.metrics.rejected))
                if not settled:
                    del req.tokens[req.resumed_from:]
                    self.release_slot(slot)
                    requeue.append(req)
            self.waiting.extendleft(reversed(requeue))
            raise

    def _admit_serial(self, slot: int, req: Request) -> None:
        """Reference path: pow2-prefix prefill + serial B=1 decode tail."""
        full = self._effective_prompt(req)
        S = len(full)
        bucket = 1 << (S.bit_length() - 1)
        logits, req_cache = self._prefill(
            self.params, self._prefill_inputs(full[None, :bucket]))
        self.metrics.prefill_calls += 1
        if bucket < S:
            # continue the prompt token-by-token at B=1: decode(prefill
            # of a prefix) is the exact sequential continuation, so the
            # final logits/cache match a full-length prefill
            if self._b1_cache is None:
                from repro.models import transformer as T
                self._b1_cache = T.make_decode_cache(self.cfg, 1, self.max_seq)
            req_cache = insert_cache(self._b1_cache, req_cache, 0)
            for tok in full[bucket:]:
                logits, req_cache = self._decode(
                    self.params, {"token": jnp.asarray([tok], jnp.int32)},
                    req_cache)
                self.metrics.prefill_calls += 1
        try:
            self.cache = insert_cache(self.cache, req_cache, slot)
            self._finalize_admits([(slot, req, 0)], logits)
        except Exception:
            self._reject_failed(slot, req)
            raise

    def _reject_failed(self, slot: int, req: Request) -> None:
        """Admission error path: release the slot and record the failing
        request as rejected, keeping the engine's accounting consistent
        (completed + rejected + waiting + active == submitted). A resumed
        request keeps its carried transcript prefix (and original TTFT)
        so a failover layer can still retry it elsewhere."""
        self.release_slot(slot)
        self.metrics.lost_tokens += max(0, len(req.tokens) - req.resumed_from)
        del req.tokens[req.resumed_from:]
        if req.resumed_from == 0:
            req.prefill_done_s = None
        req.finish_s = self._clock()
        self.metrics.rejected.append(req)

    def _admit_batched(self, admits: list) -> None:
        """Grouped prefill + shared descending-pow2 extend tails. Operates
        on the *effective* prompt (prompt + resumed transcript prefix), so
        a resumed request rides the same pipeline as a fresh one."""
        groups: dict[int, list] = {}
        for slot, req in admits:
            full = self._effective_prompt(req)
            bucket = 1 << (len(full).bit_length() - 1)
            groups.setdefault(bucket, []).append((slot, req, full))
        pend: dict[int, list] = {}          # slot -> [req, full, consumed]
        for bucket in sorted(groups, reverse=True):
            group = groups[bucket]
            kp = 1 << (len(group) - 1).bit_length()   # pow2-padded batch
            toks = np.zeros((kp, bucket), np.int32)
            # padding rows scatter to slot id max_batch -> dropped
            slots = np.full((kp,), self.max_batch, np.int32)
            for r, (slot, req, full) in enumerate(group):
                toks[r] = full[:bucket]
                slots[r] = slot
            logits, gcache = self._prefill(self.params,
                                           self._prefill_inputs(toks))
            self.metrics.prefill_calls += 1
            self.cache = insert_cache_rows(self.cache, gcache,
                                           jnp.asarray(slots))
            fins = []
            for r, (slot, req, full) in enumerate(group):
                if bucket == len(full):
                    fins.append((slot, req, r))
                else:
                    pend[slot] = [req, full, bucket]
            self._finalize_admits(fins, logits)
        while pend:
            # chunk = the largest remaining binary digit across pending
            # rows; every row with that bit set advances this round
            C = max(1 << ((len(full) - cons).bit_length() - 1)
                    for req, full, cons in pend.values())
            toks = np.zeros((self.max_batch, C), np.int32)
            mask = np.zeros((self.max_batch,), bool)
            takers = []
            for slot, (req, full, cons) in pend.items():
                if (len(full) - cons) & C:
                    toks[slot] = full[cons:cons + C]
                    mask[slot] = True
                    takers.append(slot)
            logits, self.cache = self._extend(
                self.params, jnp.asarray(toks), jnp.asarray(mask), self.cache)
            self.metrics.prefill_calls += 1
            fins = []
            for slot in takers:
                req, full, cons = pend[slot]
                cons += C
                if cons == len(full):
                    del pend[slot]
                    fins.append((slot, req, slot))
                else:
                    pend[slot][2] = cons
            self._finalize_admits(fins, logits)

    # --------------------------------------------------------------- slots
    def release_slot(self, slot: int) -> None:
        """Family-agnostic slot retirement: clear the slot's bookkeeping
        and zero its cache position, so every family's valid-length reads
        mask out the stale cache rows. Used on sequence finish, preemption
        and admission error paths. Idempotent — releasing a free (or
        never-admitted) slot is a no-op; an out-of-range slot id raises."""
        if not 0 <= slot < self.max_batch:
            raise ValueError(
                f"slot {slot} out of range [0, {self.max_batch})")
        self.active[slot] = None
        self.new_counts[slot] = 0
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)

    # ----------------------------------------------------- preempt / resume
    def preempt(self, slots: Optional[list] = None) -> list[TranscriptSnapshot]:
        """Snapshot in-flight requests and free their cache slots.

        ``slots=None`` preempts every live slot (the power-drop path).
        Each snapshot carries the full transcript and the seed that keys
        the request's sampling stream, so ``resume`` — here or on any
        other engine serving the same model — continues it bit-identically.
        """
        if slots is None:
            slots = [i for i, r in enumerate(self.active) if r is not None]
        snaps = []
        for slot in slots:
            req = self.active[slot]
            if req is None:
                continue
            seed = req.seed if req.seed is not None else self.seed
            snaps.append(TranscriptSnapshot.from_request(req, seed=seed))
            self.metrics.preemptions += 1
            self.metrics.evicted += 1
            self.release_slot(slot)
        return snaps

    def drain(self) -> list[TranscriptSnapshot]:
        """Site-death path: preempt every live slot AND evict the waiting
        queue — everything this engine owes comes back as snapshots for a
        failover layer to carry to surviving sites."""
        snaps = self.preempt()
        while self.waiting:
            req = self.waiting.popleft()
            seed = req.seed if req.seed is not None else self.seed
            snaps.append(TranscriptSnapshot.from_request(req, seed=seed))
            self.metrics.evicted += 1
        return snaps

    def resume(self, snap: TranscriptSnapshot, *,
               not_before_s: float = 0.0) -> Optional[Request]:
        """Re-admit a preempted transcript. The carried seed keeps the
        stream's keys; the carried ``prefill_done_s`` keeps the original
        TTFT honest. Returns the queued Request, or None when the
        watermark rejected it (the caller keeps the snapshot and may retry
        elsewhere)."""
        req = Request(rid=snap.rid,
                      prompt=np.asarray(snap.prompt, np.int32),
                      max_new_tokens=snap.max_new_tokens,
                      arrival_s=snap.arrival_s,
                      temperature=snap.temperature,
                      seed=snap.seed,
                      deadline_s=snap.deadline_s,
                      not_before_s=not_before_s,
                      attempts=snap.attempts,
                      resumed_from=len(snap.tokens),
                      tokens=list(snap.tokens),
                      prefill_done_s=snap.prefill_done_s)
        if not self.submit(req):
            return None
        self.metrics.resumed += 1
        self.metrics.recovered_tokens += len(snap.tokens)
        return req

    def set_brownout(self, frac: float) -> None:
        """Enter (or leave, frac=1.0) brownout: fresh admissions shed
        ``max_new_tokens`` to ``ceil(frac * requested)`` and the per-step
        admission token budget scales by ``frac`` — graceful degradation
        under a power drop instead of wholesale drops."""
        self.brownout = float(min(max(frac, 0.0), 1.0))

    def reconcile(self) -> dict:
        """Watchdog: every submitted request must be in exactly one of
        completed / rejected / timed_out / waiting / active / evicted
        (handed out as a snapshot). Returns the books and a ``balanced``
        flag — an unbalanced ledger means the engine leaked a request."""
        m = self.metrics
        books = {"submitted": m.submitted,
                 "completed": len(m.completed),
                 "rejected": len(m.rejected),
                 "timed_out": len(m.timed_out),
                 "waiting": len(self.waiting),
                 "active": sum(r is not None for r in self.active),
                 "evicted": m.evicted}
        books["balanced"] = (
            books["submitted"] == books["completed"] + books["rejected"]
            + books["timed_out"] + books["waiting"] + books["active"]
            + books["evicted"])
        return books

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit waiting requests, run one batched decode. Returns #active."""
        if self._has_deadlines:
            now = self._clock()
            for i, r in enumerate(self.active):
                if (r is not None and r.deadline_s is not None
                        and now >= r.deadline_s):
                    r.finish_s = now
                    self.metrics.timed_out.append(r)
                    self.metrics.lost_tokens += len(r.tokens)
                    self.release_slot(i)
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        logits, self.cache = self._decode(
            self.params, {"token": self.last_token}, self.cache)
        temps = np.zeros(self.max_batch, np.float32)
        idxs = np.zeros(self.max_batch, np.int32)
        for i in live:
            temps[i] = self.active[i].temperature
            idxs[i] = len(self.active[i].tokens)
        # per-(request, token-index) keys + per-row temperatures: a row's
        # draw is independent of its batch-mates and its admission order.
        # Slot base keys were pinned at admission (fold_idx on top equals
        # fold_keys bitwise), so a resumed request keeps its origin stream
        keys = fold_idx(self._slot_keys, jnp.asarray(idxs))
        toks = sample_batch(logits, keys, jnp.asarray(temps))
        toks_np = np.asarray(toks)
        self.last_token = toks
        self.metrics.steps += 1
        now = self._clock()
        for i in live:
            req = self.active[i]
            req.tokens.append(int(toks_np[i]))
            self.new_counts[i] += 1
            done = (self.new_counts[i] >= req.max_new_tokens
                    or int(toks_np[i]) == self.eos)
            if done:
                req.finish_s = now
                self.metrics.completed.append(req)
                self.release_slot(i)
        return len([r for r in self.active if r is not None])

    def run(self, max_steps: int = 10_000) -> EngineMetrics:
        """Drain all waiting + active requests."""
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.waiting:
                break
        return self.metrics
