"""Site-local serving engine: KV-cache slots + continuous batching.

This is the per-site engine the paper assumes (vLLM in their testbed) —
built here in JAX because Heron needs a real serving substrate to route
into. Design:

  * a fixed pool of ``max_batch`` cache *slots*; each slot owns one
    sequence's decode cache (KV / recurrent state, family-specific pytree);
  * **continuous batching**: new requests are admitted into free slots via
    single-request prefill + cache insertion; every engine step runs ONE
    batched decode over all slots (fixed shapes → one compiled program);
  * finished sequences retire their slot immediately — no batch barriers;
  * per-request TTFT / TBT / E2E metrics against the class SLOs, which is
    what Heron's goodput accounting consumes.

Cache insertion is family-agnostic: every cache leaf is [B]-batched at
axis 0 (1-D leaves like ``pos``) or axis 1 (stacked [L, B, ...] leaves),
so one ``dynamic_update_slice`` rule covers GQA/MLA/SSM/hybrid/enc-dec.

Compile-cache discipline: prefill is jitted per input shape, so admitting
raw prompts would compile one program per distinct prompt length. Instead
``_admit`` chunks the prompt to its largest power-of-2 prefix (prefill)
and feeds the remaining tokens through the already-compiled single-token
decode — numerically identical to a full-length prefill for every cache
family (attention and recurrent alike, since decode *is* the sequential
continuation), while keeping the prefill compile cache at O(log max_seq)
entries. Right-padding instead would corrupt recurrent/SSM states and
shift the last-token logits, so it is deliberately not used. Trade-off:
the tail is up to bucket-1 (~S/2) serial B=1 decode steps, so admission
is O(S) in the worst case — cheap per step once compiled, but a future
PR could chunk the tail through descending power-of-2 prefill chunks if
prefill ever learns to continue from an existing cache.

Sampling honours per-request temperatures within one batched decode:
``sample`` takes a per-row temperature vector, so greedy (t == 0) and
sampled (t > 0) requests coexist in the same step without collapsing the
batch to a single temperature.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import Model
from repro.serving.sampling import sample


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0
    temperature: float = 0.0
    # filled by the engine
    tokens: list = field(default_factory=list)
    prefill_done_s: Optional[float] = None
    finish_s: Optional[float] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.prefill_done_s is None:
            return None
        return self.prefill_done_s - self.arrival_s

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    @property
    def tbt(self) -> Optional[float]:
        if self.finish_s is None or len(self.tokens) < 2:
            return None
        return (self.finish_s - self.prefill_done_s) / max(len(self.tokens) - 1, 1)


def _insert_leaf(engine_leaf, req_leaf, slot: int):
    """Write a single-sequence cache leaf into slot ``slot``."""
    req_leaf = req_leaf.astype(engine_leaf.dtype)
    if engine_leaf.ndim == 1:                       # e.g. pos: [B]
        return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, (slot,))
    # stacked leaves: [L, B, ...] — batch at axis 1, write at origin elsewhere
    start = (0, slot) + (0,) * (engine_leaf.ndim - 2)
    return jax.lax.dynamic_update_slice(engine_leaf, req_leaf, start)


@jax.jit
def insert_cache(engine_cache, req_cache, slot):
    """Insert a B=1 request cache into the engine's slot ``slot``."""
    return jax.tree.map(lambda e, r: _insert_leaf(e, r, slot),
                        engine_cache, req_cache)


@dataclass
class EngineMetrics:
    completed: list
    steps: int = 0
    prefills: int = 0

    def summary(self) -> dict:
        ttfts = [r.ttft for r in self.completed if r.ttft is not None]
        e2es = [r.e2e for r in self.completed if r.e2e is not None]
        tbts = [r.tbt for r in self.completed if r.tbt is not None]
        f = lambda xs: float(np.mean(xs)) if xs else 0.0
        return {"num_completed": len(self.completed), "steps": self.steps,
                "prefills": self.prefills, "mean_ttft": f(ttfts),
                "mean_tbt": f(tbts), "mean_e2e": f(e2es)}


class ServingEngine:
    """Continuous-batching engine over one model replica."""

    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_token: int = -1, seed: int = 0,
                 clock=None):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos = eos_token
        self._key = jax.random.key(seed)
        self._clock = clock or time.perf_counter

        from repro.models import transformer as T
        self.cache = T.make_decode_cache(self.cfg, max_batch, max_seq)
        self.active: list[Optional[Request]] = [None] * max_batch
        self.last_token = jnp.zeros((max_batch,), jnp.int32)
        self.new_counts = [0] * max_batch
        self.waiting: list[Request] = []
        self.metrics = EngineMetrics(completed=[])
        self._decode = jax.jit(model.decode_fn)
        self._prefill = jax.jit(model.prefill_fn)
        # zeros template for the B=1 prompt-tail continuation (immutable)
        self._b1_cache = T.make_decode_cache(self.cfg, 1, max_seq)

    # --------------------------------------------------------------- admit
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _admit(self) -> None:
        while self.waiting:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.waiting.pop(0)
            S = len(req.prompt)
            # largest power-of-2 prefix through prefill; the tail goes
            # through the already-compiled decode (see module docstring)
            bucket = 1 << (max(S, 1).bit_length() - 1)
            prompt = jnp.asarray(req.prompt[:bucket], jnp.int32)[None]
            inputs = {"tokens": prompt}
            if self.cfg.family == "encdec":
                inputs["frames"] = jnp.zeros(
                    (1, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            if self.cfg.family == "vlm":
                inputs["patches"] = jnp.zeros(
                    (1, self.cfg.num_prefix_embeddings, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            logits, req_cache = self._prefill(self.params, inputs)
            if bucket < S:
                # continue the prompt token-by-token at B=1: decode(prefill
                # of a prefix) is the exact sequential continuation, so the
                # final logits/cache match a full-length prefill
                req_cache = insert_cache(self._b1_cache, req_cache, 0)
                for tok in req.prompt[bucket:]:
                    logits, req_cache = self._decode(
                        self.params, {"token": jnp.asarray([tok], jnp.int32)},
                        req_cache)
            self._key, k = jax.random.split(self._key)
            tok = sample(logits, k, req.temperature)
            req.tokens.append(int(tok[0]))
            req.prefill_done_s = self._clock()
            self.cache = insert_cache(self.cache, req_cache, slot)
            self.last_token = self.last_token.at[slot].set(tok[0])
            self.active[slot] = req
            self.new_counts[slot] = 1
            self.metrics.prefills += 1

    # --------------------------------------------------------------- step
    def step(self) -> int:
        """Admit waiting requests, run one batched decode. Returns #active."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        logits, self.cache = self._decode(
            self.params, {"token": self.last_token}, self.cache)
        self._key, k = jax.random.split(self._key)
        temps = np.zeros(self.max_batch, np.float32)
        for i in live:
            temps[i] = self.active[i].temperature
        # per-row temperatures: greedy and sampled requests coexist
        toks = sample(logits, k, jnp.asarray(temps))
        toks_np = np.asarray(toks)
        self.last_token = toks
        self.metrics.steps += 1
        now = self._clock()
        for i in live:
            req = self.active[i]
            req.tokens.append(int(toks_np[i]))
            self.new_counts[i] += 1
            done = (self.new_counts[i] >= req.max_new_tokens
                    or int(toks_np[i]) == self.eos)
            if done:
                req.finish_s = now
                self.metrics.completed.append(req)
                self.active[i] = None
                # zero the slot's position so its cache reads are masked
                self.cache["pos"] = self.cache["pos"].at[i].set(0)
        return len([r for r in self.active if r is not None])

    def run(self, max_steps: int = 10_000) -> EngineMetrics:
        """Drain all waiting + active requests."""
        for _ in range(max_steps):
            n = self.step()
            if n == 0 and not self.waiting:
                break
        return self.metrics
