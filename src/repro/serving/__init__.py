"""JAX serving engine: KV-cache slots, batched continuous batching, sampling."""
from repro.serving.engine import EngineMetrics, Request, ServingEngine
from repro.serving.sampling import fold_keys, sample, sample_batch

__all__ = ["EngineMetrics", "Request", "ServingEngine", "fold_keys",
           "sample", "sample_batch"]
