"""JAX serving engine: KV-cache slots, continuous batching, sampling."""
from repro.serving.engine import EngineMetrics, Request, ServingEngine
from repro.serving.sampling import sample

__all__ = ["EngineMetrics", "Request", "ServingEngine", "sample"]
