"""Token sampling for the serving engine (greedy / temperature / top-k)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("top_k",))
def sample(logits, key, temperature=0.0, top_k: int = 0):
    """logits: [B, V] -> tokens [B] int32.

    ``temperature`` is a scalar or a per-row [B] vector — rows with
    temperature == 0 decode greedily while the rest sample, so greedy and
    sampled requests coexist in one continuously-batched decode step.
    top_k > 0 restricts sampling to the k most likely tokens.
    """
    temp = jnp.asarray(temperature, jnp.float32)
    tcol = temp[..., None] if temp.ndim == 1 else temp     # [B, 1] | scalar
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(tcol, 1e-6)
    scaled = logits.astype(jnp.float32) / t
    if top_k:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)
