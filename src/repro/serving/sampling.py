"""Token sampling for the serving engine (greedy / temperature / top-k)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("top_k",))
def sample(logits, key, temperature: float = 0.0, top_k: int = 0):
    """logits: [B, V] -> tokens [B] int32.

    temperature == 0 is greedy. top_k > 0 restricts to the k most likely.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(temperature, 1e-6)
    scaled = logits.astype(jnp.float32) / t
    if top_k:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)
