"""Token sampling for the serving engine (greedy / temperature / top-k).

Two entry points:

  * ``sample`` — one key for the whole batch; kept as a public
    single-stream convenience, no longer used by the engine;
  * ``sample_batch`` + ``fold_keys`` — per-row keys derived from
    (engine seed, request id, token index). Row ``r``'s draw depends only
    on ``keys[r]`` and ``logits[r]``, so a request's token stream is
    bit-identical regardless of admission order, batch composition, or
    which slot it landed in. This is the serving engine's determinism
    contract: the same (seed, rid) always yields the same stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("top_k",))
def sample(logits, key, temperature=0.0, top_k: int = 0):
    """logits: [B, V] -> tokens [B] int32.

    ``temperature`` is a scalar or a per-row [B] vector — rows with
    temperature == 0 decode greedily while the rest sample, so greedy and
    sampled requests coexist in one continuously-batched decode step.
    top_k > 0 restricts sampling to the k most likely tokens.
    """
    temp = jnp.asarray(temperature, jnp.float32)
    tcol = temp[..., None] if temp.ndim == 1 else temp     # [B, 1] | scalar
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(tcol, 1e-6)
    scaled = logits.astype(jnp.float32) / t
    if top_k:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temp <= 0.0, greedy, sampled)


@jax.jit
def fold_keys(base_key, rids, indices):
    """Per-(request, token) sampling keys: fold_in(fold_in(base, rid), idx).

    rids/indices: [B] int32. ``idx`` is the token's index within its
    request's stream (0 = the first token sampled off the prefill logits).
    ``fold_in`` is elementwise-deterministic, so a row's key never depends
    on its batch-mates.
    """
    def one(rid, idx):
        return jax.random.fold_in(jax.random.fold_in(base_key, rid), idx)

    return jax.vmap(one)(rids, indices)


@jax.jit
def fold_idx(keys, indices):
    """Fold per-row token indices into per-row *request base keys*.

    ``keys[r]`` is a request's base key — ``fold_in(key(seed), rid)``,
    where ``seed`` is the engine seed or the request's own carried seed
    (``Request.seed``, set when a preempted transcript is resumed on a
    different engine). ``fold_idx(keys, idx)`` then equals
    ``fold_keys(base, rids, idx)`` row-for-row, so splitting the fold in
    two (rid at admission, idx per step) is bitwise the same scheme —
    which is what lets a request's stream survive a cross-engine
    failover unchanged.
    """
    return jax.vmap(jax.random.fold_in)(keys, indices)


@functools.partial(jax.jit, static_argnames=("top_k",))
def sample_batch(logits, keys, temperature, top_k: int = 0):
    """logits: [B, V]; keys: [B] typed PRNG keys; temperature: [B] or scalar.

    Per-row categorical draws under per-row keys (vmapped, so row ``r``'s
    draw is bitwise what a B=1 call with ``keys[r]`` would produce). Rows
    with temperature == 0 decode greedily and ignore their key.
    """
    temp = jnp.asarray(temperature, jnp.float32)
    tcol = temp[..., None] if temp.ndim == 1 else temp
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.maximum(tcol, 1e-6)
    scaled = logits.astype(jnp.float32) / t
    if top_k:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    sampled = jax.vmap(
        lambda k, row: jax.random.categorical(k, row).astype(jnp.int32)
    )(keys, scaled)
    return jnp.where(temp <= 0.0, greedy, sampled)
