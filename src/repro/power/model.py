"""Accelerator power/performance model.

The paper profiles H100 DGX boxes with DCGM and drives two knobs: tensor
parallelism and GPU frequency (``nvidia-smi``, ms-scale). TPUs expose no
user DVFS, so we keep *frequency* as a first-class planner knob backed by
an explicit analytical model (DESIGN.md §3 hardware adaptation):

    compute throughput  ∝ f / f_max
    HBM bandwidth       ⊥ f                      (memory clock unscaled)
    P(chip)             = P_idle + (P_peak - P_idle) · util · (f/f_max)^ALPHA

ALPHA = 2.4 approximates V·f scaling with DVFS voltage tracking (empir-
ically 2-3 on datacenter accelerators). The node multiplier 1.82× over the
accelerator aggregate is the paper's own constant (10.2 kW DGX vs 8×700 W).

Two hardware profiles ship: ``H100_DGX`` (paper-faithful: TP ∈ {2,4,8},
0.8-2.0 GHz) and ``TPU_V5E`` (our deployment target: TP ∈ {4,8,16}, the
assignment's roofline constants). All Heron experiments run on either —
the router only sees lookup tables.
"""
from __future__ import annotations

from dataclasses import dataclass, field

ALPHA = 2.4                  # DVFS power exponent
NODE_MULTIPLIER = 1.82       # paper §5.1: whole-node / accelerator-aggregate


@dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops: float            # per chip, bf16, at f_max [FLOP/s]
    hbm_bw: float                # per chip [B/s]
    hbm_capacity: float          # per chip [B]
    link_bw: float               # per-link interconnect [B/s]
    chip_peak_power: float       # accelerator-only peak draw [W]
    chip_idle_power: float       # accelerator idle draw [W]
    chips_per_node: int
    tp_degrees: tuple[int, ...]
    frequencies: tuple[float, ...]   # GHz knob values
    f_max: float
    mfu_dense: float = 0.5       # achievable matmul efficiency (prefill/train)
    pod_chips: int = 256

    def node_peak_power(self) -> float:
        return self.chips_per_node * self.chip_peak_power * NODE_MULTIPLIER


H100_DGX = HardwareModel(
    name="h100",
    peak_flops=989e12,           # bf16 dense, SXM
    hbm_bw=3.35e12,
    hbm_capacity=80e9,
    link_bw=450e9,               # NVLink per direction
    chip_peak_power=700.0,
    chip_idle_power=90.0,
    chips_per_node=8,
    tp_degrees=(2, 4, 8),
    frequencies=(0.8, 1.0, 1.2, 1.4, 1.6, 1.8, 2.0),
    f_max=2.0,
)

# Assignment roofline constants: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
TPU_V5E = HardwareModel(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_capacity=16e9,
    link_bw=50e9,
    chip_peak_power=250.0,       # board-level envelope
    chip_idle_power=60.0,
    chips_per_node=8,            # "node" = power-accounting unit (8 chips)
    tp_degrees=(4, 8, 16),
    frequencies=(0.47, 0.56, 0.66, 0.75, 0.85, 0.94, 1.04),  # ~same 7-knob span
    f_max=1.04,
)

HARDWARE = {"h100": H100_DGX, "tpu_v5e": TPU_V5E}


def accelerator_power(hw: HardwareModel, util: float, freq: float) -> float:
    """Per-chip power [W] at ``util`` in [0,1] and frequency ``freq`` [GHz]."""
    util = min(max(util, 0.0), 1.0)
    rel = min(freq / hw.f_max, 1.0)
    return hw.chip_idle_power + (hw.chip_peak_power - hw.chip_idle_power) * util * rel ** ALPHA


def instance_peak_power(hw: HardwareModel, tp: int, util: float, freq: float) -> float:
    """Whole-node-share power of a TP-``tp`` instance (paper's 1.82× applied)."""
    return tp * accelerator_power(hw, util, freq) * NODE_MULTIPLIER


# NVIDIA SuperPOD provisioning unit (paper §2.2): 1,016 H100s, 1.3 MW peak.
SUPERPOD_GPUS = 1016
SUPERPOD_PEAK_MW = 1.3
