"""Grid-interactive power plane: prices, carbon intensity, batteries.

Heron (the paper) routes around power *drops*; the economic case behind
modular wind-site DCs points further — the fleet is a grid-interactive
asset that bids load up and down against electricity **price** and
grid-**carbon** signals and rides through trips on site batteries
(PAPERS.md: "Power-Flexible AI Data Centers", the Phoenix field demo,
XWind). This module is the state for that control dimension, shared by
the rate simulators and the scenario engine:

  * ``GridSignals`` — per-site electricity price curves [$ / MWh] and
    grid-carbon-intensity traces [gCO2 / kWh], ``[S, T]`` like the wind
    series. Scenario events (``PriceSpike`` / ``CarbonRamp``) perturb
    them through multiplicative ``price_factor`` / ``carbon_factor``
    planes with the same truth/knowledge split as power: surprises lag
    in the knowledge plane by their detection delay.
  * ``BatteryBank`` — a per-site battery/UPS state model
    (capacity / charge-rate / discharge-rate / one-way efficiency).
    Charges from surplus wind (power the plan did not draw), discharges
    to ride through grid trips and price spikes. ``step`` advances one
    tick and returns the extra MW actually delivered; energy ledgers
    (``energy_in_mwh`` / ``energy_out_mwh``) let tests assert no free
    energy ever appears (out <= in * round-trip efficiency, SoC always
    in [0, capacity * health]).

Units: MW / MWh / hours throughout (the simulators convert W <-> MW at
the boundary; a 15-min slot is ``dt_h = 0.25``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# Flat defaults when no trace is supplied: cheap wind-heavy node.
DEFAULT_PRICE_USD_MWH = 30.0      # long-run PPA-ish wind price
DEFAULT_CARBON_G_KWH = 20.0       # near-source wind carbon intensity


@dataclass
class GridSignals:
    """Per-site electricity price and grid-carbon-intensity traces.

    ``price_usd_mwh``/``carbon_g_kwh`` are ``[S, T]`` base curves; the
    compiled scenario's ``price_factor``/``carbon_factor`` multiply them
    per tick (truth plane) with ``known_*`` mirrors for what the planner
    can see.
    """
    price_usd_mwh: np.ndarray       # [S, T]
    carbon_g_kwh: np.ndarray        # [S, T]

    @classmethod
    def flat(cls, num_sites: int, ticks: int,
             price: float = DEFAULT_PRICE_USD_MWH,
             carbon: float = DEFAULT_CARBON_G_KWH) -> "GridSignals":
        return cls(price_usd_mwh=np.full((num_sites, ticks), float(price)),
                   carbon_g_kwh=np.full((num_sites, ticks), float(carbon)))

    def slot_cost_usd(self, energy_mwh: np.ndarray, tick: int,
                      factor: Optional[np.ndarray] = None) -> float:
        """$ for per-site energy [S] drawn during ``tick``."""
        p = self.price_usd_mwh[:, tick]
        if factor is not None:
            p = p * factor
        return float(np.dot(energy_mwh, p))

    def slot_carbon_g(self, energy_mwh: np.ndarray, tick: int,
                      factor: Optional[np.ndarray] = None) -> float:
        """gCO2 for per-site energy [S] drawn during ``tick``."""
        ci = self.carbon_g_kwh[:, tick]
        if factor is not None:
            ci = ci * factor
        return float(np.dot(energy_mwh * 1e3, ci))    # MWh -> kWh


@dataclass
class BatteryBank:
    """Per-site battery/UPS fleet state (vectorized over sites).

    One-way ``efficiency`` applies on both charge and discharge, so the
    round trip returns ``efficiency**2`` < 1 of the energy put in. SoC
    is stored energy [MWh]; ``health`` in [0, 1] derates usable capacity
    (the ``BatteryDegradation`` scenario hook).
    """
    capacity_mwh: np.ndarray        # [S]
    charge_rate_mw: np.ndarray      # [S] max grid->battery power
    discharge_rate_mw: np.ndarray   # [S] max battery->load power
    efficiency: float = 0.95        # one-way; round trip = efficiency**2
    soc_mwh: np.ndarray = field(default=None)        # [S] stored energy
    health: np.ndarray = field(default=None)         # [S] capacity derate
    energy_in_mwh: np.ndarray = field(default=None)   # [S] absorbed
    energy_out_mwh: np.ndarray = field(default=None)  # [S] delivered

    def __post_init__(self):
        self.capacity_mwh = np.asarray(self.capacity_mwh, float)
        self.charge_rate_mw = np.asarray(self.charge_rate_mw, float)
        self.discharge_rate_mw = np.asarray(self.discharge_rate_mw, float)
        if self.soc_mwh is None:
            self.soc_mwh = np.zeros_like(self.capacity_mwh)
        else:
            self.soc_mwh = np.asarray(self.soc_mwh, float).copy()
        if self.health is None:
            self.health = np.ones_like(self.capacity_mwh)
        else:
            self.health = np.asarray(self.health, float).copy()
        if self.energy_in_mwh is None:
            self.energy_in_mwh = np.zeros_like(self.capacity_mwh)
        else:
            self.energy_in_mwh = np.asarray(self.energy_in_mwh,
                                            float).copy()
        if self.energy_out_mwh is None:
            self.energy_out_mwh = np.zeros_like(self.capacity_mwh)
        else:
            self.energy_out_mwh = np.asarray(self.energy_out_mwh,
                                             float).copy()

    @classmethod
    def sized(cls, num_sites: int, capacity_mwh: float = 1.0,
              charge_rate_mw: float = 2.0, discharge_rate_mw: float = 2.0,
              efficiency: float = 0.95, soc_frac: float = 0.0
              ) -> "BatteryBank":
        cap = np.full(num_sites, float(capacity_mwh))
        return cls(capacity_mwh=cap,
                   charge_rate_mw=np.full(num_sites, float(charge_rate_mw)),
                   discharge_rate_mw=np.full(num_sites,
                                             float(discharge_rate_mw)),
                   efficiency=float(efficiency),
                   soc_mwh=cap * float(soc_frac))

    @property
    def usable_mwh(self) -> np.ndarray:
        """Per-site usable capacity after health derating."""
        return self.capacity_mwh * np.clip(self.health, 0.0, 1.0)

    def set_health(self, health: np.ndarray) -> None:
        """Apply a degradation trace sample; SoC above the derated
        capacity is lost (the cells can no longer hold it)."""
        self.health = np.clip(np.asarray(health, float), 0.0, 1.0)
        self.soc_mwh = np.minimum(self.soc_mwh, self.usable_mwh)

    def ride_through_mw(self, dt_h: float) -> np.ndarray:
        """Max extra MW each site can sustain for one ``dt_h`` tick —
        the knowledge-plane signal a battery-aware forecast adds on top
        of predicted wind."""
        return np.minimum(self.discharge_rate_mw,
                          self.soc_mwh * self.efficiency / dt_h)

    def step(self, avail_mw: np.ndarray, demand_mw: np.ndarray,
             dt_h: float) -> np.ndarray:
        """Advance one tick. Surplus wind (avail > demand) charges;
        deficit (demand > avail) discharges. Returns per-site MW
        actually delivered from the batteries (0 where charging)."""
        avail_mw = np.asarray(avail_mw, float)
        demand_mw = np.asarray(demand_mw, float)
        surplus = np.maximum(avail_mw - demand_mw, 0.0)
        deficit = np.maximum(demand_mw - avail_mw, 0.0)

        # charge: limited by the charger and by remaining headroom
        # (stored = drawn * efficiency)
        draw_mw = np.minimum(surplus, self.charge_rate_mw)
        headroom = np.maximum(self.usable_mwh - self.soc_mwh, 0.0)
        draw_mw = np.minimum(draw_mw, headroom / (self.efficiency * dt_h))
        stored = draw_mw * dt_h * self.efficiency
        self.soc_mwh = np.minimum(self.soc_mwh + stored, self.usable_mwh)
        self.energy_in_mwh = self.energy_in_mwh + draw_mw * dt_h

        # discharge: limited by the inverter and by stored energy
        # (delivered = withdrawn * efficiency)
        out_mw = np.minimum(deficit, self.discharge_rate_mw)
        out_mw = np.minimum(out_mw, self.soc_mwh * self.efficiency / dt_h)
        withdrawn = out_mw * dt_h / self.efficiency
        self.soc_mwh = np.maximum(self.soc_mwh - withdrawn, 0.0)
        self.energy_out_mwh = self.energy_out_mwh + out_mw * dt_h
        return out_mw

    def copy(self) -> "BatteryBank":
        return BatteryBank(capacity_mwh=self.capacity_mwh.copy(),
                           charge_rate_mw=self.charge_rate_mw.copy(),
                           discharge_rate_mw=self.discharge_rate_mw.copy(),
                           efficiency=self.efficiency,
                           soc_mwh=self.soc_mwh.copy(),
                           health=self.health.copy(),
                           energy_in_mwh=self.energy_in_mwh.copy(),
                           energy_out_mwh=self.energy_out_mwh.copy())
