from repro.power.model import HardwareModel, H100_DGX, TPU_V5E, accelerator_power

__all__ = ["HardwareModel", "H100_DGX", "TPU_V5E", "accelerator_power"]
