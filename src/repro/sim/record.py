"""JSON run records under artifacts/ — shared by simulators and launch tools.

One tiny contract: ``write_record(path, payload)`` creates parent
directories and writes indented JSON (numpy scalars coerced via
``default=float``); ``load_record(path)`` reads it back. The week/fine
simulators persist their results here (``artifacts/sim/``) so benchmarks
can *reload* a run instead of re-simulating it, and the dry-run launcher
uses the same writer for its ``artifacts/dryrun/`` reports.
"""
from __future__ import annotations

import json
import os


def write_record(path: str, payload: dict) -> str:
    """Write ``payload`` as JSON at ``path``, creating directories."""
    d = os.path.dirname(os.path.abspath(path))
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_record(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
