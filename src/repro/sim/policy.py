"""RoutingPolicy — the pluggable control-plane interface of the simulators.

A routing policy is anything that can run the paper's control loop:

    plan_slot(pred_power_w, pred_load) -> Plan     Planner-L cadence (15 min)
    plan_fine(now, power_w, observed_load) -> Plan Planner-S cadence (~5 s)
    route(groups, arrivals) -> DispatchResult      Request Scheduler dispatch
    observe(latency, mask=None)                    per-site health feedback
    on_event(event)                                ScenarioEngine controls

``HeronRouter`` implements it natively (both objectives) — so
``simulate_week("heron", ...)`` now drives the *actual* router object,
straggler EWMA, site up/down marking and Configurator freeze windows
included, instead of a parallel inlined planning loop. The two paper
baselines are wrapped by ``WrrDynamoLLMPolicy`` / ``GreedyMinLatencyPolicy``
(power-variability agnostic: they ignore power predictions, health
feedback, and control events — which is exactly why scenarios hurt them).

The name->factory registry keeps the legacy string API working
(``simulate_week("wrr_dynamollm", ...)``) and is the extension point for
new baselines: ``register_policy("mine", my_factory)`` and every driver,
benchmark, and example picks it up. Factories receive
``(table, sites, **kwargs)`` where kwargs are the driver's standard knobs
(``r_frac``, ``time_limit``, ``planner_method``, ``planner_workers``,
``packing``, the Heron straggler knobs ``straggler_alpha`` /
``straggler_threshold`` / ``straggler_min_haircut``, and the
event-driven Planner-L knobs ``incremental`` / ``dirty_tol`` routing
slot solves through a persistent ``PlannerLSession``) — ignore what
does not apply.

Failover (optional extension): a policy may additionally expose
``failover_order(site) -> list[int]`` — the preferred landing order for
in-flight work drained off a dying ``site``. ``sim.cluster.
ServingCluster`` consults it when carrying preempted transcripts to
surviving sites; policies without it (both baselines) get
alive-sites-by-index failover. It is deliberately NOT part of the
Protocol body: the contract's required surface stays the five lifecycle
methods above, and ``isinstance`` checks keep working for minimal
policies.
"""
from __future__ import annotations

from typing import Callable, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.baselines import (GreedyMinLatencyPolicy, WrrDynamoLLMPolicy)
from repro.core.lookup import LookupTable
from repro.core.planner_l import Plan, SiteSpec
from repro.core.router import (STRAGGLER_ALPHA, STRAGGLER_MIN_HAIRCUT,
                               STRAGGLER_THRESHOLD, DRHeronPolicy,
                               HeronRouter, XWindPolicy)
from repro.core.scheduler import DispatchResult


@runtime_checkable
class RoutingPolicy(Protocol):
    """Structural interface — see module docstring for the lifecycle."""
    name: str

    def plan_slot(self, pred_power_w: np.ndarray,
                  pred_load: np.ndarray) -> Plan: ...

    def plan_fine(self, now: float, power_w: np.ndarray,
                  observed_load: np.ndarray) -> Plan: ...

    def route(self, groups, arrivals: np.ndarray) -> DispatchResult: ...

    def observe(self, latency: np.ndarray,
                mask: Optional[np.ndarray] = None) -> None: ...

    def on_event(self, event) -> None: ...


PolicyFactory = Callable[..., RoutingPolicy]

_REGISTRY: dict[str, PolicyFactory] = {}


def register_policy(name: str, factory: PolicyFactory) -> None:
    """Register a policy factory under ``name`` (later wins)."""
    _REGISTRY[name] = factory


def list_policies() -> list[str]:
    """Registered policy names, sorted."""
    return sorted(_REGISTRY)


def make_policy(name: str, table: LookupTable, sites: list[SiteSpec],
                **kwargs) -> RoutingPolicy:
    """Instantiate a registered policy; unknown names list what exists."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown routing policy {name!r}; registered policies: "
            f"{', '.join(list_policies())}")
    return _REGISTRY[name](table, sites, **kwargs)


# ------------------------------------------------------------------
# built-in policies
# ------------------------------------------------------------------
def _heron_factory(objective: str) -> PolicyFactory:
    def make(table: LookupTable, sites: list[SiteSpec], *,
             r_frac: float = 0.03, time_limit: float = 20.0,
             planner_method: str = "auto",
             planner_workers: Optional[int] = None,
             packing: bool = False,
             straggler_alpha: float = STRAGGLER_ALPHA,
             straggler_threshold: float = STRAGGLER_THRESHOLD,
             straggler_min_haircut: float = STRAGGLER_MIN_HAIRCUT,
             incremental: bool = False, dirty_tol: float = 0.02,
             **_ignored) -> HeronRouter:
        return HeronRouter(table=table, sites=sites, objective=objective,
                           r_frac=r_frac, time_limit_l=time_limit,
                           planner_method=planner_method,
                           planner_workers=planner_workers, packing=packing,
                           straggler_alpha=straggler_alpha,
                           straggler_threshold=straggler_threshold,
                           straggler_min_haircut=straggler_min_haircut,
                           incremental=incremental, dirty_tol=dirty_tol)
    return make


def _wrr_factory(table: LookupTable, sites: list[SiteSpec], *,
                 time_limit: float = 20.0, **_ignored) -> WrrDynamoLLMPolicy:
    return WrrDynamoLLMPolicy(table=table, sites=sites,
                              time_limit=time_limit)


def _greedy_factory(table: LookupTable, sites: list[SiteSpec],
                    **_ignored) -> GreedyMinLatencyPolicy:
    return GreedyMinLatencyPolicy(table=table, sites=sites)


def _dr_heron_factory(table: LookupTable, sites: list[SiteSpec], *,
                      r_frac: float = 0.03, time_limit: float = 20.0,
                      planner_method: str = "auto",
                      planner_workers: Optional[int] = None,
                      packing: bool = False,
                      dr_curtail_frac: float = 0.8,
                      dr_min_keep: float = 0.25,
                      incremental: bool = False, dirty_tol: float = 0.02,
                      **_ignored) -> DRHeronPolicy:
    """Heron + demand response: sheds into curtailment orders and
    price/carbon spikes (``core.router.DRHeronPolicy``)."""
    return DRHeronPolicy(table=table, sites=sites, objective="latency",
                         r_frac=r_frac, time_limit_l=time_limit,
                         planner_method=planner_method,
                         planner_workers=planner_workers, packing=packing,
                         dr_curtail_frac=dr_curtail_frac,
                         dr_min_keep=dr_min_keep,
                         incremental=incremental, dirty_tol=dirty_tol)


def _xwind_factory(table: LookupTable, sites: list[SiteSpec], *,
                   r_frac: float = 0.03, time_limit: float = 20.0,
                   planner_method: str = "auto",
                   planner_workers: Optional[int] = None,
                   packing: bool = False,
                   **_ignored) -> XWindPolicy:
    """XWind-style cross-site price router: plans under the ``"cost"``
    objective with announced per-site prices as the site-rate signal
    (``core.router.XWindPolicy``)."""
    return XWindPolicy(table=table, sites=sites,
                       r_frac=r_frac, time_limit_l=time_limit,
                       planner_method=planner_method,
                       planner_workers=planner_workers, packing=packing)


register_policy("heron", _heron_factory("latency"))
register_policy("heron_min_power", _heron_factory("power"))
register_policy("wrr_dynamollm", _wrr_factory)
register_policy("greedy_min_latency", _greedy_factory)
register_policy("dr_heron", _dr_heron_factory)
register_policy("xwind", _xwind_factory)
