"""ScenarioEngine — composable, seeded disturbance events for the simulators.

The paper evaluates Heron against power variability that is *already in
the wind traces*; everything beyond that (site failures, grid trips,
curtailment orders, demand surges, straggler onset, predictor error) used
to be out of reach because ``simulate_week`` hardcoded its disturbance
model. This module makes the disturbance model a value: a scenario is a
list of declarative events, compiled once (seeded) into per-tick
perturbation arrays plus a control-event stream, and consumed uniformly
by ``simulate_week`` (tick = 15-min slot) and ``simulate_slot_fine``
(tick = 1 s).

Two planes, mirroring a real fleet:

  * the **data plane** — what actually happens: realized power
    (``power_factor``), realized arrivals (``arrival_factor``), and
    observed per-site service-latency inflation (``latency_factor``,
    1.0 = nominal; the straggler signal the router's EWMA consumes);
  * the **knowledge plane** — what the forecast pipeline can see:
    ``known_power_factor`` / ``known_arrival_factor`` (surprise events
    lag here by their detection delay) and ``pred_noise`` (predictor
    error regimes), plus discrete ``ControlEvent``s (site down/up,
    curtailment orders) delivered to the ``RoutingPolicy`` — the hook
    that exercises ``HeronRouter.mark_site_down`` / site recovery.

The default (event-free) scenario compiles to all-ones factors and an
empty control stream, so scenario-aware drivers are bit-identical to
their pre-scenario behavior — the equivalence guarantee
tests/test_scenarios.py pins.

Events draw randomness only from substreams spawned off the engine seed
(one ``SeedSequence`` child per event), so a scenario is reproducible
end-to-end and insensitive to how many *other* events draw.

Grid-interactive plane (ISSUE 10): the same two-plane split extends to
electricity **price** and grid-**carbon** signals (``price_factor`` /
``carbon_factor`` with ``known_*`` knowledge mirrors multiplying the
``power.grid.GridSignals`` base curves) plus a per-site battery-health
trace (``battery_health``, deratting ``power.grid.BatteryBank``
capacity). ``PriceSpike`` / ``CarbonRamp`` follow the ``GridTrip``
detection-lag idiom — the truth plane moves at ``start`` but the
knowledge plane and the ``PRICE_SPIKE`` / ``CARBON_RAMP`` control only
after ``detect_ticks`` — so a price-aware policy reacts with exactly the
announcement latency the scenario grants it. ``BatteryDegradation`` is
announced (``BATTERY_DEGRADED`` fires at window start).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

# Control-event kinds delivered to RoutingPolicy.on_event
SITE_DOWN = "site_down"
SITE_UP = "site_up"
CURTAILMENT = "curtailment"
CURTAILMENT_LIFTED = "curtailment_lifted"
GRID_TRIP = "grid_trip"             # value = trip depth (fraction lost)
GRID_RESTORED = "grid_restored"
PRICE_SPIKE = "price_spike"         # value = price multiplier
PRICE_NORMAL = "price_normal"
CARBON_RAMP = "carbon_ramp"         # value = carbon-intensity multiplier
CARBON_NORMAL = "carbon_normal"
BATTERY_DEGRADED = "battery_degraded"   # value = remaining health fraction


@dataclass(frozen=True)
class ControlEvent:
    """Discrete notification to the control plane (policy), not the truth."""
    kind: str
    site: int = -1          # -1 = fleet-wide
    value: float = 0.0
    tick: int = 0


@dataclass
class CompiledScenario:
    """Per-tick perturbation arrays (multiplicative factors) + controls.

    ``S`` sites x ``T`` ticks; arrivals are per the 9 request classes.
    All factors default to 1.0 — an empty scenario perturbs nothing.
    """
    num_sites: int
    ticks: int
    power_factor: np.ndarray            # [S, T] realized / base power
    known_power_factor: np.ndarray      # [S, T] what forecasts can see
    pred_noise: np.ndarray              # [S, T] predictor-error multiplier
    arrival_factor: np.ndarray          # [9, T] realized / base arrivals
    known_arrival_factor: np.ndarray    # [9, T] what load planning sees
    latency_factor: np.ndarray          # [S, T] service-latency inflation
    price_factor: np.ndarray = None         # [S, T] realized price mult
    known_price_factor: np.ndarray = None   # [S, T] what planning sees
    carbon_factor: np.ndarray = None        # [S, T] realized carbon mult
    known_carbon_factor: np.ndarray = None  # [S, T] what planning sees
    battery_health: np.ndarray = None       # [S, T] battery capacity derate
    controls: dict[int, list[ControlEvent]] = field(default_factory=dict)

    def __post_init__(self):
        shape = (self.num_sites, self.ticks)
        for name in ("price_factor", "known_price_factor", "carbon_factor",
                     "known_carbon_factor", "battery_health"):
            if getattr(self, name) is None:
                setattr(self, name, np.ones(shape))

    def add_control(self, tick: int, kind: str, site: int = -1,
                    value: float = 0.0) -> None:
        """Schedule a control. Ticks at/beyond the horizon are kept —
        the driver flushes them when the run ends (``controls_after``)
        so a reused policy is not left e.g. permanently site-down by a
        recovery that lands exactly on the horizon boundary."""
        if tick >= 0:
            self.controls.setdefault(tick, []).append(
                ControlEvent(kind=kind, site=site, value=value, tick=tick))

    def controls_at(self, tick: int) -> list[ControlEvent]:
        return self.controls.get(tick, [])

    def controls_after(self, horizon: int) -> list[ControlEvent]:
        """Controls scheduled at/beyond ``horizon``, in tick order —
        delivered by the driver after its last simulated tick."""
        return [ev for tk in sorted(k for k in self.controls
                                    if k >= horizon)
                for ev in self.controls[tk]]

    @property
    def is_trivial(self) -> bool:
        """True when nothing is perturbed (the bit-identical fast path)."""
        return (not self.controls
                and (self.power_factor == 1.0).all()
                and (self.known_power_factor == 1.0).all()
                and (self.pred_noise == 1.0).all()
                and (self.arrival_factor == 1.0).all()
                and (self.known_arrival_factor == 1.0).all()
                and (self.latency_factor == 1.0).all()
                and (self.price_factor == 1.0).all()
                and (self.known_price_factor == 1.0).all()
                and (self.carbon_factor == 1.0).all()
                and (self.known_carbon_factor == 1.0).all()
                and (self.battery_health == 1.0).all())

    # ---- serialization: a compiled scenario is a record (chaos runs
    # archive the exact disturbance they replayed) ----
    def to_json(self) -> dict:
        return {"num_sites": int(self.num_sites),
                "ticks": int(self.ticks),
                "power_factor": self.power_factor.tolist(),
                "known_power_factor": self.known_power_factor.tolist(),
                "pred_noise": self.pred_noise.tolist(),
                "arrival_factor": self.arrival_factor.tolist(),
                "known_arrival_factor": self.known_arrival_factor.tolist(),
                "latency_factor": self.latency_factor.tolist(),
                "price_factor": self.price_factor.tolist(),
                "known_price_factor": self.known_price_factor.tolist(),
                "carbon_factor": self.carbon_factor.tolist(),
                "known_carbon_factor": self.known_carbon_factor.tolist(),
                "battery_health": self.battery_health.tolist(),
                "controls": [{"kind": ev.kind, "site": ev.site,
                              "value": ev.value, "tick": ev.tick}
                             for tk in sorted(self.controls)
                             for ev in self.controls[tk]]}

    @classmethod
    def from_json(cls, d: dict) -> "CompiledScenario":
        c = cls(num_sites=int(d["num_sites"]), ticks=int(d["ticks"]),
                power_factor=np.asarray(d["power_factor"], float),
                known_power_factor=np.asarray(d["known_power_factor"], float),
                pred_noise=np.asarray(d["pred_noise"], float),
                arrival_factor=np.asarray(d["arrival_factor"], float),
                known_arrival_factor=np.asarray(d["known_arrival_factor"],
                                                float),
                latency_factor=np.asarray(d["latency_factor"], float))
        # grid planes: absent in pre-grid records -> default all-ones
        for name in ("price_factor", "known_price_factor", "carbon_factor",
                     "known_carbon_factor", "battery_health"):
            if name in d:
                setattr(c, name, np.asarray(d[name], float))
        for ev in d.get("controls", []):
            c.add_control(int(ev["tick"]), ev["kind"], int(ev["site"]),
                          float(ev["value"]))
        return c


def _window(start: int, duration: Optional[int], T: int) -> slice:
    a = max(int(start), 0)
    b = T if duration is None else min(int(start + duration), T)
    return slice(min(a, T), max(b, min(a, T)))


# ------------------------------------------------------------------
# event types
# ------------------------------------------------------------------
@dataclass(frozen=True)
class SiteFailure:
    """Site lost to a non-power fault (fibre cut, fire, hardware).

    Truth power goes to zero (the site cannot serve) but the *power
    forecast* pipeline is untouched — only the health signal knows:
    ``SITE_DOWN`` fires after ``detect_ticks`` and ``SITE_UP`` at
    recovery, exercising the router's site-health replanning while
    power-agnostic baselines keep placing load on the dead site.
    """
    site: int
    start: int
    duration: int
    detect_ticks: int = 0

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        if w.stop <= w.start:
            return                      # outage entirely outside the horizon
        c.power_factor[self.site, w] = 0.0
        # detection clamped into [0, recovery): an outage already in
        # progress at tick 0 is detected immediately, and one whose
        # detection lag outlives the outage is never detected at all
        # (no SITE_DOWN), so down/up can never arrive out of order
        detect = max(self.start + self.detect_ticks, 0)
        if detect < w.stop:
            c.add_control(detect, SITE_DOWN, self.site)
            c.add_control(w.stop, SITE_UP, self.site)


@dataclass(frozen=True)
class GridTrip:
    """Sudden power cliff at a site (grid/turbine trip), optionally a
    partial ``depth`` < 1. A *surprise*: forecasts only reflect it after
    ``detect_ticks`` (the first affected tick(s) hit the plan via
    brownout shedding — the Fig. 8 C1 failure mode, now injectable)."""
    site: int
    start: int
    duration: int = 2
    depth: float = 1.0
    detect_ticks: int = 1

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        if w.stop <= w.start:
            return                  # trip entirely outside the horizon
        keep = 1.0 - float(self.depth)
        c.power_factor[self.site, w] *= keep
        wk = _window(self.start + self.detect_ticks,
                     max(self.duration - self.detect_ticks, 0), c.ticks)
        c.known_power_factor[self.site, wk] *= keep
        # the health signal fires when the trip is *detected* (same lag
        # as the forecast pipeline) and clears at restoration; the policy
        # decides whether depth means "site dark" (HeronRouter treats
        # depth >= 0.999 as down) or a brownout it already absorbs
        detect = max(self.start + self.detect_ticks, 0)
        if detect < w.stop:
            c.add_control(detect, GRID_TRIP, self.site, float(self.depth))
            c.add_control(w.stop, GRID_RESTORED, self.site)


@dataclass(frozen=True)
class Curtailment:
    """Grid-operator curtailment order: usable power capped at ``frac``
    of available. Announced — forecasts see it immediately, and the
    policy gets a ``CURTAILMENT`` control (demand-response hook)."""
    frac: float
    start: int
    duration: int
    sites: Optional[tuple[int, ...]] = None

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        if w.stop <= w.start:
            return                  # order entirely outside the horizon
        rows = slice(None) if self.sites is None else list(self.sites)
        c.power_factor[rows, w] *= self.frac
        c.known_power_factor[rows, w] *= self.frac
        # announcement clamped to tick 0 for orders already in force at
        # window start, so CURTAILMENT/CURTAILMENT_LIFTED always pair up
        announce = max(self.start, 0)
        for s in ([-1] if self.sites is None else self.sites):
            c.add_control(announce, CURTAILMENT, s, self.frac)
            c.add_control(w.stop, CURTAILMENT_LIFTED, s)


@dataclass(frozen=True)
class PriceSpike:
    """Electricity price spikes to ``magnitude``x over a window
    (scarcity pricing, a congested interconnect). Truth price moves at
    ``start``; the knowledge plane and the ``PRICE_SPIKE`` control lag
    by ``detect_ticks`` (the ``GridTrip`` surprise idiom — a day-ahead
    announced spike is just ``detect_ticks=0``). ``PRICE_NORMAL`` fires
    at the window end."""
    magnitude: float
    start: int
    duration: int
    sites: Optional[tuple[int, ...]] = None
    detect_ticks: int = 0

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        if w.stop <= w.start:
            return                  # spike entirely outside the horizon
        rows = slice(None) if self.sites is None else list(self.sites)
        c.price_factor[rows, w] *= self.magnitude
        wk = _window(self.start + self.detect_ticks,
                     max(self.duration - self.detect_ticks, 0), c.ticks)
        c.known_price_factor[rows, wk] *= self.magnitude
        detect = max(self.start + self.detect_ticks, 0)
        if detect < w.stop:
            for s in ([-1] if self.sites is None else self.sites):
                c.add_control(detect, PRICE_SPIKE, s, float(self.magnitude))
                c.add_control(w.stop, PRICE_NORMAL, s, 1.0)


@dataclass(frozen=True)
class CarbonRamp:
    """Grid carbon intensity ramps to ``magnitude``x over a window (the
    marginal generator switches from wind to gas/coal). Same detection
    semantics as ``PriceSpike``: truth at ``start``, knowledge and the
    ``CARBON_RAMP`` control after ``detect_ticks``, ``CARBON_NORMAL``
    at the window end."""
    magnitude: float
    start: int
    duration: int
    sites: Optional[tuple[int, ...]] = None
    detect_ticks: int = 0

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        if w.stop <= w.start:
            return                  # ramp entirely outside the horizon
        rows = slice(None) if self.sites is None else list(self.sites)
        c.carbon_factor[rows, w] *= self.magnitude
        wk = _window(self.start + self.detect_ticks,
                     max(self.duration - self.detect_ticks, 0), c.ticks)
        c.known_carbon_factor[rows, wk] *= self.magnitude
        detect = max(self.start + self.detect_ticks, 0)
        if detect < w.stop:
            for s in ([-1] if self.sites is None else self.sites):
                c.add_control(detect, CARBON_RAMP, s, float(self.magnitude))
                c.add_control(w.stop, CARBON_NORMAL, s, 1.0)


@dataclass(frozen=True)
class BatteryDegradation:
    """A site's battery bank loses capacity (cell aging, thermal
    derating, a failed string): usable capacity multiplies by ``factor``
    over the window (or permanently when ``duration`` is None).
    Announced — the BMS knows its own health — so ``BATTERY_DEGRADED``
    fires at the window start with the remaining health fraction."""
    site: int
    start: int
    factor: float
    duration: Optional[int] = None

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        if w.stop <= w.start:
            return                  # entirely outside the horizon
        c.battery_health[self.site, w] *= self.factor
        c.add_control(max(self.start, 0), BATTERY_DEGRADED, self.site,
                      float(self.factor))


@dataclass(frozen=True)
class DemandSurge:
    """Arrival-rate surge (x ``magnitude``) over a window, optionally on
    a subset of classes. ``surprise=True`` hides it from load planning
    (plans are sized for base load; the surge hits dispatch only)."""
    magnitude: float
    start: int
    duration: int
    classes: Optional[tuple[int, ...]] = None
    surprise: bool = False

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        rows = slice(None) if self.classes is None else list(self.classes)
        c.arrival_factor[rows, w] *= self.magnitude
        if not self.surprise:
            c.known_arrival_factor[rows, w] *= self.magnitude


@dataclass(frozen=True)
class DiurnalSwell:
    """Deterministic sinusoidal arrival swell (amplitude around 1.0) —
    models a marketing-launch week / seasonal load breathing on top of
    the trace's own diurnal pattern. Fully predictable."""
    amplitude: float
    period: int = 96            # ticks per cycle (96 slots = 1 day)
    phase: float = 0.0

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        t = np.arange(c.ticks)
        f = np.maximum(1.0 + self.amplitude
                       * np.sin(2 * np.pi * (t - self.phase) / self.period),
                       0.0)
        c.arrival_factor *= f
        c.known_arrival_factor *= f


@dataclass(frozen=True)
class PredictorError:
    """Multiplicative log-normal error on power predictions over a
    window (regime of bad forecasts): pred *= exp(bias + sigma * eps),
    eps drawn from this event's seeded substream."""
    sigma: float
    bias: float = 0.0
    start: int = 0
    duration: Optional[int] = None

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        n = w.stop - w.start
        if n <= 0:
            return
        eps = rng.standard_normal((c.num_sites, n))
        c.pred_noise[:, w] *= np.exp(self.bias + self.sigma * eps)


@dataclass(frozen=True)
class StragglerOnset:
    """A site starts serving ``slowdown``x slower (thermal throttling,
    failing NIC — the paper's K1 story). Pure latency signal: the
    router's EWMA observes it and deweights the site; power-agnostic
    baselines keep routing into it and eat the inflated E2E."""
    site: int
    start: int
    duration: int
    slowdown: float
    ramp: int = 0               # ticks to ramp up to full slowdown

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        w = _window(self.start, self.duration, c.ticks)
        n = w.stop - w.start
        if n <= 0:
            return
        prof = np.full(n, float(self.slowdown))
        r = min(int(self.ramp), n)
        if r > 0:
            prof[:r] = np.linspace(1.0, self.slowdown, r + 1)[1:]
        c.latency_factor[self.site, w] = np.maximum(
            c.latency_factor[self.site, w], prof)


@dataclass(frozen=True)
class PowerWiggle:
    """Second-granularity AR(1) power wiggle parameters for
    ``simulate_slot_fine`` (its historical hardcoded disturbance, now an
    event like any other). At slot granularity this is a no-op — the
    wind traces already carry slot-level variability."""
    noise: float = 0.04
    phi: float = 0.995

    def apply(self, c: CompiledScenario, rng: np.random.Generator) -> None:
        pass                    # consumed by simulate_slot_fine directly


# ------------------------------------------------------------------
# engine
# ------------------------------------------------------------------
class ScenarioEngine:
    """Composable seeded event stream; compile() -> per-tick arrays.

    ``tick`` is whatever the consuming simulator steps by: 15-min slots
    for ``simulate_week``, seconds for ``simulate_slot_fine`` — event
    ``start``/``duration`` are in the consumer's ticks.
    """

    def __init__(self, events: Sequence = (), seed: Optional[int] = None):
        self.events = list(events)
        self.seed = 0 if seed is None else int(seed)

    def __repr__(self) -> str:
        return (f"ScenarioEngine(seed={self.seed}, "
                f"events=[{', '.join(type(e).__name__ for e in self.events)}])")

    def compile(self, num_sites: int, ticks: int) -> CompiledScenario:
        c = CompiledScenario(
            num_sites=num_sites, ticks=ticks,
            power_factor=np.ones((num_sites, ticks)),
            known_power_factor=np.ones((num_sites, ticks)),
            pred_noise=np.ones((num_sites, ticks)),
            arrival_factor=np.ones((9, ticks)),
            known_arrival_factor=np.ones((9, ticks)),
            latency_factor=np.ones((num_sites, ticks)))
        # grid planes filled by __post_init__ (all-ones defaults)
        if self.events:
            streams = np.random.SeedSequence(self.seed).spawn(len(self.events))
            for ev, ss in zip(self.events, streams):
                ev.apply(c, np.random.default_rng(ss))
        return c

    def fine_wiggle(self) -> Optional[PowerWiggle]:
        """The (first) PowerWiggle event, if any — simulate_slot_fine's
        AR(1) parameters when a scenario overrides its defaults."""
        for ev in self.events:
            if isinstance(ev, PowerWiggle):
                return ev
        return None


def default_scenario(seed: Optional[int] = None) -> ScenarioEngine:
    """The event-free scenario — compiles to all-ones factors."""
    return ScenarioEngine((), seed=seed)
