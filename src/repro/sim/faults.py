"""FaultInjector — deterministic chaos against live serving engines.

The week/fine simulators perturb *rates*; this module perturbs the
*serving path itself*: site kills and restores, admission drops, step
delays, and corrupted power telemetry, injected into a
``sim.cluster.ServingCluster`` mid-decode. Two sources compose:

  * an **explicit schedule** — a list of ``Fault`` records (tick, kind,
    site, value), e.g. derived from a ``CompiledScenario`` via
    ``from_scenario`` so the same scenario definition drives the week
    sim and an engine-level chaos run;
  * a **seeded random plane** — per-tick Bernoulli draws for the noisy
    fault kinds (``delay`` / ``drop_admission`` / ``corrupt_power``),
    keyed by ``SeedSequence((seed, tick))`` so tick ``t``'s faults are
    identical no matter how many ticks ran before it or what any other
    tick drew (replayable, resumable).

Determinism is the point: a chaos run is a *test*, and the pinned
stream-identity anchors only mean something if the exact same kills land
at the exact same ticks every run.

Fault kinds
-----------
``kill``            site's engine dies: drain() -> transcript snapshots
                    (handed to the failover layer), site unroutable;
``restore``         site returns (empty engine, routable again);
``delay``           site's step stalls this tick (latency inflation on
                    live requests — no tokens sampled);
``drop_admission``  site's engine admits nothing this tick (queue holds);
``corrupt_power``   the *telemetry* the router weighs sites by is
                    multiplied by ``value`` this tick — truth power is
                    untouched (a sensor fault, not a grid fault).

Scenario derivation (``from_scenario``) reads the **truth plane**:
kills/restores fire where ``power_factor`` crosses to/from ~zero — the
engines die when the power actually drops, while the scenario's control
stream (detection-lagged) is what the *policy* sees, preserving the
two-plane split.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.sim.scenarios import CompiledScenario

KILL = "kill"
RESTORE = "restore"
DELAY = "delay"
DROP_ADMISSION = "drop_admission"
CORRUPT_POWER = "corrupt_power"

_RANDOM_KINDS = (DELAY, DROP_ADMISSION, CORRUPT_POWER)


@dataclass(frozen=True)
class Fault:
    """One scheduled fault at ``tick`` against ``site``."""
    tick: int
    kind: str
    site: int
    value: float = 0.0

    def to_json(self) -> dict:
        return {"tick": int(self.tick), "kind": self.kind,
                "site": int(self.site), "value": float(self.value)}

    @classmethod
    def from_json(cls, d: dict) -> "Fault":
        return cls(tick=int(d["tick"]), kind=d["kind"],
                   site=int(d["site"]), value=float(d.get("value", 0.0)))


@dataclass
class FaultInjector:
    """Deterministic (seeded) fault source for ``ServingCluster``.

    ``schedule``: explicit Fault records. ``p_delay`` / ``p_drop`` /
    ``p_corrupt``: per-(site, tick) probabilities for the random plane
    (0 disables a kind). ``corrupt_range``: the multiplier a corrupted
    power reading is drawn from (uniform).
    """
    num_sites: int
    seed: int = 0
    schedule: Sequence[Fault] = ()
    p_delay: float = 0.0
    p_drop: float = 0.0
    p_corrupt: float = 0.0
    corrupt_range: tuple = (0.0, 2.0)

    _by_tick: dict = field(default_factory=dict, init=False, repr=False)

    def __post_init__(self):
        for f in self.schedule:
            self._by_tick.setdefault(int(f.tick), []).append(f)

    # ------------------------------------------------------------- build
    @classmethod
    def from_scenario(cls, sc: CompiledScenario, *, seed: int = 0,
                      dead_below: float = 1e-9, **kw) -> "FaultInjector":
        """Derive kill/restore faults from a compiled scenario's TRUTH
        power plane: a site whose ``power_factor`` falls to ~zero is
        killed at that tick and restored when it rises again. Detection
        lag stays in the scenario's control stream (the policy's plane);
        the engines die on truth — exactly the asymmetry a surprise
        ``GridTrip`` is about."""
        sched = list(kw.pop("schedule", ()))
        dead = sc.power_factor <= dead_below          # [S, T]
        for s in range(sc.num_sites):
            prev = False
            for t in range(sc.ticks):
                if dead[s, t] and not prev:
                    sched.append(Fault(t, KILL, s))
                elif prev and not dead[s, t]:
                    sched.append(Fault(t, RESTORE, s))
                prev = dead[s, t]
        return cls(num_sites=sc.num_sites, seed=seed, schedule=sched, **kw)

    # ------------------------------------------------------------- query
    def _rng(self, tick: int) -> np.random.Generator:
        """Per-tick substream: draws at tick t never depend on other
        ticks (schedule edits / resume cannot shift the random plane)."""
        return np.random.default_rng(
            np.random.SeedSequence((int(self.seed), int(tick))))

    def faults_at(self, tick: int) -> list[Fault]:
        """All faults firing at ``tick``: the explicit schedule plus the
        seeded random plane, in a deterministic order (schedule first,
        then random kinds by site then kind)."""
        out = list(self._by_tick.get(int(tick), []))
        if self.p_delay or self.p_drop or self.p_corrupt:
            rng = self._rng(tick)
            # one draw matrix per call: [S, 3] uniforms + [S] corrupt
            # multipliers, consumed in a fixed order
            u = rng.random((self.num_sites, len(_RANDOM_KINDS)))
            lo, hi = self.corrupt_range
            mult = lo + (hi - lo) * rng.random(self.num_sites)
            probs = (self.p_delay, self.p_drop, self.p_corrupt)
            for s in range(self.num_sites):
                for k, (kind, p) in enumerate(zip(_RANDOM_KINDS, probs)):
                    if p > 0.0 and u[s, k] < p:
                        val = float(mult[s]) if kind == CORRUPT_POWER else 0.0
                        out.append(Fault(int(tick), kind, s, val))
        return out

    def to_json(self) -> dict:
        return {"num_sites": int(self.num_sites), "seed": int(self.seed),
                "schedule": [f.to_json() for f in self.schedule],
                "p_delay": float(self.p_delay),
                "p_drop": float(self.p_drop),
                "p_corrupt": float(self.p_corrupt),
                "corrupt_range": list(self.corrupt_range)}

    @classmethod
    def from_json(cls, d: dict) -> "FaultInjector":
        return cls(num_sites=int(d["num_sites"]), seed=int(d["seed"]),
                   schedule=[Fault.from_json(f) for f in d["schedule"]],
                   p_delay=float(d.get("p_delay", 0.0)),
                   p_drop=float(d.get("p_drop", 0.0)),
                   p_corrupt=float(d.get("p_corrupt", 0.0)),
                   corrupt_range=tuple(d.get("corrupt_range", (0.0, 2.0))))
