"""The 4-site paper grid — the §5 evaluation testbed, built once.

Benchmarks, tests, and examples all evaluate on the same construction:
the paper trace, the lookup table on the 4-point load grid, the default
wind fleet right-sized at the 20th-percentile threshold
(pods = P20 // SuperPOD peak), and generation clipped to that threshold.
This helper is the single copy; change the grid here and every consumer
moves together (the equivalence suite pins results on this grid).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs import PAPER_MODEL
from repro.core.lookup import LookupTable, build_table
from repro.core.planner_l import SiteSpec
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW

GRID = dict(load_grid=(0.25, 1.0, 4.0, 16.0), freq_grid=(1.2, 2.0))


@dataclass
class PaperGrid:
    trace: object
    table: LookupTable
    sites: list[SiteSpec]
    power_mw: np.ndarray        # [S, 672] generation clipped at P20
    arrivals_rps: np.ndarray    # [9, 672] at the requested multiplier

    def arrivals_at(self, multiplier: float) -> np.ndarray:
        """Per-class rps at another volume multiplier."""
        return self.trace.class_arrivals(multiplier=multiplier) / (15 * 60)


def paper_grid(trace_name: str = "coding", *, multiplier: float = 60.0,
               trace_seed: int = 11, fleet_seed: int = 7) -> PaperGrid:
    trace = make_trace(trace_name, base_rps=1.0, seed=trace_seed)
    table = build_table(PAPER_MODEL, trace, H100_DGX, **GRID)
    fleet = make_default_fleet(seed=fleet_seed)
    sites, thr = [], []
    for s in fleet.sites:
        pods = int(s.percentile_mw(20.0) // SUPERPOD_PEAK_MW)
        sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
        thr.append(s.percentile_mw(20.0))
    power = np.minimum(fleet.week(), np.array(thr)[:, None])
    arrivals = trace.class_arrivals(multiplier=multiplier) / (15 * 60)
    return PaperGrid(trace=trace, table=table, sites=sites,
                     power_mw=power, arrivals_rps=arrivals)
