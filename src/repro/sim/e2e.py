"""Million-user week co-sim — streamed trace replay driving live engines.

This is the layer that makes the rate-plane numbers mean "tokens a user
received on time": ``data.workload.stream_requests`` streams an
Azure-shaped request population (millions of users, diurnal/regional
structure, never materialized), and ``FleetServingSim`` — a
``ServingCluster`` with a *live fleet control plane* — runs one
``ServingEngine`` per site on the shared virtual clock while the
``RoutingPolicy``'s plan drives admission capacity and brownout:

  * every planning window the policy re-plans
    (``plan_slot``/``plan_fine``) on the knowledge-plane power, and the
    plan is confronted with truth-plane power via
    ``apply_power_reality`` — the power truth plane becomes per-site
    token budgets (``admit_token_budget``) and graceful-degradation
    brownout fractions (``set_brownout``) on the live engines;
  * per-request routing follows the plan's per-class WRR weights
    (deterministic credit counters, home-affinity sticky), i.e. the same
    dispatch-path view of capacity the rate simulators score;
  * scenario events hit *live* engines: a ``FaultInjector`` derived from
    the scenario's truth plane kills/restores engines (failover carries
    real transcripts down ``policy.failover_order``), control events
    reach the policy, and straggler ``latency_factor`` feeds
    ``policy.observe``.

Goodput is *SLO-attributed served tokens*: a completed request's tokens
count only when its TTFT and mean TBT (virtual-clock ticks — one tick is
one nominal token time) meet the per-class deadlines derived from the
lookup table's isolated references (``LookupTable.slos``;
``ClassSLO.ttft_deadline_ticks`` / ``tbt_deadline_ticks``). The result
also reports raw served tokens and user-visible p50/p99 TTFT/TBT/E2E
tails, and the delivery ledger's duplicated-token proof rides along from
``ServingCluster``.

Units note: the rate simulators' goodput (dispatched rps x slots) is an
*upper bound* on what this layer can attribute — the rate plane assumes
every dispatched request is served to completion, while live engines
lose in-flight work to trips and pay failover/backoff tails. The co-sim
smoke (tests/test_e2e.py) pins ``dispatched fraction >= served
fraction``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import numpy as np

from repro.core.baselines import apply_power_reality
from repro.core.lookup import SLO_MULTIPLIER, LookupTable
from repro.core.planner_l import SiteSpec
from repro.core.router import SLOT_SECONDS
from repro.data.workload import RequestChunk, WorkloadTrace, stream_requests
from repro.power.grid import GridSignals
from repro.serving.engine import Request
from repro.sim.cluster import ServingCluster
from repro.sim.faults import FaultInjector
from repro.sim.scenarios import ScenarioEngine
from repro.stats import finite_or, percentile


# token-length compression: streamed Azure lengths (hundreds..thousands)
# map onto smoke-engine budgets (max_seq ~ 64) by fixed divisors — the
# *shape* (class mix, tails) survives; absolute scale is the engine's
PROMPT_DIVISOR = 256.0
OUTPUT_DIVISOR = 64.0


@dataclass
class E2EResult:
    """Served-token scorecard of one fleet co-sim run."""
    name: str
    ticks: int
    offered_requests: int
    offered_tokens: int         # requested output tokens (engine scale)
    completed: int
    rejected: int
    timed_out: int
    failed: int                 # failover retry budget exhausted
    served_tokens: int          # unique delivered tokens (ledger hwm)
    slo_served_tokens: int      # ... of which met the class SLO deadlines
    slo_hits: int
    slo_misses: int
    duplicated_tokens: int      # MUST be 0
    lost_tokens: int
    preemptions: int
    resumes: int
    p50_ttft: float
    p99_ttft: float
    p50_tbt: float
    p99_tbt: float
    p50_e2e: float
    p99_e2e: float
    # grid-interactive counters (ISSUE 10): $ and gCO2 billed on the
    # realized window draws under the scenario's price/carbon planes
    cost_usd: float = 0.0
    carbon_g: float = 0.0
    # rate-plane comparison hook (filled by benchmarks): served fraction
    # of simulate_week's dispatched rps over the same scenario
    dispatched_fraction: Optional[float] = None
    faults: dict = field(default_factory=dict)
    # per-window Planner-L cost counters (solve_s / mode / dirty_sites),
    # mirroring WeekResult.planner — filled by simulate_fleet_serving
    planner: dict = field(default_factory=dict)

    @property
    def goodput_fraction(self) -> float:
        """Unique delivered tokens / requested tokens."""
        return self.served_tokens / max(self.offered_tokens, 1)

    @property
    def slo_goodput_fraction(self) -> float:
        """SLO-attributed delivered tokens / requested tokens — the
        paper-faithful 'tokens a user received on time' fraction."""
        return self.slo_served_tokens / max(self.offered_tokens, 1)

    def to_json(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "name", "ticks", "offered_requests", "offered_tokens",
            "completed", "rejected", "timed_out", "failed",
            "served_tokens", "slo_served_tokens", "slo_hits", "slo_misses",
            "duplicated_tokens", "lost_tokens", "preemptions", "resumes")}
        for k in ("p50_ttft", "p99_ttft", "p50_tbt", "p99_tbt",
                  "p50_e2e", "p99_e2e"):
            d[k] = finite_or(getattr(self, k), -1.0)   # strict-JSON safe
        for k in ("cost_usd", "carbon_g"):
            d[k] = finite_or(getattr(self, k), 0.0)
        d["kind"] = "e2e"
        d["goodput_fraction"] = self.goodput_fraction
        d["slo_goodput_fraction"] = self.slo_goodput_fraction
        if self.dispatched_fraction is not None:
            d["dispatched_fraction"] = float(self.dispatched_fraction)
        d["faults"] = dict(self.faults)
        if self.planner:
            d["planner"] = dict(self.planner)
        return d


def slo_deadline_ticks(table: LookupTable) -> tuple[np.ndarray, np.ndarray]:
    """Per-class (ttft, tbt) deadlines in virtual-clock ticks.

    One engine tick is one nominal token time, so the wall-clock SLOs
    rescale by the class's isolated TBT reference (``ClassSLO``). Tables
    built before the SLO refs existed fall back to a uniform
    ``SLO_MULTIPLIER`` on both axes.
    """
    if table.slos:
        ttft = np.array([s.ttft_deadline_ticks() for s in table.slos])
        tbt = np.array([s.tbt_deadline_ticks() for s in table.slos])
    else:
        ttft = np.full(9, SLO_MULTIPLIER)
        tbt = np.full(9, SLO_MULTIPLIER)
    return ttft, tbt


class FleetServingSim(ServingCluster):
    """``ServingCluster`` + the live fleet control plane.

    Adds to the base cluster: per-class WRR routing from the current
    plan, plan-driven admission budgets/brownout, and per-request SLO
    attribution against the lookup table's class deadlines. The base
    class keeps owning failover, the delivery ledger (duplicated-token
    proof), and engine lifecycle.
    """

    def __init__(self, num_sites: int, make_engine, table: LookupTable, *,
                 policy=None, failover: bool = True, retry_budget: int = 3,
                 tick_seconds: float = 1.0):
        super().__init__(num_sites, make_engine, policy=policy,
                         failover=failover, retry_budget=retry_budget,
                         tick_seconds=tick_seconds)
        self.table = table
        self._slo_ttft, self._slo_tbt = slo_deadline_ticks(table)
        self._rid_cls: dict[int, int] = {}
        self._wrr_w = np.zeros((9, num_sites))     # class x site weights
        self._wrr_credit = np.zeros((9, num_sites))
        self.completed_tbt: list[float] = []
        self.slo_hits = 0
        self.slo_misses = 0
        self.slo_served_tokens = 0

    # ------------------------------------------------------- control plane
    def apply_plan(self, plan, realized, nominal_budget: int) -> None:
        """Push a planning window's (plan, power-realized plan) onto the
        live engines: per-class WRR weights from the realized plan's
        dispatch view, per-site brownout = realized/planned capacity, and
        admission token budgets scaled by the realized share."""
        S = self.num_sites
        planned = np.zeros(S)
        real_cap = np.zeros(S)
        for p, acc in ((plan, planned), (realized, real_cap)):
            site, _cls, _tp, load, _pow, _ = p.column_arrays()
            counts = np.asarray(p.counts, float)
            np.add.at(acc, site[: len(counts)], counts * load[: len(counts)])
        self._wrr_w[:] = 0.0
        for c, rows in realized.wrr_weights().items():
            for s, _row, w in rows:
                if s < S:
                    self._wrr_w[c, s] += w
        self._wrr_credit[:] = 0.0
        for s in range(S):
            eng = self.engines[s]
            if eng is None:
                continue
            frac = (real_cap[s] / planned[s]) if planned[s] > 1e-12 else 1.0
            eng.set_brownout(min(frac, 1.0))
            eng.admit_token_budget = max(
                1, int(round(nominal_budget * min(frac, 1.0))))

    def route_site(self, cls: int, home: int) -> int:
        """Pick the landing site for a class-``cls`` request from region
        ``home``: sticky to the home site while the plan provisions it,
        else deterministic weighted-round-robin over the plan's per-class
        weights (alive sites only), else any alive site."""
        w = self._wrr_w[cls] * self.alive
        if self.alive[home] and w[home] > 0:
            return home
        tot = float(w.sum())
        if tot <= 0:
            # plan places none of this class (or all its sites died):
            # home if alive, else first alive site
            if self.alive[home]:
                return home
            alive = np.flatnonzero(self.alive)
            return int(alive[0]) if len(alive) else home
        self._wrr_credit[cls] += w
        pick = int(np.argmax(self._wrr_credit[cls]))
        self._wrr_credit[cls, pick] -= tot
        return pick

    def submit_classed(self, req: Request, cls: int, home: int) -> bool:
        self._rid_cls[req.rid] = int(cls)
        return self.submit(req, self.route_site(cls, home))

    # ---------------------------------------------------- SLO attribution
    def _harvest(self, site: int) -> None:
        eng = self.engines[site]
        if eng is None:
            return
        done = eng.metrics.completed
        fresh = done[self._ncons[site]:]
        super()._harvest(site)
        for req in fresh:
            tbt = req.tbt
            if tbt is not None:
                self.completed_tbt.append(tbt)
            cls = self._rid_cls.get(req.rid)
            if cls is None:
                continue
            ok = True
            if req.ttft is not None and req.ttft > self._slo_ttft[cls]:
                ok = False
            if tbt is not None and tbt > self._slo_tbt[cls]:
                ok = False
            if ok:
                self.slo_hits += 1
                self.slo_served_tokens += self._hwm.get(req.rid, 0)
            else:
                self.slo_misses += 1

    # -------------------------------------------------------------- result
    def e2e_result(self, name: str, ticks: int, *, offered_requests: int,
                   offered_tokens: int,
                   faults_record: Optional[dict] = None) -> E2EResult:
        base = self.result(name, ticks, faults_record=faults_record)
        return E2EResult(
            name=name, ticks=ticks,
            offered_requests=offered_requests,
            offered_tokens=offered_tokens,
            completed=base.completed, rejected=base.rejected,
            timed_out=base.timed_out, failed=base.failed,
            served_tokens=base.served_tokens,
            slo_served_tokens=self.slo_served_tokens,
            slo_hits=self.slo_hits, slo_misses=self.slo_misses,
            duplicated_tokens=base.duplicated_tokens,
            lost_tokens=base.lost_tokens,
            preemptions=base.preemptions, resumes=base.resumes,
            p50_ttft=base.p50_ttft, p99_ttft=base.p99_ttft,
            p50_tbt=percentile(self.completed_tbt, 50),
            p99_tbt=percentile(self.completed_tbt, 99),
            p50_e2e=base.p50_e2e, p99_e2e=base.p99_e2e,
            faults=base.faults)


def _chunk_requests(ch: RequestChunk, vocab: int, max_prompt: int,
                    max_new: int, rng: np.random.Generator):
    """Materialize a streamed chunk as engine ``Request``s (token ids are
    synthetic — the smoke models are untrained; lengths carry the signal)."""
    out = []
    np_len = np.clip(np.round(ch.lin / PROMPT_DIVISOR), 1, max_prompt
                     ).astype(int)
    nt_len = np.clip(np.round(ch.lout / OUTPUT_DIVISOR), 1, max_new
                     ).astype(int)
    for i in range(len(ch)):
        prompt = rng.integers(1, vocab, size=int(np_len[i])).astype(np.int32)
        out.append((int(ch.rid[i]), int(ch.site[i]), int(ch.cls[i]),
                    Request(rid=int(ch.rid[i]), prompt=prompt,
                            max_new_tokens=int(np_len[i] + nt_len[i]),
                            temperature=0.8 if ch.rid[i] % 2 else 0.0)))
    return out


def simulate_fleet_serving(
        policy, table: LookupTable, sites: list[SiteSpec],
        power_mw: np.ndarray, make_engine, *,
        traces: Union[WorkloadTrace, Sequence[WorkloadTrace]],
        num_users: int, ticks: int, tick_seconds: float = 1.0,
        window_ticks: int = 60, plan_load_scale: float = 1.0,
        scenario: Optional[ScenarioEngine] = None, seed: int = 0,
        name: str = "e2e", failover: bool = True, retry_budget: int = 3,
        fine_ticks: int = 15,
        vocab: int = 256, max_prompt: int = 16, max_new: int = 16,
        nominal_budget: int = 64, drain_ticks: int = 512,
        power_col: int = 200, return_fleet: bool = False):
    """Drive the streamed workload through live per-site engines under
    the live fleet plan. See the module docstring for the architecture.

    ``power_mw``: [S, T] slot-granularity generation (the paper grid);
    each planning window reads column ``power_col + window`` (wrapping),
    scaled by the scenario's knowledge/truth factors at that tick.
    ``plan_load_scale`` maps the stream's observed rps into the regime
    the lookup table is calibrated for (the plan's *relative* geometry —
    WRR weights, brownout fractions — is what the engines consume, so
    the scale only needs to keep the planner away from degenerate
    all-slack or all-surplus corners).

    ``fine_ticks``: Planner-S cadence in ticks. Between slot plans the
    policy's ``plan_fine`` re-solves on the *current* knowledge-plane
    power (warm-started for Heron; the WRR baseline returns its stale
    slot plan) and the fleet re-applies weights/brownout/budgets — this
    is what lets a health-aware policy route around a mid-window trip
    instead of waiting for the next slot boundary. 0 disables.
    """
    S = len(sites)
    engine = scenario if scenario is not None else ScenarioEngine(seed=seed)
    sc = engine.compile(S, ticks)
    injector = FaultInjector.from_scenario(sc, seed=seed)
    fleet = FleetServingSim(S, make_engine, table, policy=policy,
                            failover=failover, retry_budget=retry_budget,
                            tick_seconds=tick_seconds)
    rng = np.random.default_rng(seed)
    T = power_mw.shape[1]

    duration_s = ticks * tick_seconds
    chunks = stream_requests(
        traces, num_users=num_users, num_sites=S, duration_s=duration_s,
        chunk_s=window_ticks * tick_seconds, seed=seed)

    offered_requests = 0
    offered_tokens = 0
    rates = GridSignals.flat(S, ticks)
    cost_usd = carbon_g = 0.0
    win_h = window_ticks * tick_seconds / 3600.0
    pl_solve: list = []      # per-window Planner-L wall seconds
    pl_mode: list = []       # session mode ("incremental"/"full"/"stateless")
    pl_dirty: list = []      # dirty-set size (-1 when not incremental)
    nwin = int(np.ceil(ticks / window_ticks))
    tick = 0
    for w in range(nwin):
        ch = next(chunks)
        reqs = _chunk_requests(ch, vocab, max_prompt, max_new, rng)
        offered_requests += len(reqs)
        offered_tokens += int(sum(r.max_new_tokens for *_k, r in reqs))
        # by-tick arrival buckets relative to this window
        by_tick: dict[int, list] = {}
        rel = ((ch.arrival_s - ch.start_s) // tick_seconds).astype(int)
        for i, (_rid, home, cls, req) in enumerate(reqs):
            by_tick.setdefault(int(rel[i]), []).append((home, cls, req))

        # --- plan the window on the knowledge plane ---
        col = (power_col + w) % T
        kf = sc.known_power_factor[:, min(tick, ticks - 1)]
        pred_w = power_mw[:, col] * kf * 1e6
        # observed per-class load (stream truth at this window, scaled
        # into the table's calibrated regime)
        cls_counts = np.bincount(ch.cls, minlength=9).astype(float)
        win_s = max(ch.end_s - ch.start_s, 1e-9)
        plan_load = cls_counts / win_s * plan_load_scale
        plan = policy.plan_slot(pred_w, plan_load)
        me = getattr(plan, "meta", None) or {}
        pl_solve.append(float(plan.solve_seconds))
        pl_mode.append(str(me.get("mode", "stateless")))
        pl_dirty.append(int(me.get("dirty_sites", -1)))
        actual_w = power_mw[:, col] * sc.power_factor[:, min(tick, ticks - 1)] * 1e6
        realized = apply_power_reality(plan, actual_w)
        fleet.apply_plan(plan, realized, nominal_budget)
        # bill the window's realized draw under the grid plane (flat
        # default rates x the scenario's price/carbon factors)
        t_bill = min(tick, ticks - 1)
        energy_mwh = realized.power_used() / 1e6 * win_h
        cost_usd += rates.slot_cost_usd(energy_mwh, t_bill,
                                        sc.price_factor[:, t_bill])
        carbon_g += rates.slot_carbon_g(energy_mwh, t_bill,
                                        sc.carbon_factor[:, t_bill])
        # straggler signal for next window's plan
        policy.observe(sc.latency_factor[:, min(tick, ticks - 1)])

        # --- run the window's ticks ---
        # the router's slot clock advanced SLOT_SECONDS at plan_slot;
        # fine replans ride monotonically inside that slot
        plan_base = (w + 1) * SLOT_SECONDS
        w_end = min((w + 1) * window_ticks, ticks)
        while tick < w_end:
            rel_t = tick - w * window_ticks
            for ev in sc.controls_at(tick):
                # non-health events (curtailment notices etc.) still reach
                # the policy; kill/restore edges also arrive via the
                # injector -> cluster path (idempotent on the policy side)
                policy.on_event(ev)
            if fine_ticks and rel_t and rel_t % fine_ticks == 0:
                t_idx = min(tick, ticks - 1)
                fine = policy.plan_fine(
                    plan_base + rel_t * tick_seconds,
                    power_mw[:, col] * sc.known_power_factor[:, t_idx] * 1e6,
                    plan_load)
                fine_real = apply_power_reality(
                    fine,
                    power_mw[:, col] * sc.power_factor[:, t_idx] * 1e6)
                fleet.apply_plan(fine, fine_real, nominal_budget)
            arrivals = []
            for home, cls, req in by_tick.get(rel_t, ()):
                fleet._rid_cls[req.rid] = int(cls)
                arrivals.append((fleet.route_site(cls, home), req))
            fleet.step_tick(faults=injector.faults_at(tick),
                            arrivals=arrivals)
            tick += 1

    for _ in range(drain_ticks):
        if fleet.drained():
            break
        fleet.step_tick()
    res = fleet.e2e_result(
        name, ticks, offered_requests=offered_requests,
        offered_tokens=offered_tokens,
        faults_record=injector.to_json())
    res.planner = {"solve_s": pl_solve, "mode": pl_mode,
                   "dirty_sites": pl_dirty}
    res.cost_usd = float(cost_usd)
    res.carbon_g = float(carbon_g)
    return (res, fleet) if return_fleet else res
