"""Week-long cross-site serving simulation (paper §5.2/§5.3).

Two granularities, matching the paper's evaluation methodology:

  * ``simulate_week``      — 15-min slots over 672 slots: a pluggable
    ``RoutingPolicy`` (see ``repro.sim.policy``) plans each slot; goodput
    / drops / latency / power are accounted per slot. Baselines are
    power-variability agnostic, so their plans are confronted with
    reality via ``apply_power_reality`` (whole-instance brownout
    shedding) — reproducing Fig. 8/14/15.

  * ``simulate_slot_fine`` — 1-s steps inside one slot: per-second power
    and Poisson arrivals fluctuate around the slot values; Planner-S re-
    solves (f, l) every few seconds inside Planner-L's GPU budget, and the
    Request Scheduler's packing heuristic absorbs transient per-class
    overloads — reproducing Fig. 17 and the §5.3 elasticity test.

Control plane
-------------
The driver is policy/scenario-driven rather than an inlined planning
loop:

  * ``simulate_week(name_or_policy, ...)`` resolves a ``RoutingPolicy``
    through the name->factory registry (``"heron"``,
    ``"heron_min_power"``, ``"wrr_dynamollm"``, ``"greedy_min_latency"``,
    or anything added via ``register_policy``) and drives its
    plan_slot / route / observe / on_event lifecycle. For the Heron
    names this is the *actual* ``HeronRouter`` object — straggler EWMA
    haircuts and ``mark_site_down`` health replanning shape weekly
    results (the paper's K1 story), and the Configurator's re-shard
    freeze clock ticks at slot cadence (its freeze windows bind
    Planner-S via ``plan_fine``) — instead of being bypassed by a
    parallel if/elif loop. A policy *instance* is driven as configured
    (e.g. a hand-built ``HeronRouter`` keeps its ``packing=True``
    dispatch default); use the registry names for the week scoring
    convention (no packing, matching ``simulate_week_reference``).
  * disturbances come from a seeded ``ScenarioEngine``
    (``repro.sim.scenarios``): site failures & recoveries, grid-trip
    power cliffs, curtailment orders, demand surges/diurnal swell,
    predictor-error regimes, straggler onset — compiled once into
    per-tick truth/knowledge factors and control events, consumed
    uniformly here and in ``simulate_slot_fine``. The default
    (event-free) scenario perturbs nothing, and the legacy scheduler
    names stay bit-identical to the pre-refactor driver (kept as
    ``simulate_week_reference``; pinned by tests/test_scenarios.py).

Fluid-flow semantics: requests are rps flows per class; queueing beyond
rated capacity accrues in a per-class fluid backlog whose Little's-law
wait adds to the table E2E. 'Goodput' is served rps (the paper's "requests
being actually served").

Fast path
---------
Both simulators run on the columnar dispatch engine (``GroupTable``):

  * the AR(1) power wiggle is generated for all sites at once with a
    first-order ``scipy.signal.lfilter`` (bit-identical to the scalar
    recursion — same draws, same order, same arithmetic);
  * ``simulate_slot_fine`` batches the seconds between two Planner-S
    re-solves: the plan — and hence the shed geometry — is constant
    inside a segment, so brownout shedding for the whole segment is one
    vectorized ``shed_counts_batch`` call and each second's dispatch is
    a cheap ``GroupTable.with_counts`` + vector dispatch (the per-second
    Python loop only threads the fluid backlog, which is inherently
    sequential);
  * each Planner-S re-solve is warm-started from the previous one
    (status ``"warm"``; ``FineResult.warm_hits`` counts them, and
    ``warm_start=False`` restores cold solves for A/B benchmarks).

Run records: ``WeekResult``/``FineResult`` round-trip through
``to_json``/``from_json``; pass ``record=`` to persist a run under
``artifacts/sim/`` (benchmarks reload records via ``load_week_result``
instead of re-simulating).
"""
from __future__ import annotations

import hashlib
import os
from collections import deque
from dataclasses import dataclass, field
from typing import Literal, Optional, Union

import numpy as np
from scipy.signal import lfilter

from repro.core.baselines import (apply_power_reality,
                                  baseline_greedy_min_latency,
                                  baseline_wrr_dynamollm, shed_counts_batch)
from repro.core.lookup import LookupTable
from repro.core.planner_l import Method, Plan, SiteSpec, plan_l
from repro.core.planner_s import plan_s
from repro.core.predictor import SeriesPredictor
from repro.core.scheduler import Configurator, GroupTable, RequestScheduler
from repro.power.grid import BatteryBank, GridSignals
from repro.stats import finite_or, percentile
from repro.sim.record import load_record, write_record
from repro.sim.scenarios import ScenarioEngine

SchedulerName = Literal["heron", "heron_min_power", "wrr_dynamollm",
                        "greedy_min_latency"]


@dataclass
class SlotMetrics:
    served: np.ndarray
    dropped: np.ndarray
    mean_e2e: float
    power_w: float
    solve_s: float
    reconfigs: int
    # grid-interactive counters (ISSUE 10): $ and gCO2 for the slot's
    # realized energy draw under the scenario's price/carbon planes —
    # 0.0 on pre-grid records
    cost_usd: float = 0.0
    carbon_g: float = 0.0

    @property
    def total_served(self) -> float:
        return float(self.served.sum())

    @property
    def total_dropped(self) -> float:
        return float(self.dropped.sum())

    def to_json(self) -> dict:
        return {"served": self.served.tolist(),
                "dropped": self.dropped.tolist(),
                "mean_e2e": float(self.mean_e2e),
                "power_w": float(self.power_w),
                "solve_s": float(self.solve_s),
                "reconfigs": int(self.reconfigs),
                "cost_usd": finite_or(self.cost_usd, 0.0),
                "carbon_g": finite_or(self.carbon_g, 0.0)}

    @classmethod
    def from_json(cls, d: dict) -> "SlotMetrics":
        return cls(served=np.asarray(d["served"], float),
                   dropped=np.asarray(d["dropped"], float),
                   mean_e2e=float(d["mean_e2e"]),
                   power_w=float(d["power_w"]),
                   solve_s=float(d["solve_s"]),
                   reconfigs=int(d["reconfigs"]),
                   cost_usd=float(d.get("cost_usd", 0.0)),
                   carbon_g=float(d.get("carbon_g", 0.0)))


@dataclass
class WeekResult:
    name: str
    slots: list[SlotMetrics]
    # fault/chaos counters attached by chaos-aware drivers (empty for a
    # plain week run) — round-trips through to_json/from_json
    faults: dict = field(default_factory=dict)
    # per-slot Planner-L cost counters ({"solve_s": [...], "mode": [...],
    # "dirty_sites": [...]}) so bench/co-sim runs expose planner cost
    # without a profiler; "stateless" mode / dirty -1 = plain plan_l
    planner: dict = field(default_factory=dict)

    def goodput(self) -> np.ndarray:
        return np.array([s.total_served for s in self.slots])

    def drops(self) -> np.ndarray:
        return np.array([s.total_dropped for s in self.slots])

    def slots_with_drops(self, eps: float = 1e-6) -> int:
        return int((self.drops() > eps).sum())

    def mean_e2e(self) -> np.ndarray:
        return np.array([s.mean_e2e for s in self.slots])

    def power(self) -> np.ndarray:
        return np.array([s.power_w for s in self.slots])

    def cost_usd(self) -> np.ndarray:
        return np.array([s.cost_usd for s in self.slots])

    def carbon_g(self) -> np.ndarray:
        return np.array([s.carbon_g for s in self.slots])

    def to_json(self) -> dict:
        out = {"kind": "week", "name": self.name,
               "slots": [s.to_json() for s in self.slots]}
        if self.faults:
            out["faults"] = dict(self.faults)
        if self.planner:
            out["planner"] = dict(self.planner)
        return out

    @classmethod
    def from_json(cls, d: dict) -> "WeekResult":
        return cls(name=d["name"],
                   slots=[SlotMetrics.from_json(s) for s in d["slots"]],
                   faults=dict(d.get("faults", {})),
                   planner=dict(d.get("planner", {})))


def goodput_improvement(heron: WeekResult, baseline: WeekResult) -> np.ndarray:
    """Per-slot goodput ratio (Fig. 14 middle / Fig. 15): Heron / baseline."""
    g_h, g_b = heron.goodput(), baseline.goodput()
    return g_h / np.maximum(g_b, 1e-9)


# repo root (src/repro/sim/cluster.py -> 4 levels up): record=True must
# land in the same artifacts/sim/ tree the benchmarks read regardless of
# the launch directory
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def _record_path(record: Union[str, bool], name: str, S: int, T: int,
                 seed: Optional[int], engine: ScenarioEngine,
                 power_mw: np.ndarray, arrivals_rps: np.ndarray,
                 predictor_kind: str, planner_knobs: tuple) -> str:
    if record is True:
        record = os.path.join(_REPO_ROOT, "artifacts", "sim")
    if str(record).endswith(".json"):
        return str(record)
    # distinct runs must not overwrite each other's records: the auto
    # name keys on the workload inputs (power/arrival windows, predictor,
    # planner knobs) and, when events are present, the scenario stack
    h = hashlib.md5()
    h.update(np.ascontiguousarray(power_mw).tobytes())
    h.update(np.ascontiguousarray(arrivals_rps).tobytes())
    h.update(repr((predictor_kind, planner_knobs)).encode())
    tag = f"_w{h.hexdigest()[:8]}"
    if seed is not None:
        tag += f"_seed{seed}"
    if engine.events:
        sc_digest = hashlib.md5(
            repr((engine.seed, engine.events)).encode()).hexdigest()[:8]
        tag += f"_sc{sc_digest}"
    return os.path.join(str(record), f"week_{name}_{S}sites_{T}slots{tag}.json")


def load_week_result(path: str) -> WeekResult:
    """Reload a recorded ``simulate_week`` run (see the ``record=`` knob)."""
    d = load_record(path)
    return WeekResult.from_json(d.get("result", d))


def simulate_week(scheduler, table: LookupTable,
                  sites: list[SiteSpec], power_mw: np.ndarray,
                  arrivals_rps: np.ndarray, *,
                  predictor_kind: str = "oracle", r_frac: float = 0.03,
                  time_limit: float = 20.0,
                  slots: Optional[int] = None,
                  planner_method: Method = "auto",
                  planner_workers: Optional[int] = None,
                  incremental: bool = False, dirty_tol: float = 0.02,
                  scenario: Optional[ScenarioEngine] = None,
                  seed: Optional[int] = None,
                  grid: Optional[GridSignals] = None,
                  battery: Optional[BatteryBank] = None,
                  record: Union[str, bool, None] = None) -> WeekResult:
    """Slot-level week simulation, driven by a pluggable RoutingPolicy.

    ``scheduler``: a registered policy name (see
    ``repro.sim.policy.list_policies``) or a ``RoutingPolicy`` instance.
    ``power_mw``: [S, T] available generation per site; arrivals_rps:
    [9, T]. The site's usable power is min(generation, provisioned
    demand) — the provisioned hardware cap is already expressed by the
    GPU constraint. ``planner_method``/``planner_workers`` select the
    Planner-L solve path for the Heron policies ("auto" = the
    drain-priced decomposition at every fleet size; "monolithic" = the
    exact reference) and the site-ILP pool size.
    ``incremental``/``dirty_tol`` route Heron slot re-plans through a
    persistent ``PlannerLSession`` (dirty-site incremental path).

    ``scenario`` perturbs per-slot truth and emits control events
    (``repro.sim.scenarios``); ``seed`` makes the whole run reproducible
    (it seeds the default scenario — pass an explicitly-seeded engine to
    combine both). ``record`` persists the result as a JSON run record:
    ``True`` -> artifacts/sim/, a directory, or a full ``.json`` path.

    Grid plane (ISSUE 10): ``grid`` supplies per-site price/carbon base
    curves (defaults to flat wind-node rates) and every slot's realized
    energy draw is billed into ``SlotMetrics.cost_usd``/``carbon_g``
    under the scenario's price/carbon factors. ``battery`` co-simulates
    a per-site ``BatteryBank``: surplus wind (generation beyond the
    realized plan draw) charges it; when truth power falls short of the
    planned draw it discharges to ride through, and the *knowledge*
    plane credits each site's sustainable ride-through power on top of
    the forecast so the planner keeps assigning a battery-backed site
    through a trip. The bank passed in is copied (a run never mutates
    the caller's state) and follows the scenario's ``battery_health``
    derating trace.
    """
    S, T = power_mw.shape
    T = min(T, arrivals_rps.shape[1]) if slots is None else min(slots, T)

    engine = scenario if scenario is not None else ScenarioEngine(seed=seed)
    sc = engine.compile(S, T)

    if isinstance(scheduler, str):
        from repro.sim.policy import make_policy
        policy = make_policy(scheduler, table, sites, r_frac=r_frac,
                             time_limit=time_limit,
                             planner_method=planner_method,
                             planner_workers=planner_workers,
                             incremental=incremental, dirty_tol=dirty_tol)
        name = scheduler
    else:
        policy = scheduler
        name = getattr(scheduler, "name", type(scheduler).__name__)

    # knowledge plane: the forecast pipeline's view of the power series
    # (full-length so predictor clamping sees the same range as truth)
    known_power = power_mw.astype(float).copy()
    known_power[:, :T] *= sc.known_power_factor
    predictors = [SeriesPredictor(known_power[s], kind=predictor_kind)
                  for s in range(S)]

    old: Optional[Plan] = None
    cfgtor = Configurator()
    out: list[SlotMetrics] = []
    pl_solve: list[float] = []
    pl_mode: list[str] = []
    pl_dirty: list[int] = []
    # grid plane: flat default rates when no curves are supplied, so
    # cost/carbon counters are always populated (uniform rates cannot
    # change any plan — they only meter it)
    rates = grid if grid is not None else GridSignals.flat(S, T)
    bank = battery.copy() if battery is not None else None
    prev_draw_w = np.zeros(S)
    dt_h = 0.25                     # one 15-min slot in hours
    for t in range(T):
        for ev in sc.controls_at(t):
            policy.on_event(ev)
        actual_w = power_mw[:, t] * sc.power_factor[:, t] * 1e6
        pred_w = np.array([p.predict(t) for p in predictors]) * 1e6
        noise = sc.pred_noise[:, t]
        if (noise != 1.0).any():
            pred_w = pred_w * noise
        if bank is not None:
            # knowledge plane: the BMS knows its state of charge — the
            # forecast credits each site with the ride-through power the
            # bank can sustain toward holding the previous draw level
            bank.set_health(sc.battery_health[:, t])
            ride_w = bank.ride_through_mw(dt_h) * 1e6
            pred_w = pred_w + np.minimum(
                ride_w, np.maximum(prev_draw_w - pred_w, 0.0))
        loads_known = arrivals_rps[:, t] * sc.known_arrival_factor[:, t]
        loads_true = arrivals_rps[:, t] * sc.arrival_factor[:, t]

        p = policy.plan_slot(pred_w, loads_known)
        me = getattr(p, "meta", None) or {}
        pl_solve.append(float(p.solve_seconds))
        pl_mode.append(str(me.get("mode", "stateless")))
        pl_dirty.append(int(me.get("dirty_sites", -1)))
        reconfigs = cfgtor.reconfig_count(old, p)
        old = p
        # reality: any plan drawing beyond actual generation browns out
        # — unless the site's battery bridges the deficit (and surplus
        # wind the plan leaves unused charges it)
        avail_w = actual_w
        if bank is not None:
            delivered_mw = bank.step(actual_w / 1e6,
                                     p.power_used() / 1e6, dt_h)
            avail_w = actual_w + delivered_mw * 1e6
        real = apply_power_reality(p, avail_w)
        gtable = real.group_table()
        res = policy.route(gtable, loads_true)
        # observed service latency: per-site inflation (1.0 = nominal) —
        # the straggler signal; feeds the policy for the *next* slot
        lat = sc.latency_factor[:, t]
        mean_e2e = res.aggregate_e2e()
        if (lat != 1.0).any():
            w = res.per_site_load
            tot = float(w.sum())
            if tot > 0:
                mean_e2e *= float((w * lat).sum() / tot)
        policy.observe(lat)
        # bill the slot's realized per-site draw under the scenario's
        # price/carbon factors (truth plane)
        site_draw_w = real.power_used()
        energy_mwh = site_draw_w / 1e6 * dt_h
        prev_draw_w = site_draw_w
        out.append(SlotMetrics(served=res.served, dropped=res.dropped,
                               mean_e2e=mean_e2e,
                               power_w=gtable.total_power(),
                               solve_s=p.solve_seconds, reconfigs=reconfigs,
                               cost_usd=rates.slot_cost_usd(
                                   energy_mwh, t, sc.price_factor[:, t]),
                               carbon_g=rates.slot_carbon_g(
                                   energy_mwh, t, sc.carbon_factor[:, t])))
    # flush controls scheduled at/beyond the horizon (e.g. a recovery
    # landing exactly on the boundary) so a reused policy ends consistent
    for ev in sc.controls_after(T):
        policy.on_event(ev)
    wk = WeekResult(name=name, slots=out,
                    planner={"solve_s": pl_solve, "mode": pl_mode,
                             "dirty_sites": pl_dirty})
    if record:
        # the seed kwarg is inoperative when an explicit scenario is
        # passed (the engine carries its own) — keep it out of the auto
        # filename so identical runs map to one record
        tag_seed = seed if scenario is None else None
        knobs = (r_frac, time_limit, planner_method, planner_workers)
        if grid is not None or battery is not None:
            # grid-plane runs key their own records; plain runs keep the
            # historical knob tuple (existing records stay addressable)
            knobs = knobs + ("grid", grid is not None, battery is not None)
        write_record(_record_path(record, name, S, T, tag_seed, engine,
                                  power_mw[:, :T], arrivals_rps[:, :T],
                                  predictor_kind, knobs),
                     {"policy": name, "seed": engine.seed,
                      "scenario": repr(engine),
                      "predictor_kind": predictor_kind,
                      "result": wk.to_json()})
    return wk


def simulate_week_reference(scheduler: SchedulerName, table: LookupTable,
                            sites: list[SiteSpec], power_mw: np.ndarray,
                            arrivals_rps: np.ndarray, *,
                            predictor_kind: str = "oracle",
                            r_frac: float = 0.03,
                            time_limit: float = 20.0,
                            slots: Optional[int] = None,
                            planner_method: Method = "auto",
                            planner_workers: Optional[int] = None) -> WeekResult:
    """Pre-refactor inlined driver, kept verbatim as the equivalence
    oracle: the policy-driven ``simulate_week`` must reproduce it
    bit-identically for the four legacy scheduler names under the
    default (event-free) scenario (tests/test_scenarios.py)."""
    S, T = power_mw.shape
    T = min(T, arrivals_rps.shape[1]) if slots is None else min(slots, T)
    dispatcher = RequestScheduler(S, packing=False)
    predictors = [SeriesPredictor(power_mw[s], kind=predictor_kind)
                  for s in range(S)]
    old: Optional[Plan] = None
    cfgtor = Configurator()
    out: list[SlotMetrics] = []
    for t in range(T):
        actual_w = power_mw[:, t] * 1e6
        pred_w = np.array([p.predict(t) for p in predictors]) * 1e6
        loads = arrivals_rps[:, t]
        if scheduler == "heron":
            p = plan_l(table, sites, pred_w, loads, objective="latency",
                       old=old, r_frac=r_frac, time_limit=time_limit,
                       method=planner_method, workers=planner_workers)
        elif scheduler == "heron_min_power":
            p = plan_l(table, sites, pred_w, loads, objective="power",
                       old=old, r_frac=r_frac, time_limit=time_limit,
                       method=planner_method, workers=planner_workers)
        elif scheduler == "wrr_dynamollm":
            p = baseline_wrr_dynamollm(table, sites, loads,
                                       time_limit=time_limit)
        elif scheduler == "greedy_min_latency":
            p = baseline_greedy_min_latency(table, sites, loads)
        else:
            raise ValueError(scheduler)
        reconfigs = cfgtor.reconfig_count(old, p)
        old = p
        real = apply_power_reality(p, actual_w)
        gtable = real.group_table()
        res = dispatcher.dispatch(gtable, loads)
        out.append(SlotMetrics(served=res.served, dropped=res.dropped,
                               mean_e2e=res.aggregate_e2e(),
                               power_w=gtable.total_power(),
                               solve_s=p.solve_seconds, reconfigs=reconfigs))
    return WeekResult(name=scheduler, slots=out)


def ar1_wiggle(rng: np.random.Generator, num_sites: int, seconds: int,
               noise: float, phi: float = 0.995) -> np.ndarray:
    """[S, seconds] AR(1) log-wiggle, variance-matched to ``noise``.

    Vectorized over sites and time with a first-order linear filter;
    draws (and results) are identical to the scalar recursion
    ``w[t] = phi*w[t-1] + sig*eps[t]`` with row-major eps draws.
    """
    wig = np.zeros((num_sites, seconds))
    if seconds > 1:
        sig = noise * np.sqrt(1 - phi * phi)
        eps = rng.standard_normal((num_sites, seconds - 1))
        wig[:, 1:] = lfilter([sig], [1.0, -phi], eps, axis=1)
    return wig


# ------------------------------------------------------------------
# fine-grained (1 s) slot simulation — Planner-S + packing (Fig. 17)
# ------------------------------------------------------------------
@dataclass
class FineResult:
    e2e_per_second: dict[str, np.ndarray]       # variant -> [seconds]
    dropped: dict[str, float]                   # variant -> total dropped rps
    class_e2e: dict[str, np.ndarray]            # variant -> [9] mean e2e
    planner_s_solves: list[float] = field(default_factory=list)
    planner_s_status: list[str] = field(default_factory=list)
    # fault/chaos counters (empty for an undisturbed run)
    faults: dict = field(default_factory=dict)

    @property
    def warm_hits(self) -> int:
        """How many Planner-S re-solves the warm path absorbed."""
        return sum(1 for s in self.planner_s_status if s == "warm")

    def to_json(self) -> dict:
        out = {"kind": "fine",
               "e2e_per_second": {k: v.tolist()
                                  for k, v in self.e2e_per_second.items()},
               "dropped": {k: float(v) for k, v in self.dropped.items()},
               "class_e2e": {k: v.tolist()
                             for k, v in self.class_e2e.items()},
               "planner_s_solves": [float(s) for s in self.planner_s_solves],
               "planner_s_status": list(self.planner_s_status)}
        if self.faults:
            out["faults"] = dict(self.faults)
        return out

    @classmethod
    def from_json(cls, d: dict) -> "FineResult":
        return cls(e2e_per_second={k: np.asarray(v, float)
                                   for k, v in d["e2e_per_second"].items()},
                   dropped={k: float(v) for k, v in d["dropped"].items()},
                   class_e2e={k: np.asarray(v, float)
                              for k, v in d["class_e2e"].items()},
                   planner_s_solves=list(d.get("planner_s_solves", [])),
                   planner_s_status=list(d.get("planner_s_status", [])),
                   faults=dict(d.get("faults", {})))


def simulate_slot_fine(table: LookupTable, sites: list[SiteSpec],
                       base_plan: Plan, power_w_slot: np.ndarray,
                       arrivals_rps: np.ndarray, *, seconds: int = 900,
                       planner_s_period: float = 5.0,
                       power_noise: float = 0.04,
                       power_scale: float = 1.0,
                       variants=("L", "L+S", "L+S+pack"),
                       seed: int = 0, warm_start: bool = True,
                       scenario: Optional[ScenarioEngine] = None) -> FineResult:
    """Second-level simulation of one 15-min slot.

    Power per second follows an AR(1) wiggle (±power_noise) around
    ``power_scale`` × the slot value; arrivals are Poisson per second.
    Variants: 'L' follows Planner-L blindly; 'L+S' re-solves (f, l) every
    ``planner_s_period`` s at observed load/power; '+pack' adds the
    Request Scheduler packing heuristic.

    ``scenario`` injects second-granularity disturbances through the
    same engine the week simulator uses (tick = 1 s here): grid trips /
    curtailment scale per-second power, demand surges scale the Poisson
    intensities, and a ``PowerWiggle`` event overrides the AR(1)
    parameters. The default (no scenario) path is bit-identical to the
    historical hardcoded AR(1)-only disturbance model.

    Scenarios thread BOTH planes at second granularity: Planner-S plans
    on the *knowledge* power (``known_power_factor`` — a surprise
    ``GridTrip`` is invisible to the re-solve until its detection lag
    elapses) with sites zeroed once the scenario's control stream marks
    them down (``site_down`` / full-depth ``grid_trip``), while brownout
    shedding always confronts the plan with *truth* power. Per-site
    ``latency_factor`` inflates the service component of E2E (not the
    queueing wait) weighted by where the dispatch actually landed load —
    so a mid-slot trip shows second-granularity detection dynamics and a
    straggler site drags exactly the seconds it serves.

    The Planner-L GPU grant is pulled once as a columnar ``GpuBudget``
    and each Planner-S re-solve is warm-started from the previous one
    (``warm_start=False`` restores cold solves — the knob
    benchmarks/bench_planning.py measures).
    """
    rng = np.random.default_rng(seed)
    S = len(sites)
    gpu_budget = base_plan.gpu_budget_pool()
    period = max(float(planner_s_period), 1.0)
    # per-second power: AR(1) multiplicative wiggle (vectorized)
    wig_ev = scenario.fine_wiggle() if scenario is not None else None
    if wig_ev is not None:
        wig = ar1_wiggle(rng, S, seconds, wig_ev.noise, wig_ev.phi)
    else:
        wig = ar1_wiggle(rng, S, seconds, power_noise)
    pw = power_w_slot[:, None] * power_scale * np.exp(wig)
    lam = np.maximum(arrivals_rps, 0)[:, None]
    known_pw = pw                   # knowledge plane == truth by default
    lat_f = None                    # [S, seconds] latency inflation
    sc = None
    if scenario is not None:
        sc = scenario.compile(S, seconds)
        if not sc.is_trivial:       # trivial scenario keeps the exact
            known_pw = pw * sc.known_power_factor
            pw = pw * sc.power_factor   # historical arrays (bit-compat)
            lam = lam * sc.arrival_factor
            if (sc.latency_factor != 1.0).any():
                lat_f = sc.latency_factor
        else:
            sc = None
    arr = rng.poisson(lam, size=(9, seconds)).astype(float)
    # ticks where a control RESTORES a site: event-driven Planner-S
    # re-solve points. Without these, a site coming back mid-segment sits
    # idle until the cadence's next multiple-of-period solve — the L+S
    # recovery lag the goodput regression pins.
    restore_ticks: list[int] = []
    if sc is not None:
        restore_ticks = sorted(
            tk for tk, evs in sc.controls.items()
            if any(e.kind in ("site_up", "grid_restored") for e in evs))

    def _apply_controls(alive: np.ndarray, tick: int) -> None:
        """Second-granularity site-health edges for the Planner-S view
        (mirrors HeronRouter.on_event's health semantics)."""
        for ev in sc.controls_at(tick):
            if ev.kind == "site_down" or (
                    ev.kind == "grid_trip" and ev.value >= 0.999):
                alive[ev.site] = False
            elif ev.kind in ("site_up", "grid_restored"):
                alive[ev.site] = True

    results_e2e = {}
    results_drop = {}
    results_cls = {}
    solves = []
    statuses = []
    for variant in variants:
        packing = variant.endswith("pack")
        use_s = variant != "L"
        dispatcher = RequestScheduler(S, packing=packing)
        backlog = np.zeros(9)
        e2e_series = np.zeros(seconds)
        cls_num = np.zeros(9)
        cls_den = np.zeros(9)
        dropped_total = 0.0
        plan = base_plan
        prev_s: Optional[Plan] = None
        alive = np.ones(S, bool)    # control-stream site health (per variant)
        t = 0
        while t < seconds:
            if sc is not None:
                _apply_controls(alive, t)
            if use_s:
                obs_load = arr[:, max(0, t - 5): t + 1].mean(axis=1)
                # plan on the KNOWLEDGE plane: what telemetry/forecasts
                # can see at second t, with control-confirmed dead sites
                # zeroed — truth hits via shedding below
                plan_pw = known_pw[:, t]
                if not alive.all():
                    plan_pw = plan_pw * alive
                # plan for a small headroom over observed load
                p = plan_s(table, sites, plan_pw, obs_load * 1.1,
                           gpu_budget, objective=base_plan.objective,
                           warm=prev_s if warm_start else None)
                if p.status != "empty":
                    plan = p
                    prev_s = p
                    solves.append(p.solve_seconds)
                    statuses.append(p.status)
                # next re-solve at the next multiple of the period — or at
                # the next restore edge, whichever lands first: the next
                # iteration then re-solves AT the restore with ``alive``
                # freshly updated instead of waiting out the cadence
                next_solve = (np.floor(t / period) + 1) * period
                t_end = min(seconds, int(np.ceil(next_solve)))
                for rt in restore_ticks:
                    if t < rt < t_end:
                        t_end = rt
                        break
            else:
                t_end = seconds
            # ---- segment [t, t_end): the plan (and shed geometry) is
            # constant, so brown out the whole segment in one shot ----
            seg_counts = shed_counts_batch(plan, pw[:, t:t_end])
            gtable = GroupTable.from_plan(plan, active_only=False)
            for tt in range(t, t_end):
                if sc is not None and tt > t:
                    # mid-segment control edges update health for the
                    # NEXT re-solve (detection → next Planner-S pass)
                    _apply_controls(alive, tt)
                tbl = gtable.with_counts(seg_counts[:, tt - t])
                demand = arr[:, tt] + backlog
                res = dispatcher.dispatch(tbl, demand)
                cap = np.bincount(tbl.cls, weights=tbl.capacity, minlength=9)
                # fluid backlog: what was neither served nor dropped waits
                backlog = np.maximum(demand - res.served - res.dropped, 0.0)
                # cap the queue at 2x/s of capacity; beyond that it drops
                overflow = np.maximum(backlog - 2.0 * cap, 0.0)
                backlog -= overflow
                drop = res.dropped + overflow
                dropped_total += float(drop.sum())
                wait = np.where(cap > 0, backlog / np.maximum(cap, 1e-9), 0.0)
                svc = res.mean_e2e
                if lat_f is not None and (lat_f[:, tt] != 1.0).any():
                    # stragglers inflate SERVICE time (not queueing),
                    # weighted by where this second's load actually went
                    w_site = res.per_site_load
                    tot = float(w_site.sum())
                    if tot > 0:
                        svc = svc * float(
                            (w_site * lat_f[:, tt]).sum() / tot)
                e2e_c = svc + wait
                m = res.served > 0
                e2e_series[tt] = (float((e2e_c[m] * res.served[m]).sum()
                                        / res.served[m].sum()) if m.any() else 0.0)
                cls_num += e2e_c * res.served
                cls_den += res.served
            t = t_end
        results_e2e[variant] = e2e_series
        results_drop[variant] = dropped_total
        results_cls[variant] = cls_num / np.maximum(cls_den, 1e-9)
    return FineResult(e2e_per_second=results_e2e, dropped=results_drop,
                      class_e2e=results_cls, planner_s_solves=solves,
                      planner_s_status=statuses)


# ------------------------------------------------------------------
# engine-level chaos: live ServingEngines under a FaultInjector
# ------------------------------------------------------------------
# shared percentile helper (core.stats): empty samples report NaN so a
# site that served nothing during a trip cannot fake a perfect tail
_pctl = percentile


@dataclass
class ChaosResult:
    """Outcome of a ``simulate_serving_chaos`` run — the resilience
    scorecard ``benchmarks/bench_resilience.py`` compares variants on."""
    name: str
    ticks: int
    completed: int
    failed: int                 # permanent failures (retry budget spent)
    timed_out: int
    rejected: int
    preemptions: int
    resumes: int
    served_tokens: int          # unique delivered tokens over completed rids
    recovered_tokens: int       # tokens carried across preempt->resume
    lost_tokens: int            # tokens generated but never delivered
    duplicated_tokens: int      # MUST be 0 — resume behind the stream
    p50_ttft: float
    p99_ttft: float
    p50_e2e: float
    p99_e2e: float
    faults: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "name", "ticks", "completed", "failed", "timed_out", "rejected",
            "preemptions", "resumes", "served_tokens", "recovered_tokens",
            "lost_tokens", "duplicated_tokens",
            "p50_ttft", "p99_ttft", "p50_e2e", "p99_e2e")}
        d["kind"] = "chaos"
        d["faults"] = dict(self.faults)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ChaosResult":
        kw = {k: d[k] for k in (
            "name", "ticks", "completed", "failed", "timed_out", "rejected",
            "preemptions", "resumes", "served_tokens", "recovered_tokens",
            "lost_tokens", "duplicated_tokens",
            "p50_ttft", "p99_ttft", "p50_e2e", "p99_e2e")}
        return cls(faults=dict(d.get("faults", {})), **kw)


class ServingCluster:
    """Live ``ServingEngine``s at every site + the cross-site failover
    layer — where the control plane's fault story meets real tokens.

    ``make_engine(site, clock) -> ServingEngine`` builds a site's engine
    on the cluster's shared *virtual* clock (one tick = ``tick_seconds``),
    so TTFT/E2E are deterministic simulated seconds, not wall time.

    Failover contract (see ``core.router`` docstring): on a ``kill``
    fault the dying site's engine is drained into transcript snapshots;
    with ``failover=True`` each snapshot is re-admitted sticky-first down
    ``policy.failover_order(site)`` (alive-sites-by-index without a
    policy), spending a per-snapshot retry budget with
    ``serving.engine.retry_backoff`` pacing re-attempts; a snapshot that
    exhausts the budget is a permanent failure. With ``failover=False``
    (the blind baseline) drained work is simply lost. New arrivals
    redirect off dead sites in both modes, so a resilience A/B isolates
    exactly the in-flight recovery path.

    Delivery ledger: per-rid high-water marks of tokens already streamed
    to the user catch *duplicated* tokens — a resume that restarts behind
    its own stream re-emits tokens, which the keyed sampling scheme makes
    impossible by construction; the ledger is the run-time proof.
    """

    def __init__(self, num_sites: int, make_engine, *, policy=None,
                 failover: bool = True, retry_budget: int = 3,
                 tick_seconds: float = 1.0):
        self.num_sites = num_sites
        self.policy = policy
        self.failover = failover
        self.retry_budget = retry_budget
        self.tick_seconds = float(tick_seconds)
        self.now = 0.0
        self._make_engine = make_engine
        self.engines = [make_engine(s, self._clock) for s in range(num_sites)]
        self.alive = np.ones(num_sites, bool)
        self.read_power = np.ones(num_sites)   # corruptible telemetry
        self._delayed: set = set()             # sites stalled this tick
        self._dropping: set = set()            # sites not admitting this tick
        self._ncons = [0] * num_sites          # completed-harvest cursors
        self._graveyard: list = []             # metrics of replaced engines
        self._hwm: dict[int, int] = {}         # rid -> delivered high-water
        self._done_rids: set = set()
        self.pending: list = []                # [snap, next_try_s] awaiting slot
        self.failed: list = []                 # permanently failed snapshots
        self.completed_ttft: list = []
        self.completed_e2e: list = []
        self.duplicated_tokens = 0
        self.lost_tokens = 0                   # cluster-level (failed snaps)
        self.fault_counts: dict[str, int] = {}

    def _clock(self) -> float:
        return self.now

    # ------------------------------------------------------------ routing
    def _order_from(self, site: int) -> list[int]:
        """Failover landing order off ``site`` — the policy's view when it
        has one (``failover_order``), else alive sites by index."""
        fo = getattr(self.policy, "failover_order", None)
        if fo is not None:
            order = [s for s in fo(site)
                     if s < self.num_sites and self.alive[s]]
            # policy may not know about every dead/alive edge we've seen
            rest = [s for s in range(self.num_sites)
                    if self.alive[s] and s != site and s not in order]
            return order + rest
        return [s for s in range(self.num_sites)
                if self.alive[s] and s != site]

    def submit(self, req, site: int) -> bool:
        """Submit a fresh request to ``site``, redirecting down the
        failover order when the site is dead or its watermark rejects."""
        candidates = ([site] if self.alive[site] else []) \
            + self._order_from(site)
        for s in candidates:
            if self.engines[s] is not None and self.engines[s].submit(req):
                return True
        return False

    # ------------------------------------------------------------- faults
    def apply_fault(self, f) -> None:
        from repro.sim import faults as F
        self.fault_counts[f.kind] = self.fault_counts.get(f.kind, 0) + 1
        if f.kind == F.KILL:
            self.kill(f.site)
        elif f.kind == F.RESTORE:
            self.restore(f.site)
        elif f.kind == F.DELAY:
            self._delayed.add(f.site)
        elif f.kind == F.DROP_ADMISSION:
            self._dropping.add(f.site)
        elif f.kind == F.CORRUPT_POWER:
            self.read_power[f.site] = f.value
        else:
            raise ValueError(f"unknown fault kind {f.kind!r}")

    def kill(self, site: int) -> None:
        """Site loses power mid-decode: drain the engine, hand the
        transcripts to failover (or lose them, blind mode)."""
        if not self.alive[site] or self.engines[site] is None:
            return
        eng = self.engines[site]
        self._harvest(site)
        snaps = eng.drain()
        self.alive[site] = False
        if self.policy is not None:
            from repro.sim.scenarios import ControlEvent
            self.policy.on_event(ControlEvent(kind="site_down", site=site))
        self._graveyard.append(eng.metrics)
        self.engines[site] = None
        self._ncons[site] = 0
        if self.failover:
            for snap in snaps:
                self._place(snap, from_site=site)
        else:
            for snap in snaps:
                self.lost_tokens += len(snap.tokens)
                self.failed.append(snap)

    def restore(self, site: int) -> None:
        if self.alive[site]:
            return
        self.engines[site] = self._make_engine(site, self._clock)
        self.alive[site] = True
        self.read_power[site] = 1.0
        if self.policy is not None:
            from repro.sim.scenarios import ControlEvent
            self.policy.on_event(ControlEvent(kind="site_up", site=site))

    # ----------------------------------------------------------- failover
    def _place(self, snap, from_site: int) -> None:
        """Sticky re-route: first surviving site in the failover order
        that accepts wins; a snapshot nobody accepts waits out a capped
        exponential backoff before the next attempt; the retry budget
        bounds total attempts, after which the request permanently fails
        (and its generated-but-undelivered tokens count as lost)."""
        from repro.serving.engine import retry_backoff
        snap.attempts += 1
        if snap.attempts > self.retry_budget:
            self.lost_tokens += len(snap.tokens)
            self.failed.append(snap)
            return
        for s in self._order_from(from_site):
            eng = self.engines[s]
            if eng is None:
                continue
            # duplicated-token check BEFORE resuming: resuming below the
            # delivered high-water mark would re-emit tokens
            hwm = self._hwm.get(snap.rid, 0)
            req = eng.resume(
                snap, not_before_s=self.now + retry_backoff(snap.attempts))
            if req is not None:
                self.duplicated_tokens += max(0, hwm - len(snap.tokens))
                return
        # nowhere to land right now — retry after backoff
        self.pending.append([snap, self.now + retry_backoff(snap.attempts)])

    def _retry_pending(self) -> None:
        due = [p for p in self.pending if p[1] <= self.now]
        if not due:
            return
        self.pending = [p for p in self.pending if p[1] > self.now]
        for snap, _ in due:
            self._place(snap, from_site=-1)

    # ------------------------------------------------------------ stepping
    def _harvest(self, site: int) -> None:
        """Pull newly-completed requests into the delivery ledger."""
        eng = self.engines[site]
        if eng is None:
            return
        done = eng.metrics.completed
        for req in done[self._ncons[site]:]:
            n = len(req.tokens)
            hwm = self._hwm.get(req.rid, 0)
            self._hwm[req.rid] = max(hwm, n)
            self._done_rids.add(req.rid)
            if req.ttft is not None:
                self.completed_ttft.append(req.ttft)
            if req.e2e is not None:
                self.completed_e2e.append(req.e2e)
        self._ncons[site] = len(done)

    def step_tick(self, faults=(), arrivals=()) -> None:
        """One cluster tick: faults land, pending failovers retry, this
        tick's arrivals submit, every live site steps once (unless
        delayed), the delivery ledger harvests completions, the virtual
        clock advances."""
        self._delayed.clear()
        self._dropping.clear()
        for f in faults:
            self.apply_fault(f)
        self._retry_pending()
        for site, req in arrivals:
            req.arrival_s = self.now
            self.submit(req, site)
        for s in range(self.num_sites):
            eng = self.engines[s]
            if eng is None or s in self._delayed:
                continue                     # stalled: live requests wait
            if s in self._dropping:
                held = eng.waiting           # admission frozen this tick
                eng.waiting = deque()
                try:
                    eng.step()
                finally:
                    # held requests keep their queue position; anything an
                    # error path requeued lands behind them
                    leftover = eng.waiting
                    eng.waiting = held
                    eng.waiting.extend(leftover)
            else:
                eng.step()
            self._harvest(s)
        self.now += self.tick_seconds

    def drained(self) -> bool:
        return (not self.pending
                and all(e is None or (not e.waiting
                                      and not any(e.active))
                        for e in self.engines))

    # ------------------------------------------------------------- result
    def result(self, name: str, ticks: int,
               faults_record: Optional[dict] = None) -> ChaosResult:
        for s in range(self.num_sites):
            self._harvest(s)
        metrics = list(self._graveyard) + [e.metrics for e in self.engines
                                           if e is not None]
        agg = lambda attr: int(sum(getattr(m, attr) for m in metrics))
        served = int(sum(self._hwm[r] for r in self._done_rids))
        rec = {"counts": dict(self.fault_counts)}
        if faults_record:
            rec.update(faults_record)
        return ChaosResult(
            name=name, ticks=ticks,
            completed=len(self._done_rids),
            failed=len(self.failed),
            timed_out=int(sum(len(m.timed_out) for m in metrics)),
            rejected=int(sum(len(m.rejected) for m in metrics)),
            preemptions=agg("preemptions"),
            resumes=agg("resumed"),
            served_tokens=served,
            recovered_tokens=agg("recovered_tokens"),
            lost_tokens=self.lost_tokens + agg("lost_tokens"),
            duplicated_tokens=self.duplicated_tokens
            + agg("duplicated_tokens"),
            p50_ttft=_pctl(self.completed_ttft, 50),
            p99_ttft=_pctl(self.completed_ttft, 99),
            p50_e2e=_pctl(self.completed_e2e, 50),
            p99_e2e=_pctl(self.completed_e2e, 99),
            faults=rec)


def simulate_serving_chaos(num_sites: int, make_engine, requests,
                           injector=None, *, name: str = "chaos",
                           policy=None, failover: bool = True,
                           retry_budget: int = 3, ticks: int = 64,
                           drain_ticks: int = 512,
                           tick_seconds: float = 1.0) -> ChaosResult:
    """Drive live engines through a faulted request timeline.

    ``requests``: [(tick, site, Request)] arrivals; ``injector``: a
    ``sim.faults.FaultInjector`` (None = fault-free). After ``ticks``
    scripted ticks the cluster keeps stepping (fault-free) up to
    ``drain_ticks`` more to let surviving work finish — goodput then
    reflects what the fleet actually delivered, not where the horizon
    happened to fall.
    """
    cluster = ServingCluster(num_sites, make_engine, policy=policy,
                             failover=failover, retry_budget=retry_budget,
                             tick_seconds=tick_seconds)
    by_tick: dict[int, list] = {}
    for tick, site, req in requests:
        by_tick.setdefault(int(tick), []).append((site, req))
    for t in range(ticks):
        faults = injector.faults_at(t) if injector is not None else ()
        cluster.step_tick(faults=faults, arrivals=by_tick.get(t, ()))
    for _ in range(drain_ticks):
        if cluster.drained():
            break
        cluster.step_tick()
    return cluster.result(
        name, ticks,
        faults_record=(injector.to_json() if injector is not None else None))
