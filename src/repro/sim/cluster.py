"""Week-long cross-site serving simulation (paper §5.2/§5.3).

Two granularities, matching the paper's evaluation methodology:

  * ``simulate_week``      — 15-min slots over 672 slots: a pluggable
    ``RoutingPolicy`` (see ``repro.sim.policy``) plans each slot; goodput
    / drops / latency / power are accounted per slot. Baselines are
    power-variability agnostic, so their plans are confronted with
    reality via ``apply_power_reality`` (whole-instance brownout
    shedding) — reproducing Fig. 8/14/15.

  * ``simulate_slot_fine`` — 1-s steps inside one slot: per-second power
    and Poisson arrivals fluctuate around the slot values; Planner-S re-
    solves (f, l) every few seconds inside Planner-L's GPU budget, and the
    Request Scheduler's packing heuristic absorbs transient per-class
    overloads — reproducing Fig. 17 and the §5.3 elasticity test.

Control plane
-------------
The driver is policy/scenario-driven rather than an inlined planning
loop:

  * ``simulate_week(name_or_policy, ...)`` resolves a ``RoutingPolicy``
    through the name->factory registry (``"heron"``,
    ``"heron_min_power"``, ``"wrr_dynamollm"``, ``"greedy_min_latency"``,
    or anything added via ``register_policy``) and drives its
    plan_slot / route / observe / on_event lifecycle. For the Heron
    names this is the *actual* ``HeronRouter`` object — straggler EWMA
    haircuts and ``mark_site_down`` health replanning shape weekly
    results (the paper's K1 story), and the Configurator's re-shard
    freeze clock ticks at slot cadence (its freeze windows bind
    Planner-S via ``plan_fine``) — instead of being bypassed by a
    parallel if/elif loop. A policy *instance* is driven as configured
    (e.g. a hand-built ``HeronRouter`` keeps its ``packing=True``
    dispatch default); use the registry names for the week scoring
    convention (no packing, matching ``simulate_week_reference``).
  * disturbances come from a seeded ``ScenarioEngine``
    (``repro.sim.scenarios``): site failures & recoveries, grid-trip
    power cliffs, curtailment orders, demand surges/diurnal swell,
    predictor-error regimes, straggler onset — compiled once into
    per-tick truth/knowledge factors and control events, consumed
    uniformly here and in ``simulate_slot_fine``. The default
    (event-free) scenario perturbs nothing, and the legacy scheduler
    names stay bit-identical to the pre-refactor driver (kept as
    ``simulate_week_reference``; pinned by tests/test_scenarios.py).

Fluid-flow semantics: requests are rps flows per class; queueing beyond
rated capacity accrues in a per-class fluid backlog whose Little's-law
wait adds to the table E2E. 'Goodput' is served rps (the paper's "requests
being actually served").

Fast path
---------
Both simulators run on the columnar dispatch engine (``GroupTable``):

  * the AR(1) power wiggle is generated for all sites at once with a
    first-order ``scipy.signal.lfilter`` (bit-identical to the scalar
    recursion — same draws, same order, same arithmetic);
  * ``simulate_slot_fine`` batches the seconds between two Planner-S
    re-solves: the plan — and hence the shed geometry — is constant
    inside a segment, so brownout shedding for the whole segment is one
    vectorized ``shed_counts_batch`` call and each second's dispatch is
    a cheap ``GroupTable.with_counts`` + vector dispatch (the per-second
    Python loop only threads the fluid backlog, which is inherently
    sequential);
  * each Planner-S re-solve is warm-started from the previous one
    (status ``"warm"``; ``FineResult.warm_hits`` counts them, and
    ``warm_start=False`` restores cold solves for A/B benchmarks).

Run records: ``WeekResult``/``FineResult`` round-trip through
``to_json``/``from_json``; pass ``record=`` to persist a run under
``artifacts/sim/`` (benchmarks reload records via ``load_week_result``
instead of re-simulating).
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass, field
from typing import Literal, Optional, Union

import numpy as np
from scipy.signal import lfilter

from repro.core.baselines import (apply_power_reality,
                                  baseline_greedy_min_latency,
                                  baseline_wrr_dynamollm, shed_counts_batch)
from repro.core.lookup import LookupTable
from repro.core.planner_l import Method, Plan, SiteSpec, plan_l
from repro.core.planner_s import plan_s
from repro.core.predictor import SeriesPredictor
from repro.core.scheduler import Configurator, GroupTable, RequestScheduler
from repro.sim.record import load_record, write_record
from repro.sim.scenarios import ScenarioEngine

SchedulerName = Literal["heron", "heron_min_power", "wrr_dynamollm",
                        "greedy_min_latency"]


@dataclass
class SlotMetrics:
    served: np.ndarray
    dropped: np.ndarray
    mean_e2e: float
    power_w: float
    solve_s: float
    reconfigs: int

    @property
    def total_served(self) -> float:
        return float(self.served.sum())

    @property
    def total_dropped(self) -> float:
        return float(self.dropped.sum())

    def to_json(self) -> dict:
        return {"served": self.served.tolist(),
                "dropped": self.dropped.tolist(),
                "mean_e2e": float(self.mean_e2e),
                "power_w": float(self.power_w),
                "solve_s": float(self.solve_s),
                "reconfigs": int(self.reconfigs)}

    @classmethod
    def from_json(cls, d: dict) -> "SlotMetrics":
        return cls(served=np.asarray(d["served"], float),
                   dropped=np.asarray(d["dropped"], float),
                   mean_e2e=float(d["mean_e2e"]),
                   power_w=float(d["power_w"]),
                   solve_s=float(d["solve_s"]),
                   reconfigs=int(d["reconfigs"]))


@dataclass
class WeekResult:
    name: str
    slots: list[SlotMetrics]

    def goodput(self) -> np.ndarray:
        return np.array([s.total_served for s in self.slots])

    def drops(self) -> np.ndarray:
        return np.array([s.total_dropped for s in self.slots])

    def slots_with_drops(self, eps: float = 1e-6) -> int:
        return int((self.drops() > eps).sum())

    def mean_e2e(self) -> np.ndarray:
        return np.array([s.mean_e2e for s in self.slots])

    def power(self) -> np.ndarray:
        return np.array([s.power_w for s in self.slots])

    def to_json(self) -> dict:
        return {"kind": "week", "name": self.name,
                "slots": [s.to_json() for s in self.slots]}

    @classmethod
    def from_json(cls, d: dict) -> "WeekResult":
        return cls(name=d["name"],
                   slots=[SlotMetrics.from_json(s) for s in d["slots"]])


def goodput_improvement(heron: WeekResult, baseline: WeekResult) -> np.ndarray:
    """Per-slot goodput ratio (Fig. 14 middle / Fig. 15): Heron / baseline."""
    g_h, g_b = heron.goodput(), baseline.goodput()
    return g_h / np.maximum(g_b, 1e-9)


# repo root (src/repro/sim/cluster.py -> 4 levels up): record=True must
# land in the same artifacts/sim/ tree the benchmarks read regardless of
# the launch directory
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def _record_path(record: Union[str, bool], name: str, S: int, T: int,
                 seed: Optional[int], engine: ScenarioEngine,
                 power_mw: np.ndarray, arrivals_rps: np.ndarray,
                 predictor_kind: str, planner_knobs: tuple) -> str:
    if record is True:
        record = os.path.join(_REPO_ROOT, "artifacts", "sim")
    if str(record).endswith(".json"):
        return str(record)
    # distinct runs must not overwrite each other's records: the auto
    # name keys on the workload inputs (power/arrival windows, predictor,
    # planner knobs) and, when events are present, the scenario stack
    h = hashlib.md5()
    h.update(np.ascontiguousarray(power_mw).tobytes())
    h.update(np.ascontiguousarray(arrivals_rps).tobytes())
    h.update(repr((predictor_kind, planner_knobs)).encode())
    tag = f"_w{h.hexdigest()[:8]}"
    if seed is not None:
        tag += f"_seed{seed}"
    if engine.events:
        sc_digest = hashlib.md5(
            repr((engine.seed, engine.events)).encode()).hexdigest()[:8]
        tag += f"_sc{sc_digest}"
    return os.path.join(str(record), f"week_{name}_{S}sites_{T}slots{tag}.json")


def load_week_result(path: str) -> WeekResult:
    """Reload a recorded ``simulate_week`` run (see the ``record=`` knob)."""
    d = load_record(path)
    return WeekResult.from_json(d.get("result", d))


def simulate_week(scheduler, table: LookupTable,
                  sites: list[SiteSpec], power_mw: np.ndarray,
                  arrivals_rps: np.ndarray, *,
                  predictor_kind: str = "oracle", r_frac: float = 0.03,
                  time_limit: float = 20.0,
                  slots: Optional[int] = None,
                  planner_method: Method = "auto",
                  planner_workers: Optional[int] = None,
                  scenario: Optional[ScenarioEngine] = None,
                  seed: Optional[int] = None,
                  record: Union[str, bool, None] = None) -> WeekResult:
    """Slot-level week simulation, driven by a pluggable RoutingPolicy.

    ``scheduler``: a registered policy name (see
    ``repro.sim.policy.list_policies``) or a ``RoutingPolicy`` instance.
    ``power_mw``: [S, T] available generation per site; arrivals_rps:
    [9, T]. The site's usable power is min(generation, provisioned
    demand) — the provisioned hardware cap is already expressed by the
    GPU constraint. ``planner_method``/``planner_workers`` select the
    Planner-L solve path for the Heron policies ("auto" = the
    drain-priced decomposition at every fleet size; "monolithic" = the
    exact reference) and the site-ILP pool size.

    ``scenario`` perturbs per-slot truth and emits control events
    (``repro.sim.scenarios``); ``seed`` makes the whole run reproducible
    (it seeds the default scenario — pass an explicitly-seeded engine to
    combine both). ``record`` persists the result as a JSON run record:
    ``True`` -> artifacts/sim/, a directory, or a full ``.json`` path.
    """
    S, T = power_mw.shape
    T = min(T, arrivals_rps.shape[1]) if slots is None else min(slots, T)

    engine = scenario if scenario is not None else ScenarioEngine(seed=seed)
    sc = engine.compile(S, T)

    if isinstance(scheduler, str):
        from repro.sim.policy import make_policy
        policy = make_policy(scheduler, table, sites, r_frac=r_frac,
                             time_limit=time_limit,
                             planner_method=planner_method,
                             planner_workers=planner_workers)
        name = scheduler
    else:
        policy = scheduler
        name = getattr(scheduler, "name", type(scheduler).__name__)

    # knowledge plane: the forecast pipeline's view of the power series
    # (full-length so predictor clamping sees the same range as truth)
    known_power = power_mw.astype(float).copy()
    known_power[:, :T] *= sc.known_power_factor
    predictors = [SeriesPredictor(known_power[s], kind=predictor_kind)
                  for s in range(S)]

    old: Optional[Plan] = None
    cfgtor = Configurator()
    out: list[SlotMetrics] = []
    for t in range(T):
        for ev in sc.controls_at(t):
            policy.on_event(ev)
        actual_w = power_mw[:, t] * sc.power_factor[:, t] * 1e6
        pred_w = np.array([p.predict(t) for p in predictors]) * 1e6
        noise = sc.pred_noise[:, t]
        if (noise != 1.0).any():
            pred_w = pred_w * noise
        loads_known = arrivals_rps[:, t] * sc.known_arrival_factor[:, t]
        loads_true = arrivals_rps[:, t] * sc.arrival_factor[:, t]

        p = policy.plan_slot(pred_w, loads_known)
        reconfigs = cfgtor.reconfig_count(old, p)
        old = p
        # reality: any plan drawing beyond actual generation browns out
        real = apply_power_reality(p, actual_w)
        gtable = real.group_table()
        res = policy.route(gtable, loads_true)
        # observed service latency: per-site inflation (1.0 = nominal) —
        # the straggler signal; feeds the policy for the *next* slot
        lat = sc.latency_factor[:, t]
        mean_e2e = res.aggregate_e2e()
        if (lat != 1.0).any():
            w = res.per_site_load
            tot = float(w.sum())
            if tot > 0:
                mean_e2e *= float((w * lat).sum() / tot)
        policy.observe(lat)
        out.append(SlotMetrics(served=res.served, dropped=res.dropped,
                               mean_e2e=mean_e2e,
                               power_w=gtable.total_power(),
                               solve_s=p.solve_seconds, reconfigs=reconfigs))
    # flush controls scheduled at/beyond the horizon (e.g. a recovery
    # landing exactly on the boundary) so a reused policy ends consistent
    for ev in sc.controls_after(T):
        policy.on_event(ev)
    wk = WeekResult(name=name, slots=out)
    if record:
        # the seed kwarg is inoperative when an explicit scenario is
        # passed (the engine carries its own) — keep it out of the auto
        # filename so identical runs map to one record
        tag_seed = seed if scenario is None else None
        write_record(_record_path(record, name, S, T, tag_seed, engine,
                                  power_mw[:, :T], arrivals_rps[:, :T],
                                  predictor_kind,
                                  (r_frac, time_limit, planner_method,
                                   planner_workers)),
                     {"policy": name, "seed": engine.seed,
                      "scenario": repr(engine),
                      "predictor_kind": predictor_kind,
                      "result": wk.to_json()})
    return wk


def simulate_week_reference(scheduler: SchedulerName, table: LookupTable,
                            sites: list[SiteSpec], power_mw: np.ndarray,
                            arrivals_rps: np.ndarray, *,
                            predictor_kind: str = "oracle",
                            r_frac: float = 0.03,
                            time_limit: float = 20.0,
                            slots: Optional[int] = None,
                            planner_method: Method = "auto",
                            planner_workers: Optional[int] = None) -> WeekResult:
    """Pre-refactor inlined driver, kept verbatim as the equivalence
    oracle: the policy-driven ``simulate_week`` must reproduce it
    bit-identically for the four legacy scheduler names under the
    default (event-free) scenario (tests/test_scenarios.py)."""
    S, T = power_mw.shape
    T = min(T, arrivals_rps.shape[1]) if slots is None else min(slots, T)
    dispatcher = RequestScheduler(S, packing=False)
    predictors = [SeriesPredictor(power_mw[s], kind=predictor_kind)
                  for s in range(S)]
    old: Optional[Plan] = None
    cfgtor = Configurator()
    out: list[SlotMetrics] = []
    for t in range(T):
        actual_w = power_mw[:, t] * 1e6
        pred_w = np.array([p.predict(t) for p in predictors]) * 1e6
        loads = arrivals_rps[:, t]
        if scheduler == "heron":
            p = plan_l(table, sites, pred_w, loads, objective="latency",
                       old=old, r_frac=r_frac, time_limit=time_limit,
                       method=planner_method, workers=planner_workers)
        elif scheduler == "heron_min_power":
            p = plan_l(table, sites, pred_w, loads, objective="power",
                       old=old, r_frac=r_frac, time_limit=time_limit,
                       method=planner_method, workers=planner_workers)
        elif scheduler == "wrr_dynamollm":
            p = baseline_wrr_dynamollm(table, sites, loads,
                                       time_limit=time_limit)
        elif scheduler == "greedy_min_latency":
            p = baseline_greedy_min_latency(table, sites, loads)
        else:
            raise ValueError(scheduler)
        reconfigs = cfgtor.reconfig_count(old, p)
        old = p
        real = apply_power_reality(p, actual_w)
        gtable = real.group_table()
        res = dispatcher.dispatch(gtable, loads)
        out.append(SlotMetrics(served=res.served, dropped=res.dropped,
                               mean_e2e=res.aggregate_e2e(),
                               power_w=gtable.total_power(),
                               solve_s=p.solve_seconds, reconfigs=reconfigs))
    return WeekResult(name=scheduler, slots=out)


def ar1_wiggle(rng: np.random.Generator, num_sites: int, seconds: int,
               noise: float, phi: float = 0.995) -> np.ndarray:
    """[S, seconds] AR(1) log-wiggle, variance-matched to ``noise``.

    Vectorized over sites and time with a first-order linear filter;
    draws (and results) are identical to the scalar recursion
    ``w[t] = phi*w[t-1] + sig*eps[t]`` with row-major eps draws.
    """
    wig = np.zeros((num_sites, seconds))
    if seconds > 1:
        sig = noise * np.sqrt(1 - phi * phi)
        eps = rng.standard_normal((num_sites, seconds - 1))
        wig[:, 1:] = lfilter([sig], [1.0, -phi], eps, axis=1)
    return wig


# ------------------------------------------------------------------
# fine-grained (1 s) slot simulation — Planner-S + packing (Fig. 17)
# ------------------------------------------------------------------
@dataclass
class FineResult:
    e2e_per_second: dict[str, np.ndarray]       # variant -> [seconds]
    dropped: dict[str, float]                   # variant -> total dropped rps
    class_e2e: dict[str, np.ndarray]            # variant -> [9] mean e2e
    planner_s_solves: list[float] = field(default_factory=list)
    planner_s_status: list[str] = field(default_factory=list)

    @property
    def warm_hits(self) -> int:
        """How many Planner-S re-solves the warm path absorbed."""
        return sum(1 for s in self.planner_s_status if s == "warm")

    def to_json(self) -> dict:
        return {"kind": "fine",
                "e2e_per_second": {k: v.tolist()
                                   for k, v in self.e2e_per_second.items()},
                "dropped": {k: float(v) for k, v in self.dropped.items()},
                "class_e2e": {k: v.tolist()
                              for k, v in self.class_e2e.items()},
                "planner_s_solves": [float(s) for s in self.planner_s_solves],
                "planner_s_status": list(self.planner_s_status)}

    @classmethod
    def from_json(cls, d: dict) -> "FineResult":
        return cls(e2e_per_second={k: np.asarray(v, float)
                                   for k, v in d["e2e_per_second"].items()},
                   dropped={k: float(v) for k, v in d["dropped"].items()},
                   class_e2e={k: np.asarray(v, float)
                              for k, v in d["class_e2e"].items()},
                   planner_s_solves=list(d.get("planner_s_solves", [])),
                   planner_s_status=list(d.get("planner_s_status", [])))


def simulate_slot_fine(table: LookupTable, sites: list[SiteSpec],
                       base_plan: Plan, power_w_slot: np.ndarray,
                       arrivals_rps: np.ndarray, *, seconds: int = 900,
                       planner_s_period: float = 5.0,
                       power_noise: float = 0.04,
                       power_scale: float = 1.0,
                       variants=("L", "L+S", "L+S+pack"),
                       seed: int = 0, warm_start: bool = True,
                       scenario: Optional[ScenarioEngine] = None) -> FineResult:
    """Second-level simulation of one 15-min slot.

    Power per second follows an AR(1) wiggle (±power_noise) around
    ``power_scale`` × the slot value; arrivals are Poisson per second.
    Variants: 'L' follows Planner-L blindly; 'L+S' re-solves (f, l) every
    ``planner_s_period`` s at observed load/power; '+pack' adds the
    Request Scheduler packing heuristic.

    ``scenario`` injects second-granularity disturbances through the
    same engine the week simulator uses (tick = 1 s here): grid trips /
    curtailment scale per-second power, demand surges scale the Poisson
    intensities, and a ``PowerWiggle`` event overrides the AR(1)
    parameters. The default (no scenario) path is bit-identical to the
    historical hardcoded AR(1)-only disturbance model.

    The Planner-L GPU grant is pulled once as a columnar ``GpuBudget``
    and each Planner-S re-solve is warm-started from the previous one
    (``warm_start=False`` restores cold solves — the knob
    benchmarks/bench_planning.py measures).
    """
    rng = np.random.default_rng(seed)
    S = len(sites)
    gpu_budget = base_plan.gpu_budget_pool()
    period = max(float(planner_s_period), 1.0)
    # per-second power: AR(1) multiplicative wiggle (vectorized)
    wig_ev = scenario.fine_wiggle() if scenario is not None else None
    if wig_ev is not None:
        wig = ar1_wiggle(rng, S, seconds, wig_ev.noise, wig_ev.phi)
    else:
        wig = ar1_wiggle(rng, S, seconds, power_noise)
    pw = power_w_slot[:, None] * power_scale * np.exp(wig)
    lam = np.maximum(arrivals_rps, 0)[:, None]
    if scenario is not None:
        sc = scenario.compile(S, seconds)
        if not sc.is_trivial:       # trivial scenario keeps the exact
            pw = pw * sc.power_factor   # historical arrays (bit-compat)
            lam = lam * sc.arrival_factor
    arr = rng.poisson(lam, size=(9, seconds)).astype(float)

    results_e2e = {}
    results_drop = {}
    results_cls = {}
    solves = []
    statuses = []
    for variant in variants:
        packing = variant.endswith("pack")
        use_s = variant != "L"
        dispatcher = RequestScheduler(S, packing=packing)
        backlog = np.zeros(9)
        e2e_series = np.zeros(seconds)
        cls_num = np.zeros(9)
        cls_den = np.zeros(9)
        dropped_total = 0.0
        plan = base_plan
        prev_s: Optional[Plan] = None
        t = 0
        while t < seconds:
            if use_s:
                obs_load = arr[:, max(0, t - 5): t + 1].mean(axis=1)
                # plan for a small headroom over observed load
                p = plan_s(table, sites, pw[:, t], obs_load * 1.1,
                           gpu_budget, objective=base_plan.objective,
                           warm=prev_s if warm_start else None)
                if p.status != "empty":
                    plan = p
                    prev_s = p
                    solves.append(p.solve_seconds)
                    statuses.append(p.status)
                # next re-solve at the next multiple of the period
                next_solve = (np.floor(t / period) + 1) * period
                t_end = min(seconds, int(np.ceil(next_solve)))
            else:
                t_end = seconds
            # ---- segment [t, t_end): the plan (and shed geometry) is
            # constant, so brown out the whole segment in one shot ----
            seg_counts = shed_counts_batch(plan, pw[:, t:t_end])
            gtable = GroupTable.from_plan(plan, active_only=False)
            for tt in range(t, t_end):
                tbl = gtable.with_counts(seg_counts[:, tt - t])
                demand = arr[:, tt] + backlog
                res = dispatcher.dispatch(tbl, demand)
                cap = np.bincount(tbl.cls, weights=tbl.capacity, minlength=9)
                # fluid backlog: what was neither served nor dropped waits
                backlog = np.maximum(demand - res.served - res.dropped, 0.0)
                # cap the queue at 2x/s of capacity; beyond that it drops
                overflow = np.maximum(backlog - 2.0 * cap, 0.0)
                backlog -= overflow
                drop = res.dropped + overflow
                dropped_total += float(drop.sum())
                wait = np.where(cap > 0, backlog / np.maximum(cap, 1e-9), 0.0)
                e2e_c = res.mean_e2e + wait
                m = res.served > 0
                e2e_series[tt] = (float((e2e_c[m] * res.served[m]).sum()
                                        / res.served[m].sum()) if m.any() else 0.0)
                cls_num += e2e_c * res.served
                cls_den += res.served
            t = t_end
        results_e2e[variant] = e2e_series
        results_drop[variant] = dropped_total
        results_cls[variant] = cls_num / np.maximum(cls_den, 1e-9)
    return FineResult(e2e_per_second=results_e2e, dropped=results_drop,
                      class_e2e=results_cls, planner_s_solves=solves,
                      planner_s_status=statuses)
