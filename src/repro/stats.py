"""Shared order-statistics helpers.

One percentile implementation for every layer that reports latency tails
(``serving.engine`` summaries, ``sim.cluster`` chaos scorecards, the
workload generator's class edges), with explicit empty-input semantics.

The historical copies (``_pct`` in serving/engine.py, ``_pctl`` in
sim/cluster.py) silently reported ``0.0`` for an empty sample — so a
site that served *nothing* during a grid trip looked like it had a
perfect p99 TTFT and dragged aggregate tails toward zero. The shared
helper returns NaN for an empty sample by default (callers that need a
sentinel pass ``empty=``), and every caller shares numpy's default
linear interpolation between order statistics.
"""
from __future__ import annotations

import math
from typing import Iterable, Union

import numpy as np


def percentile(xs: Union[Iterable, np.ndarray], q: float, *,
               empty: float = math.nan) -> float:
    """q-th percentile of ``xs`` (linear interpolation), ``empty`` when
    the sample has no elements. NaN — the default — propagates honestly
    through aggregation instead of under-reporting the tail as 0."""
    arr = np.asarray(list(xs) if not isinstance(xs, np.ndarray) else xs,
                     dtype=float)
    if arr.size == 0:
        return float(empty)
    return float(np.percentile(arr, q))


def percentiles(xs, qs: Iterable[float], *,
                empty: float = math.nan) -> list[float]:
    """Several percentiles of one sample (single sort)."""
    arr = np.asarray(list(xs) if not isinstance(xs, np.ndarray) else xs,
                     dtype=float)
    if arr.size == 0:
        return [float(empty) for _ in qs]
    return [float(v) for v in np.percentile(arr, list(qs))]


def finite_or(x: float, fallback: float = 0.0) -> float:
    """Map NaN/inf to ``fallback`` — for JSON consumers that cannot carry
    NaN (strict parsers); keeps the NaN-propagation inside the library
    honest while records stay loadable everywhere."""
    return float(x) if math.isfinite(x) else float(fallback)
