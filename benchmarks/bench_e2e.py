"""Million-user week co-sim — SLO-attributed served-token goodput (ISSUE 8).

The rate-plane benches (goodput/scenarios) score dispatched rps x slots;
this one closes the loop: the streamed Azure-shaped request population
(``data.workload.stream_requests``) drives live per-site
``ServingEngine``s through ``sim.e2e.simulate_fleet_serving``, with the
fleet plan (power truth plane -> per-site token budgets + brownout)
admitted by the routing policy under scenario disturbances.

A/B per scenario family (site failure, grid trip): **Heron**
(``HeronRouter`` — health-aware replanning, straggler EWMA,
WRR-weight-ranked failover) vs **WRR-DynamoLLM** (power/health-agnostic
baseline, index-order failover). Reported: SLO-attributed served-token
goodput fraction, raw served fraction, user-visible p99 TTFT/TBT tails,
duplicated tokens (MUST be 0), and the rate-plane ``simulate_week``
dispatched fraction over the same scenario family — the upper bound the
served-token number must sit below.

Writes ``BENCH_e2e.json`` at the repo root under the
``--update-tracker`` discipline (artifacts/bench/e2e.json always).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, row, save_tracker

SEED = 0
ARCH = "llama3.2-1b"            # smoke-sized GQA family
NUM_SITES = 4
NUM_USERS = 150_000             # ~1.7 fleet rps at 1 req/user/day
PLAN_LOAD_SCALE = 30.0          # stream rps -> table-calibrated regime
POWER_COL = 200


def _scenarios(ticks: int):
    """Tick-granularity scenarios for the live engines and their
    slot-granularity analogs for the rate-plane upper bound."""
    from repro.sim.scenarios import GridTrip, ScenarioEngine, SiteFailure
    q = ticks // 3
    return {
        # fail the mid-size site (~1/3 fleet power): the survivors can
        # absorb it, so the A/B measures routing/failover quality, not
        # raw capacity loss (killing the windiest site saturates every
        # policy equally)
        "site_failure": (
            ScenarioEngine([SiteFailure(site=1, start=q, duration=q)],
                           seed=SEED),
            lambda slots: ScenarioEngine(
                [SiteFailure(site=1, start=slots // 3,
                             duration=slots // 3)], seed=SEED)),
        # partial depth: the site stays alive but sheds 70% power —
        # exercises the brownout/admission path, while site_failure
        # above exercises the kill/failover path (a depth-1.0 trip
        # would compile to the same truth-plane kill schedule)
        "grid_trip": (
            ScenarioEngine([GridTrip(site=0, start=q, duration=q,
                                     depth=0.7, detect_ticks=2)], seed=SEED),
            lambda slots: ScenarioEngine(
                [GridTrip(site=0, start=slots // 3, duration=slots // 3,
                          depth=0.7, detect_ticks=1)], seed=SEED)),
    }


def _dispatched_fraction(policy_name: str, g, scenario, slots: int) -> float:
    """Rate-plane goodput fraction (served / offered rps) over the same
    scenario family — the upper bound on served-token goodput."""
    from repro.sim.cluster import simulate_week
    wk = simulate_week(policy_name, g.table, g.sites[:NUM_SITES],
                       g.power_mw[:NUM_SITES, POWER_COL:POWER_COL + slots],
                       g.arrivals_rps[:, POWER_COL:POWER_COL + slots],
                       scenario=scenario, time_limit=10)
    served = sum(s.total_served for s in wk.slots)
    offered = served + sum(s.total_dropped for s in wk.slots)
    return served / max(offered, 1e-9)


def run(fast: bool = True):
    import jax

    from repro.configs import smoke_config
    from repro.core.router import HeronRouter
    from repro.data.workload import make_trace
    from repro.models.api import build
    from repro.serving.engine import ServingEngine
    from repro.sim.e2e import simulate_fleet_serving
    from repro.sim.policy import make_policy
    from repro.sim.testbed import paper_grid

    rows = []
    t = Timer()
    ticks = 120 if fast else 360
    slots = 9 if fast else 18
    if common.SMOKE:
        ticks, slots = 24, 3

    g = paper_grid("coding", multiplier=60.0)
    traces = [make_trace("coding"), make_trace("conversation")]
    cfg = smoke_config(ARCH)
    model = build(cfg)
    params = model.init_params(jax.random.key(0))

    # Right-size each site's serving capacity to its power share (the
    # paper's modular DCs provision GPUs to the wind resource): decode
    # slots ~ mean generation around the benched columns. A uniform
    # fleet would make power-agnostic even spreading accidentally
    # optimal and the plan's concentration on windy sites look like a
    # routing bug.
    pshare = g.power_mw[:NUM_SITES, POWER_COL:POWER_COL + 12].mean(axis=1)
    pshare = pshare / pshare.sum()
    batches = np.maximum(2, np.round(16 * pshare)).astype(int)

    def make_engine(site, clock):
        return ServingEngine(model, params, max_batch=int(batches[site]),
                             max_seq=64, seed=site, clock=clock)

    def policies():
        return {
            "heron": HeronRouter(table=g.table, sites=g.sites[:NUM_SITES],
                                 time_limit_l=20),
            "wrr_dynamollm": make_policy("wrr_dynamollm", g.table,
                                         g.sites[:NUM_SITES], time_limit=10),
        }

    payload = {"arch": ARCH, "num_sites": NUM_SITES, "ticks": ticks,
               "num_users": NUM_USERS, "seed": SEED, "scenarios": {}}
    with t():
        for name, (tick_sc, slot_sc) in _scenarios(ticks).items():
            res = {}
            for pname, policy in policies().items():
                r = simulate_fleet_serving(
                    policy, g.table, g.sites[:NUM_SITES],
                    g.power_mw[:NUM_SITES], make_engine, traces=traces,
                    num_users=NUM_USERS, ticks=ticks,
                    plan_load_scale=PLAN_LOAD_SCALE,
                    scenario=tick_sc, seed=SEED, power_col=POWER_COL,
                    name=f"{name}_{pname}")
                d = r.to_json()
                d["dispatched_fraction"] = _dispatched_fraction(
                    pname, g, slot_sc(slots), slots)
                res[pname] = d
            res["slo_goodput_ratio"] = (
                res["heron"]["slo_goodput_fraction"]
                / max(res["wrr_dynamollm"]["slo_goodput_fraction"], 1e-9))
            payload["scenarios"][name] = res
    us_total = t.us
    for name, res in payload["scenarios"].items():
        h, b = res["heron"], res["wrr_dynamollm"]
        rows.append(row(
            f"e2e_{name}", us_total / (2 * len(payload["scenarios"])),
            f"slo-goodput {h['slo_goodput_fraction']:.3f} vs wrr "
            f"{b['slo_goodput_fraction']:.3f} "
            f"(x{res['slo_goodput_ratio']:.2f}), dup {h['duplicated_tokens']}"
            f", p99 ttft {h['p99_ttft']:.0f} vs {b['p99_ttft']:.0f} ticks, "
            f"dispatched<= {h['dispatched_fraction']:.3f}"))
    save_tracker("e2e", payload)
    return rows


def main():
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    common.emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
