"""Fig 14 right — Planner-L / Planner-S / packing execution time vs #sites.

Extended beyond the paper's 64 sites: the columnar dispatch fast path
makes 256-1024-site fleets routine, so the dispatch column is measured
at those counts on synthetic plans (no ILP solve needed — planning cost
is reported separately at the ILP-tractable counts).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, row, save
from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec, plan_l
from repro.core.planner_s import plan_s
from repro.core.scheduler import RequestScheduler
from repro.data.wind import make_site_population
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW

GRID = dict(load_grid=(0.25, 1.0, 4.0, 16.0), freq_grid=(1.4, 2.0))


def run(fast: bool = True):
    rows = []
    trace = make_trace("coding", base_rps=1.0, seed=11)
    table = build_table(PAPER_MODEL, trace, H100_DGX, **GRID)
    counts = (4, 8) if common.SMOKE else ((4, 8, 16) if fast
                                            else (4, 8, 16, 32, 64))
    pop = make_site_population(max(counts), seed=13)

    results = {}
    for n in counts:
        sites, power = [], []
        for s in pop[:n]:
            pods = max(1, int(np.percentile(s.long_term_mw, 20.0)
                              // SUPERPOD_PEAK_MW))
            sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
            power.append(min(s.series_mw[100],
                             np.percentile(s.long_term_mw, 20.0)) * 1e6)
        power = np.array(power)
        # demand scaled to the fleet (~30% of GPU capacity at ~0.1 rps/GPU)
        total_gpus = sum(s.num_gpus for s in sites)
        load = np.full(9, total_gpus * 0.1 * 0.3 / 9)
        t0 = time.perf_counter()
        pl = plan_l(table, sites, power, load, objective="latency",
                    time_limit=300)
        t_l = time.perf_counter() - t0
        t0 = time.perf_counter()
        ps = plan_s(table, sites, power, load, pl.gpu_budget())
        t_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        disp = RequestScheduler(n, packing=True)
        disp.dispatch(pl.group_table(), load)
        t_p = time.perf_counter() - t0
        results[n] = {"planner_l_s": t_l, "planner_s_s": t_s,
                      "packing_s": t_p, "columns": len(pl.columns),
                      "status": pl.status}

    n_hi = max(counts)
    r = results[n_hi]
    rows.append(row("fig14r_scalability", r["planner_l_s"] * 1e6,
                    f"{n_hi} sites: L {r['planner_l_s']:.1f}s / "
                    f"S {r['planner_s_s']:.2f}s / pack {r['packing_s']*1e3:.0f}ms"
                    " (paper: L ≤ 6 min @64, S ~30x faster)"))
    speedup = r["planner_l_s"] / max(r["planner_s_s"], 1e-9)
    rows.append(row("fig14r_planner_s_speedup", 0.0,
                    f"Planner-S {speedup:.0f}x faster than Planner-L"))

    # ---- fleet-scale dispatch: 256+ sites on the columnar fast path ----
    from benchmarks.bench_dispatch import synthetic_plan
    rng = np.random.default_rng(21)
    disp_counts = (64, 256) if fast else (64, 256, 1024)
    disp_res = {}
    for n in disp_counts:
        plan = synthetic_plan(table, rng, n)
        sched = RequestScheduler(n, packing=True)
        gtable = plan.group_table()
        # hot arrivals (some classes past capacity) so the packing
        # waterfall — not just the WRR pass — is on the timed path
        arr = plan.capacity() * rng.uniform(0.2, 1.4, size=9)
        sched.dispatch(gtable, arr)                     # warm
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            sched.dispatch(gtable, arr)
        us = (time.perf_counter() - t0) / reps * 1e6
        disp_res[n] = {"dispatch_us": us, "groups": len(gtable)}
        rows.append(row(f"fleet_dispatch_{n}sites", us,
                        f"{len(gtable)} groups columnar dispatch"))
    results["dispatch"] = {str(k): v for k, v in disp_res.items()}

    save("scalability", {str(k): v for k, v in results.items()})
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
