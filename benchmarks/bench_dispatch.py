"""Dispatch fast-path benchmark: columnar engine vs loop reference.

Times ``RequestScheduler.dispatch`` (vectorized ``GroupTable`` path)
against ``dispatch_reference`` (the per-``InstanceGroup`` Python loop)
on randomized fleet-scale plans, verifies 1e-9 agreement on every run,
and refreshes the ``BENCH_dispatch.json`` perf tracker at the repo root
when ``--update-tracker`` is passed (artifacts/bench/dispatch.json is
written either way). Acceptance: >= 10x at 64 sites.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import row, save_tracker
from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import Plan
from repro.core.scheduler import GroupTable, RequestScheduler
from repro.data.workload import make_trace
from repro.power.model import H100_DGX

GRID = dict(load_grid=(0.25, 1.0, 4.0, 16.0), freq_grid=(1.4, 2.0))


def synthetic_plan(table, rng, num_sites: int, cols_per_site: int = 6) -> Plan:
    """Fleet-scale plan without an ILP solve: random rows per site with
    random counts — the dispatch workload, not the planning workload."""
    all_rows = table.rows
    columns, counts = [], []
    for s in range(num_sites):
        for _ in range(cols_per_site):
            columns.append((s, all_rows[int(rng.integers(0, len(all_rows)))]))
            counts.append(int(rng.integers(1, 6)))
    return Plan(columns=columns, counts=np.array(counts, int),
                unserved=np.zeros(9), objective="latency", status="synthetic",
                solve_seconds=0.0, num_sites=num_sites)


def _check_match(got, want, context: str) -> float:
    worst = 0.0
    for f in ("served", "dropped", "mean_e2e", "packed", "per_site_load"):
        a, b = getattr(got, f), getattr(want, f)
        err = float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1.0)))
        if err > 1e-9:
            raise AssertionError(f"{context}: field {f} mismatch ({err:.2e})")
        worst = max(worst, err)
    return worst


def bench_sites(table, num_sites: int, reps: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    plan = synthetic_plan(table, rng, num_sites)
    sched = RequestScheduler(num_sites, packing=True)
    groups = sched.groups_from_plan(plan)
    gtable = plan.group_table()
    # hot arrivals: ~40% above fleet capacity to exercise packing + drops
    cap = plan.capacity()
    arrivals = [cap * rng.uniform(0.2, 1.4, size=9) for _ in range(reps)]

    worst = 0.0
    t0 = time.perf_counter()
    ref = [sched.dispatch_reference(groups, a) for a in arrivals]
    t_ref = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    vec = [sched.dispatch(gtable, a) for a in arrivals]
    t_vec = (time.perf_counter() - t0) / reps
    for i, (g, w) in enumerate(zip(vec, ref)):
        worst = max(worst, _check_match(g, w, f"{num_sites} sites rep {i}"))
    return {"sites": num_sites, "groups": len(gtable), "reps": reps,
            "ref_us": t_ref * 1e6, "vec_us": t_vec * 1e6,
            "speedup": t_ref / max(t_vec, 1e-12), "max_rel_err": worst}


def run(fast: bool = True):
    trace = make_trace("coding", base_rps=1.0, seed=11)
    table = build_table(PAPER_MODEL, trace, H100_DGX, **GRID)
    counts = (16, 64, 256) if fast else (16, 64, 256, 1024)
    reps = 30 if fast else 50
    if common.SMOKE:
        counts, reps = (16, 64), 3
    results = {str(n): bench_sites(table, n, reps) for n in counts}

    save_tracker("dispatch", results)

    rows = []
    for n, r in results.items():
        rows.append(row(f"dispatch_vec_{n}sites", r["vec_us"],
                        f"{r['groups']} groups: ref {r['ref_us']:.0f}us -> "
                        f"vec {r['vec_us']:.0f}us ({r['speedup']:.1f}x, "
                        f"err {r['max_rel_err']:.1e})"))
    s64 = results["64"]["speedup"]
    rows.append(row("dispatch_speedup_64sites", 0.0,
                    f"{s64:.1f}x vectorized over loop reference "
                    f"(target >= 10x)"))
    return rows


def main():
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    common.emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
