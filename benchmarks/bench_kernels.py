"""Kernel micro-bench: Pallas (interpret) vs oracle correctness + XLA-path
wall clock. CPU wall-times are NOT TPU predictions — the roofline bench is
the perf story; this bench pins correctness deltas and the XLA fallback
cost of each kernel's shape regime.

Paged-vs-dense decode sweep: at overprovisioning ratio R = max_seq /
mean-live-length, dense decode streams the whole max_seq cache row while
paged decode streams only the live pages (block table sliced to the
pow-2 cover, as the serving engine does). The sweep times the XLA paths
at R in {1, 2, 4, 8} next to the roofline-projected byte ratio
(``analysis.roofline.paged_decode_memory_s``) — the committed
``BENCH_kernels.json`` pins that paged wins from R >= 4.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import Timer, row, save_tracker
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = True):
    rows = []
    key = jax.random.key(0)
    ks = jax.random.split(key, 8)

    # flash attention (prefill regime)
    B, S, H, KVH, hd = 1, (256 if common.SMOKE else 1024), 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)
    us_ref = _time(lambda *a: ref.attention_ref(*a, causal=True), q, k, v)
    out_p = ops.flash_attention(q, k, v, causal=True)
    err = float(jnp.abs(out_p - ref.attention_ref(q, k, v, causal=True)).max())
    rows.append(row("kernel_flash_attention", us_ref,
                    f"S={S} GQA4 max|err|={err:.1e} vs oracle"))

    # decode attention (ragged cache)
    S = 512 if common.SMOKE else 4096
    q1 = jax.random.normal(ks[3], (4, H, hd), jnp.float32)
    kc = jax.random.normal(ks[4], (4, S, KVH, hd), jnp.float32)
    vc = jax.random.normal(ks[5], (4, S, KVH, hd), jnp.float32)
    lens = jnp.array([S, S // 2, 100, 1], jnp.int32)
    us_ref = _time(ref.decode_attention_ref, q1, kc, vc, lens)
    err = float(jnp.abs(ops.decode_attention(q1, kc, vc, lens)
                        - ref.decode_attention_ref(q1, kc, vc, lens)).max())
    rows.append(row("kernel_decode_attention", us_ref,
                    f"S={S} ragged max|err|={err:.1e} vs oracle"))

    # grouped matmul (MoE regime)
    E, C, D, F = 8, 256, 256, 512
    xe = jax.random.normal(ks[6], (E, C, D), jnp.bfloat16)
    w = jax.random.normal(ks[7], (E, D, F), jnp.bfloat16)
    fill = jnp.array([C, C // 2, 0, C, 10, C, C // 4, C], jnp.int32)
    want = jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                      w.astype(jnp.float32))
    rw = jnp.arange(C)[None, :, None]
    want = jnp.where(rw < fill[:, None, None], want, 0)
    got = ops.expert_matmul(xe, w, fill)
    err = float(jnp.abs(got.astype(jnp.float32) - want).max())
    us_ref = _time(lambda a, b: jnp.einsum("ecd,edf->ecf", a, b), xe, w)
    rows.append(row("kernel_grouped_matmul", us_ref,
                    f"E={E} bf16 max|err|={err:.1e} vs fp32 oracle"))

    # wkv6 (rwkv6 recurrence)
    B, S, Hh, hd = 1, 256, 4, 64
    kk = jax.random.split(jax.random.key(1), 6)
    r = jax.random.normal(kk[0], (B, S, Hh, hd)) * 0.5
    kx = jax.random.normal(kk[1], (B, S, Hh, hd)) * 0.5
    vx = jax.random.normal(kk[2], (B, S, Hh, hd)) * 0.5
    logw = jnp.clip(-jax.nn.softplus(jax.random.normal(kk[3], (B, S, Hh, hd))),
                    -1.5, -1e-6)
    u = jax.random.normal(kk[4], (Hh, hd)) * 0.3
    s0 = jnp.zeros((B, Hh, hd, hd))
    us_ref = _time(lambda *a: ref.wkv6_ref(*a)[0], r, kx, vx, logw, u, s0)
    o_p, _ = ops.wkv6(r, kx, vx, logw, u, s0)
    o_r, _ = ref.wkv6_ref(r, kx, vx, logw, u, s0)
    err = float(jnp.abs(o_p - o_r).max())
    rows.append(row("kernel_wkv6", us_ref,
                    f"S={S} chunked max|err|={err:.1e} vs token-serial oracle"))

    # paged vs dense decode sweep (XLA paths — the apples-to-apples CPU
    # measurement; interpret-mode Pallas timing is not meaningful)
    sweep = _paged_sweep(fast=fast)
    for R, cell in sorted(sweep.items()):
        rows.append(row(f"kernel_paged_decode_r{R}", cell["paged_us"],
                        (f"ratio={R}x dense={cell['dense_us']:.0f}us "
                         f"speedup={cell['speedup']:.2f}x "
                         f"roofline={cell['roofline_speedup']:.2f}x "
                         f"max|err|={cell['err']:.1e}")))

    payload = {r[0]: r[2] for r in rows}
    payload["paged_decode_sweep"] = {str(k): v for k, v in sorted(sweep.items())}
    save_tracker("kernels", payload)
    return rows


def _paged_sweep(fast: bool = True) -> dict:
    """Time dense vs paged decode at overprovisioning ratios R = S/mean_len.

    The paged call slices the block table to the pow-2 page cover of the
    live length (exactly what ServingEngine._decode_width does), so the
    gathered view — and the bytes streamed — shrink with the live length
    while dense always walks the full max_seq row.
    """
    from repro.analysis.roofline import paged_decode_memory_s
    from repro.configs import get_config

    B, S, page, KVH, H, hd = 4, (512 if common.SMOKE else
                                  (2048 if fast else 4096)), 16, 2, 8, 64
    maxP = S // page
    P = B * maxP
    cfg = get_config("llama3.2-1b")
    rng = np.random.default_rng(0)
    kd = rng.standard_normal((B, S, KVH, hd)).astype(np.float32)
    vd = rng.standard_normal((B, S, KVH, hd)).astype(np.float32)
    # identity-permutation page layout: slot b owns pages [b*maxP, (b+1)*maxP)
    table = np.arange(P, dtype=np.int32).reshape(B, maxP)
    k_pool = jnp.asarray(kd.reshape(P, page, KVH, hd))
    v_pool = jnp.asarray(vd.reshape(P, page, KVH, hd))
    kd, vd = jnp.asarray(kd), jnp.asarray(vd)
    q = jnp.asarray(rng.standard_normal((B, H, hd)).astype(np.float32))

    out = {}
    for R in (1, 2, 4, 8):
        mean_len = S // R
        lens = jnp.full((B,), mean_len, jnp.int32)
        pw = maxP // R                       # pow-2 page cover of mean_len
        tab = jnp.asarray(table[:, :pw])
        dense_us = _time(
            lambda a, b, c, d: ops.decode_attention(a, b, c, d,
                                                    use_pallas=False),
            q, kd, vd, lens)
        paged_us = _time(
            lambda a, b, c, d, e: ops.paged_decode_attention(
                a, b, c, d, e, use_pallas=False),
            q, k_pool, v_pool, tab, lens)
        err = float(jnp.abs(
            ops.paged_decode_attention(q, k_pool, v_pool, tab, lens,
                                       use_pallas=False)
            - ref.decode_attention_ref(q, kd, vd, lens)).max())
        d_s, p_s = paged_decode_memory_s(cfg, mean_len, B, S, chips=1,
                                         model_axis=16)
        out[R] = {
            "mean_len": mean_len, "max_seq": S,
            "dense_us": dense_us, "paged_us": paged_us,
            "speedup": dense_us / paged_us,
            "roofline_speedup": d_s / p_s,
            "err": err,
        }
    return out


def main():
    import argparse

    from benchmarks import common
    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
