"""§Roofline — per-(arch × shape) roofline terms from the dry-run artifacts.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits
the three-term table: compute / memory / collective seconds per step,
dominant bottleneck, MODEL_FLOPS/HLO ratio, roofline fraction.
"""
from __future__ import annotations

import os

from benchmarks.common import Timer, row, save
from repro.analysis.roofline import load_table

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def run(fast: bool = True, pod: str = "pod1", tag: str = ""):
    rows = []
    t = Timer()
    if not os.path.isdir(DRYRUN_DIR):
        rows.append(row("roofline", 0.0,
                        "NO ARTIFACTS — run python -m repro.launch.dryrun --all first"))
        return rows
    with t():
        table = load_table(DRYRUN_DIR, pod=pod, tag=tag)
    if not table:
        rows.append(row("roofline", t.us, f"no {pod} artifacts found"))
        return rows

    by_dom = {}
    payload = []
    for terms in table:
        d = terms.as_dict()
        payload.append(d)
        by_dom.setdefault(terms.dominant, []).append(terms)
        rows.append(row(
            f"roofline_{terms.arch}_{terms.shape}", 0.0,
            f"{terms.dominant}-bound; step {terms.step_time_s*1e3:.2f}ms; "
            f"C/M/X = {terms.compute_s*1e3:.2f}/{terms.memory_s*1e3:.2f}/"
            f"{terms.collective_s*1e3:.2f} ms; "
            f"roofline {terms.roofline_fraction:.1%}; "
            f"useful {terms.useful_ratio:.2f}"))
    summary = ", ".join(f"{k}:{len(v)}" for k, v in sorted(by_dom.items()))
    rows.append(row("roofline_summary", t.us,
                    f"{len(table)} cells ({pod}); dominated by {summary}"))
    save(f"roofline_{pod}" + (f"_{tag}" if tag else ""), {"cells": payload})
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
