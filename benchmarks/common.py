"""Shared benchmark plumbing.

Every bench module exposes ``run(fast: bool) -> list[Row]`` where a Row is
``(name, us_per_call, derived)`` — the CSV contract of benchmarks.run —
and writes its raw numbers under artifacts/bench/<module>.json.

Tracker hygiene: the repo-root ``BENCH_<name>.json`` files are committed
perf trackers. Bench modules write them through ``save_tracker``, which
only touches the root file when ``--update-tracker`` was passed (to
``benchmarks.run`` or a module's own ``main``); a default run writes the
artifacts copy only, so benching one module can never dirty another
PR's tracker.
"""
from __future__ import annotations

import json
import os
import sys
import time
from contextlib import contextmanager

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACTS = os.path.join(REPO_ROOT, "artifacts", "bench")

UPDATE_TRACKER = False      # set by --update-tracker in run.py / module mains
# --smoke tier: every module clamps to toy sizes (seconds, not minutes)
# and committed root trackers are NEVER written — run.py forces
# UPDATE_TRACKER off when SMOKE is on, so a smoke pass can be used as a
# does-everything-still-run gate without perturbing perf baselines.
SMOKE = False


def save(name: str, payload: dict) -> None:
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def save_tracker(name: str, payload: dict) -> None:
    """Write artifacts/bench/<name>.json always; the committed root
    tracker ``BENCH_<name>.json`` only under ``--update-tracker``."""
    save(name, payload)
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    if UPDATE_TRACKER:
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=float)
    else:
        print(f"# {os.path.basename(path)} not updated "
              "(pass --update-tracker to refresh the committed tracker)",
              file=sys.stderr)


class Timer:
    def __init__(self):
        self.us = 0.0

    @contextmanager
    def __call__(self):
        t0 = time.perf_counter()
        yield
        self.us = (time.perf_counter() - t0) * 1e6


def row(name: str, us: float, derived: str) -> tuple:
    return (name, round(us, 1), derived)


def emit(rows) -> None:
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
