"""Shared benchmark plumbing.

Every bench module exposes ``run(fast: bool) -> list[Row]`` where a Row is
``(name, us_per_call, derived)`` — the CSV contract of benchmarks.run —
and writes its raw numbers under artifacts/bench/<module>.json.
"""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

ARTIFACTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "bench")


def save(name: str, payload: dict) -> None:
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


class Timer:
    def __init__(self):
        self.us = 0.0

    @contextmanager
    def __call__(self):
        t0 = time.perf_counter()
        yield
        self.us = (time.perf_counter() - t0) * 1e6


def row(name: str, us: float, derived: str) -> tuple:
    return (name, round(us, 1), derived)


def emit(rows) -> None:
    for name, us, derived in rows:
        print(f"{name},{us},{derived}")
