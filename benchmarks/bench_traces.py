"""Fig 12 — trace characteristics (lengths, arrivals) for both use cases."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, row, save
from repro.data.workload import make_trace


def run(fast: bool = True):
    rows = []
    t = Timer()
    with t():
        stats = {}
        for name in ("coding", "conversation"):
            tr = make_trace(name, base_rps=1.0, seed=11)
            stats[name] = {
                "in_median": float(np.median(tr.input_lens)),
                "in_p95": float(np.percentile(tr.input_lens, 95)),
                "in_max": int(tr.input_lens.max()),
                "out_median": float(np.median(tr.output_lens)),
                "out_p95": float(np.percentile(tr.output_lens, 95)),
                "out_max": int(tr.output_lens.max()),
                "arrivals_per_slot_mean": float(tr.arrivals.mean()),
                "arrivals_day_night_ratio": float(
                    np.percentile(tr.arrivals, 90)
                    / max(np.percentile(tr.arrivals, 10), 1)),
                "class_mix": tr.class_mix().tolist(),
            }
    code, conv = stats["coding"], stats["conversation"]
    rows.append(row("fig12_inputs", t.us,
                    f"coding med {code['in_median']:.0f} ≈ "
                    f"{code['in_median']/conv['in_median']:.1f}x conversation"
                    " (paper ~2x)"))
    rows.append(row("fig12_outputs", 0.0,
                    f"conv p95 {conv['out_p95']:.0f} ≈ "
                    f"{conv['out_p95']/code['out_p95']:.1f}x coding "
                    "(paper ~6x)"))
    rows.append(row("fig12_arrivals", 0.0,
                    f"day/night {code['arrivals_day_night_ratio']:.1f}x "
                    "(strong diurnal)"))
    save("traces", stats)
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
