"""Fig 13 / §5.1 — the profiling exercise that fills the lookup tables."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, row, save
from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, TPU_V5E


def run(fast: bool = True):
    rows = []
    t = Timer()
    tables = {}
    with t():
        for name in ("coding", "conversation"):
            tr = make_trace(name, base_rps=1.0, seed=11)
            tables[name] = build_table(PAPER_MODEL, tr, H100_DGX)
    n_total = sum(len(tb) for tb in tables.values())
    rows.append(row("fig13_tables", t.us,
                    f"{n_total} SLO-valid rows over 2 traces "
                    "(paper ~2,000)"))

    # Fig 13's qualitative grid for the MM class
    tb = tables["conversation"]
    mm = tb.valid_rows(4)
    grid = {}
    for r in mm:
        grid[f"tp{r.tp}_f{r.freq}_l{r.load}"] = {
            "power_w": r.power, "e2e_s": r.e2e, "ttft_s": r.ttft}
    tp2_max = max((r.load for r in mm if r.tp == 2), default=0.0)
    tp8_max = max((r.load for r in mm if r.tp == 8), default=0.0)
    rows.append(row("fig13_mm_grid", 0.0,
                    f"MM: TP2 tops out at {tp2_max} rps vs TP8 {tp8_max} rps "
                    "(grey-cell pattern)"))

    # hardware-adapted TPU target table (DESIGN.md §3)
    with t():
        tr = make_trace("conversation", base_rps=1.0, seed=11)
        tpu_table = build_table(PAPER_MODEL, tr, TPU_V5E)
    rows.append(row("profiling_tpu_v5e", t.us,
                    f"{len(tpu_table)} rows on the TPU v5e profile "
                    f"(TP {TPU_V5E.tp_degrees})"))

    save("profiling", {
        "rows_per_trace": {k: len(v) for k, v in tables.items()},
        "mm_grid_conversation": grid,
        "tpu_rows": len(tpu_table),
    })
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
