"""Fig 16 — latency ↔ power trade-off of the two Planner-L objectives."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, row, save
from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec, plan_l
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW

GRID = dict(load_grid=(0.25, 1.0, 4.0, 16.0), freq_grid=(1.0, 1.4, 2.0))


def run(fast: bool = True, trace_name: str = "coding"):
    rows = []
    t = Timer()
    trace = make_trace(trace_name, base_rps=1.0, seed=11)
    table = build_table(PAPER_MODEL, trace, H100_DGX, **GRID)
    fleet = make_default_fleet(seed=7)
    sites, thr = [], []
    for s in fleet.sites:
        pods = int(s.percentile_mw(20.0) // SUPERPOD_PEAK_MW)
        sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
        thr.append(s.percentile_mw(20.0))
    power = np.minimum(fleet.week(), np.array(thr)[:, None])
    mult = 600.0
    arr = trace.class_arrivals(multiplier=mult) / (15 * 60)

    n_slots = 3 if common.SMOKE else (16 if fast else 96)
    pts = []
    with t():
        for i in range(n_slots):
            sl = 140 + i * 4
            pw = power[:, sl] * 1e6
            load = arr[:, sl]
            p_lat = plan_l(table, sites, pw, load, objective="latency",
                           time_limit=20)
            p_pow = plan_l(table, sites, pw, load, objective="power",
                           time_limit=20)
            if p_lat.unserved.sum() > 1e-6 or p_pow.unserved.sum() > 1e-6:
                continue
            e_lat, e_pow = p_lat.mean_e2e(load), p_pow.mean_e2e(load)
            w_lat, w_pow = p_lat.total_power(), p_pow.total_power()
            if e_pow > 0 and w_pow > 0:
                pts.append({"lat_gain_pct": 100 * (1 - e_lat / e_pow),
                            "power_cost_pct": 100 * (w_lat / w_pow - 1)})
    lat_gain = np.array([p["lat_gain_pct"] for p in pts])
    pow_cost = np.array([p["power_cost_pct"] for p in pts])
    rows.append(row(f"fig16_tradeoff_{trace_name}", t.us,
                    f"mean {lat_gain.mean():.0f}% lower E2E costs "
                    f"{pow_cost.mean():.0f}% more power over {len(pts)} slots"
                    " (paper: 25% ↔ 42%)"))
    save(f"tradeoff_{trace_name}", {"points": pts})
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
