"""Figs 8/14/15 — drops and goodput: Heron vs the two baselines.

The headline reproduction: power-variability-aware cross-site planning
(Planner-L) vs (c) WRR+DynamoLLM and (d) greedy-min-latency. Reported:
  * slots with at least one drop across workload volumes (Fig 14 left),
  * per-slot goodput improvement ratio distribution (Fig 14 mid / Fig 15).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, row, save
from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW
from repro.sim.cluster import goodput_improvement, simulate_week

GRID = dict(load_grid=(0.25, 1.0, 4.0, 16.0), freq_grid=(1.2, 2.0))
# volume multipliers relative to the paper's production-trace unit rate;
# calibrated so the upper entries stress the provisioned power like the
# paper's 60x coding / 50x conversation operating points do
VOLUMES = {"coding": (60.0, 600.0, 2400.0),
           "conversation": (50.0, 500.0, 2000.0)}


def _setup(trace_name: str):
    trace = make_trace(trace_name, base_rps=1.0, seed=11)
    table = build_table(PAPER_MODEL, trace, H100_DGX, **GRID)
    fleet = make_default_fleet(seed=7)
    sites, thr = [], []
    for s in fleet.sites:
        pods = int(s.percentile_mw(20.0) // SUPERPOD_PEAK_MW)
        sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
        thr.append(s.percentile_mw(20.0))
    power = np.minimum(fleet.week(), np.array(thr)[:, None])
    return trace, table, sites, power


def run(fast: bool = True, trace_name: str = None):
    if trace_name is None:          # driver entry: both paper traces
        return (run(fast, "coding") + run(fast, "conversation"))
    rows = []
    t = Timer()
    trace, table, sites, power = _setup(trace_name)
    # fast mode: the 24 h window around the week's deep drought (UK ~0,
    # Iceland ~4% of threshold near slot 500-560 — the Fig 8 scenario)
    sl = slice(500, 500 + 96) if fast else slice(0, power.shape[1])
    power_w = power[:, sl]

    # Fig 14 left: drop slots across volumes
    drop_slots = {}
    with t():
        for mult in VOLUMES[trace_name]:
            arr = trace.class_arrivals(multiplier=mult)[:, sl] / (15 * 60)
            res = {}
            for sched in ("heron", "wrr_dynamollm", "greedy_min_latency"):
                wk = simulate_week(sched, table, sites, power_w, arr)
                res[sched] = wk.slots_with_drops()
            drop_slots[mult] = res
    hi = max(VOLUMES[trace_name])
    rows.append(row(f"fig14l_drops_{trace_name}", t.us,
                    f"@{hi:.0f}x: heron {drop_slots[hi]['heron']} dropslots "
                    f"vs dynamollm {drop_slots[hi]['wrr_dynamollm']} "
                    f"vs greedy {drop_slots[hi]['greedy_min_latency']}"))

    # Fig 14 middle / Fig 15: goodput ratio at the paper's operating volume
    mult = VOLUMES[trace_name][-1]
    with t():
        arr = trace.class_arrivals(multiplier=mult)[:, sl] / (15 * 60)
        heron = simulate_week("heron", table, sites, power_w, arr)
        base_c = simulate_week("wrr_dynamollm", table, sites, power_w, arr)
        base_d = simulate_week("greedy_min_latency", table, sites, power_w,
                               arr)
        ratio_c = goodput_improvement(heron, base_c)
        ratio_d = goodput_improvement(heron, base_d)
    rows.append(row(f"fig14m_goodput_{trace_name}", t.us,
                    f"vs dynamollm: p50 {np.percentile(ratio_c, 50):.2f}, "
                    f"p95 {np.percentile(ratio_c, 95):.2f}, "
                    f"max {ratio_c.max():.2f} (paper up to 1.8x)"))

    save(f"goodput_{trace_name}", {
        "volumes": {str(k): v for k, v in drop_slots.items()},
        "ratio_vs_dynamollm": {
            "p50": float(np.percentile(ratio_c, 50)),
            "p90": float(np.percentile(ratio_c, 90)),
            "p99": float(np.percentile(ratio_c, 99)),
            "max": float(ratio_c.max())},
        "ratio_vs_greedy": {
            "p50": float(np.percentile(ratio_d, 50)),
            "max": float(ratio_d.max())},
        "heron_goodput_total": float(heron.goodput().sum()),
        "dynamollm_goodput_total": float(base_c.goodput().sum()),
        "slots": int(power_w.shape[1]),
    })
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))
    emit(run(fast=True, trace_name="conversation"))


if __name__ == "__main__":
    main()
