"""Figs 8/14/15 — drops and goodput: Heron vs the two baselines.

The headline reproduction: power-variability-aware cross-site planning
(Planner-L) vs (c) WRR+DynamoLLM and (d) greedy-min-latency. Reported:
  * slots with at least one drop across workload volumes (Fig 14 left),
  * per-slot goodput improvement ratio distribution (Fig 14 mid / Fig 15).

The volume sweep records every top-volume run under artifacts/sim/
(``simulate_week(record=...)``); the ratio section *reloads* those
records instead of re-simulating the same three weeks — the sweep and
the ratio stay consistent by construction and the module runs ~25%
faster.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks import common
from benchmarks.common import REPO_ROOT, Timer, row, save
from repro.sim.cluster import (goodput_improvement, load_week_result,
                               simulate_week)
from repro.sim.testbed import paper_grid

SIM_DIR = os.path.join(REPO_ROOT, "artifacts", "sim")

# volume multipliers relative to the paper's production-trace unit rate;
# calibrated so the upper entries stress the provisioned power like the
# paper's 60x coding / 50x conversation operating points do
VOLUMES = {"coding": (60.0, 600.0, 2400.0),
           "conversation": (50.0, 500.0, 2000.0)}


def _setup(trace_name: str):
    g = paper_grid(trace_name)
    return g.trace, g.table, g.sites, g.power_mw


def run(fast: bool = True, trace_name: str = None):
    if trace_name is None:          # driver entry: both paper traces
        return (run(fast, "coding") + run(fast, "conversation"))
    rows = []
    t = Timer()
    trace, table, sites, power = _setup(trace_name)
    # fast mode: the 24 h window around the week's deep drought (UK ~0,
    # Iceland ~4% of threshold near slot 500-560 — the Fig 8 scenario)
    sl = slice(500, 500 + 96) if fast else slice(0, power.shape[1])
    if common.SMOKE:
        sl = slice(500, 500 + 12)
    power_w = power[:, sl]

    # Fig 14 left: drop slots across volumes (top-volume runs recorded
    # under artifacts/sim/ and reloaded by the ratio section below)
    drop_slots = {}
    hi = max(VOLUMES[trace_name])
    rec_path = {s: os.path.join(SIM_DIR, f"goodput_{trace_name}_{s}.json")
                for s in ("heron", "wrr_dynamollm", "greedy_min_latency")}
    with t():
        for mult in VOLUMES[trace_name]:
            arr = trace.class_arrivals(multiplier=mult)[:, sl] / (15 * 60)
            res = {}
            for sched in ("heron", "wrr_dynamollm", "greedy_min_latency"):
                wk = simulate_week(sched, table, sites, power_w, arr,
                                   record=rec_path[sched] if mult == hi
                                   else None)
                res[sched] = wk.slots_with_drops()
            drop_slots[mult] = res
    rows.append(row(f"fig14l_drops_{trace_name}", t.us,
                    f"@{hi:.0f}x: heron {drop_slots[hi]['heron']} dropslots "
                    f"vs dynamollm {drop_slots[hi]['wrr_dynamollm']} "
                    f"vs greedy {drop_slots[hi]['greedy_min_latency']}"))

    # Fig 14 middle / Fig 15: goodput ratio at the paper's operating
    # volume — reloaded from the sweep's run records, not re-simulated
    with t():
        heron = load_week_result(rec_path["heron"])
        base_c = load_week_result(rec_path["wrr_dynamollm"])
        base_d = load_week_result(rec_path["greedy_min_latency"])
        ratio_c = goodput_improvement(heron, base_c)
        ratio_d = goodput_improvement(heron, base_d)
    rows.append(row(f"fig14m_goodput_{trace_name}", t.us,
                    f"vs dynamollm: p50 {np.percentile(ratio_c, 50):.2f}, "
                    f"p95 {np.percentile(ratio_c, 95):.2f}, "
                    f"max {ratio_c.max():.2f} (paper up to 1.8x)"))

    save(f"goodput_{trace_name}", {
        "volumes": {str(k): v for k, v in drop_slots.items()},
        "ratio_vs_dynamollm": {
            "p50": float(np.percentile(ratio_c, 50)),
            "p90": float(np.percentile(ratio_c, 90)),
            "p99": float(np.percentile(ratio_c, 99)),
            "max": float(ratio_c.max())},
        "ratio_vs_greedy": {
            "p50": float(np.percentile(ratio_d, 50)),
            "max": float(ratio_d.max())},
        "heron_goodput_total": float(heron.goodput().sum()),
        "dynamollm_goodput_total": float(base_c.goodput().sum()),
        "slots": int(power_w.shape[1]),
    })
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))
    emit(run(fast=True, trace_name="conversation"))


if __name__ == "__main__":
    main()
