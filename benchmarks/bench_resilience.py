"""Resilience — served-token goodput under site failures, with vs without
cross-site failover (ISSUE 6).

The week/scenario benches score *rate-level* brownout shedding; this one
scores the serving path itself: live ``ServingEngine``s (smoke-sized GQA
model) at every site, a seeded ``FaultInjector`` derived from the same
``ScenarioEngine`` definitions the week sim uses, and the
``ServingCluster`` failover layer carrying preempted transcripts to
surviving sites picked by a solved ``HeronRouter`` plan
(``failover_order``). Two scenarios — mid-slot site failure and a
full-depth grid trip — each run twice:

  * ``failover``  — drained transcripts resume on surviving sites
    (bit-identical continuations; recovered tokens are real);
  * ``blind``     — drained work is lost (the pre-lifecycle engine's
    behavior). New arrivals redirect in BOTH modes, so the delta is
    exactly the in-flight recovery path.

Reported per scenario: served-token goodput, recovered / lost /
duplicated tokens (duplicated MUST be 0), p99 TTFT/E2E, and the goodput
ratio failover/blind (> 1 is the tentpole's claim).

Writes ``BENCH_resilience.json`` at the repo root under the
``--update-tracker`` discipline (artifacts/bench/resilience.json always).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, row, save_tracker

SEED = 0
ARCH = "llama3.2-1b"            # smoke-sized GQA family


def _grid_policy(num_sites: int):
    """A HeronRouter with one solved plan over the paper grid, so
    ``failover_order`` ranks sites by real WRR weights (not index)."""
    from repro.core.router import HeronRouter
    from repro.sim.testbed import paper_grid
    g = paper_grid("coding", multiplier=60.0)
    router = HeronRouter(table=g.table, sites=g.sites[:num_sites])
    router.plan_slot(g.power_mw[:num_sites, 200] * 1e6,
                     g.arrivals_rps[:, 200])
    return router


def _workload(num_sites: int, n_requests: int, ticks: int, vocab: int):
    from repro.serving.engine import Request
    rng = np.random.default_rng(SEED)
    out = []
    span = max(ticks // 2, 1)
    for rid in range(n_requests):
        prompt = rng.integers(1, vocab, size=int(rng.integers(4, 9)))
        out.append((rid % span, rid % num_sites,
                    Request(rid=rid, prompt=prompt.astype(np.int32),
                            max_new_tokens=12,
                            temperature=0.8 if rid % 2 else 0.0)))
    return out


def _scenarios(num_sites: int, ticks: int) -> dict[str, object]:
    from repro.sim.scenarios import GridTrip, ScenarioEngine, SiteFailure
    q = max(ticks // 4, 1)
    return {
        # site 0 dies mid-run and comes back: the drained transcripts are
        # the recoverable work
        "site_failure_midslot": ScenarioEngine(
            [SiteFailure(site=0, start=q, duration=2 * q)], seed=SEED),
        "grid_trip": ScenarioEngine(
            [GridTrip(site=0, start=q, duration=2 * q, depth=1.0,
                      detect_ticks=1)], seed=SEED),
    }


def run(fast: bool = True):
    import jax

    from repro.configs import smoke_config
    from repro.models.api import build
    from repro.serving.engine import ServingEngine
    from repro.sim.cluster import simulate_serving_chaos
    from repro.sim.faults import FaultInjector

    rows = []
    t = Timer()
    num_sites = 3
    ticks = 24 if fast else 48
    n_requests = 12 if fast else 36
    if common.SMOKE:
        ticks, n_requests = 12, 6

    cfg = smoke_config(ARCH)
    model = build(cfg)
    params = model.init_params(jax.random.key(0))

    def make_engine(site, clock):
        return ServingEngine(model, params, max_batch=4, max_seq=64,
                             seed=site, clock=clock)

    policy = _grid_policy(num_sites)
    payload = {"arch": ARCH, "num_sites": num_sites, "ticks": ticks,
               "n_requests": n_requests, "seed": SEED, "scenarios": {}}
    with t():
        for name, engine in _scenarios(num_sites, ticks).items():
            sc = engine.compile(num_sites, ticks)
            inj = FaultInjector.from_scenario(sc, seed=SEED)
            res = {}
            for mode, failover in (("failover", True), ("blind", False)):
                r = simulate_serving_chaos(
                    num_sites, make_engine,
                    _workload(num_sites, n_requests, ticks, cfg.vocab_size),
                    inj, name=f"{name}_{mode}",
                    policy=policy if failover else None,
                    failover=failover, ticks=ticks)
                res[mode] = r.to_json()
            res["goodput_ratio"] = (
                res["failover"]["served_tokens"]
                / max(res["blind"]["served_tokens"], 1))
            payload["scenarios"][name] = res
    us_total = t.us

    for name, res in payload["scenarios"].items():
        f, b = res["failover"], res["blind"]
        rows.append(row(
            f"resilience_{name}", us_total / (2 * len(payload["scenarios"])),
            f"served {f['served_tokens']} vs blind {b['served_tokens']} "
            f"tok (x{res['goodput_ratio']:.2f}), recovered "
            f"{f['recovered_tokens']}, dup {f['duplicated_tokens']}, "
            f"p99 e2e {f['p99_e2e']:.1f}s vs {b['p99_e2e']:.1f}s"))
    save_tracker("resilience", payload)
    return rows


def main():
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    common.emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
