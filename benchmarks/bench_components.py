"""Fig 17 + §5.3 — Planner-S and packing incremental latency wins,
power elasticity under a −20% stress test."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, row, save
from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec, plan_l
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW
from repro.sim.cluster import simulate_slot_fine

GRID = dict(load_grid=(0.25, 1.0, 4.0, 16.0), freq_grid=(1.0, 1.4, 2.0))


def _setup(trace_name):
    trace = make_trace(trace_name, base_rps=1.0, seed=11)
    table = build_table(PAPER_MODEL, trace, H100_DGX, **GRID)
    fleet = make_default_fleet(seed=7)
    sites, thr = [], []
    for s in fleet.sites:
        pods = int(s.percentile_mw(20.0) // SUPERPOD_PEAK_MW)
        sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
        thr.append(s.percentile_mw(20.0))
    power = np.minimum(fleet.week(), np.array(thr)[:, None])
    arr = trace.class_arrivals(multiplier=600.0) / (15 * 60)
    return table, sites, power, arr


def run(fast: bool = True, trace_name: str = "coding"):
    rows = []
    t = Timer()
    table, sites, power, arr = _setup(trace_name)
    # a drought slot where power BINDS (Planner-L must downclock to fit its
    # safe-sided forecast) — the regime where Planner-S's upclock-on-actual
    # and the packing heuristic have headroom to win (Fig 17's setting)
    slot = 520
    seconds = 20 if common.SMOKE else (120 if fast else 900)

    with t():
        # Planner-L plans on the safe-sided 15-min power forecast (10%
        # haircut, §2.3 margin); Planner-S sees the ACTUAL second-level
        # power and upclocks into the surplus — the Fig 17 mechanism.
        plan = plan_l(table, sites, power[:, slot] * 1e6 * 0.9, arr[:, slot],
                      objective="latency", time_limit=30)
        res = simulate_slot_fine(table, sites, plan, power[:, slot] * 1e6,
                                 arr[:, slot], seconds=seconds,
                                 planner_s_period=5.0, seed=3)
    m = {k: float(np.mean(v[v > 0])) for k, v in res.e2e_per_second.items()}
    s_gain = 100 * (1 - m["L+S"] / m["L"]) if m["L"] else 0.0
    p_gain = 100 * (1 - m["L+S+pack"] / m["L+S"]) if m["L+S"] else 0.0
    rows.append(row(f"fig17_components_{trace_name}", t.us,
                    f"Planner-S {s_gain:.0f}% lower E2E, packing +"
                    f"{p_gain:.1f}% (paper 27% / +3% for coding)"))

    # §5.3 elasticity: −20% power
    with t():
        res20 = simulate_slot_fine(table, sites, plan, power[:, slot] * 1e6,
                                   arr[:, slot], seconds=min(seconds, 60),
                                   power_scale=0.8, seed=4)
    total = arr[:, slot].sum() * min(seconds, 60)
    frac_l = res20.dropped["L"] / max(total, 1e-9)
    frac_s = res20.dropped["L+S"] / max(total, 1e-9)
    rows.append(row(f"s53_elasticity_{trace_name}", t.us,
                    f"-20% power: blind-L drops {frac_l:.1%}, "
                    f"Planner-S drops {frac_s:.1%}"))

    save(f"components_{trace_name}", {
        "mean_e2e": m, "planner_s_gain_pct": s_gain,
        "packing_gain_pct": p_gain,
        "elasticity": {"dropped": res20.dropped, "total_arrivals": total},
        "planner_s_solve_s": (float(np.mean(res.planner_s_solves))
                              if res.planner_s_solves else None),
    })
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
