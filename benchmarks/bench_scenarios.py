"""Scenario families — goodput/drops/E2E under injected disturbances.

Drives the policy/scenario control plane (ISSUE 5): for each scenario
family (site failure, grid trip, curtailment, demand surge, straggler
onset, predictor-error regime) the same seeded ScenarioEngine week is
simulated under Heron and both power-agnostic baselines. Reported per
family: drops absorbed (baseline drops - Heron drops), goodput ratio,
and for the straggler family the E2E inflation each policy eats relative
to its own event-free run — Heron's site-health/straggler path is the
only one that reacts, which is the chart the paper's K1 story implies.

Runs on a healthy-power window (the wind week's own drought is benched
by bench_goodput) so the injected events are the dominant signal.

Writes ``BENCH_scenarios.json`` at the repo root under the
``--update-tracker`` discipline (artifacts/bench/scenarios.json always).
"""
from __future__ import annotations

from benchmarks import common
from benchmarks.common import Timer, row, save_tracker
from repro.sim.cluster import simulate_week
from repro.sim.scenarios import (Curtailment, DemandSurge, GridTrip,
                                 PredictorError, ScenarioEngine, SiteFailure,
                                 StragglerOnset)
from repro.sim.testbed import paper_grid

POLICIES = ("heron", "wrr_dynamollm", "greedy_min_latency")
START = 200                   # healthy-power window (events are the signal)
VOLUME = 240.0
SEED = 0


def _families(slots: int) -> dict[str, list]:
    """Event stacks scaled to the window; site 0 is the biggest site."""
    q = max(slots // 4, 1)
    return {
        "none": [],
        "site_failure": [SiteFailure(site=0, start=q, duration=2 * q)],
        "grid_trip": [GridTrip(site=0, start=q, duration=2, depth=1.0,
                               detect_ticks=1)],
        "curtailment": [Curtailment(frac=0.5, start=q, duration=2 * q)],
        "demand_surge": [DemandSurge(magnitude=2.0, start=q, duration=2 * q)],
        "straggler": [StragglerOnset(site=0, start=1, duration=slots,
                                     slowdown=6.0)],
        "predictor_error": [PredictorError(sigma=0.3)],
    }


def run(fast: bool = True):
    rows = []
    t = Timer()
    slots = 4 if common.SMOKE else (10 if fast else 24)
    g = paper_grid("coding", multiplier=VOLUME)
    table, sites = g.table, g.sites
    pw = g.power_mw[:, START:START + slots]
    ar = g.arrivals_rps[:, START:START + slots]

    results: dict[str, dict[str, dict]] = {}
    with t():
        for fam, events in _families(slots).items():
            sc = ScenarioEngine(events, seed=SEED)
            results[fam] = {}
            for pol in POLICIES:
                wk = simulate_week(pol, table, sites, pw, ar, scenario=sc,
                                   seed=SEED)
                results[fam][pol] = {
                    "goodput": float(wk.goodput().sum()),
                    "drops": float(wk.drops().sum()),
                    "drop_slots": int(wk.slots_with_drops()),
                    "mean_e2e": float(wk.mean_e2e().mean()),
                    "power_mw": float(wk.power().mean() / 1e6),
                }
    us_total = t.us

    payload = {"slots": slots, "start": START, "volume": VOLUME,
               "seed": SEED, "families": {}}
    for fam, by_pol in results.items():
        h = by_pol["heron"]
        fam_out = {"policies": by_pol}
        if fam != "none":
            for base in ("wrr_dynamollm", "greedy_min_latency"):
                b = by_pol[base]
                fam_out[f"absorbed_vs_{base}"] = b["drops"] - h["drops"]
                fam_out[f"goodput_ratio_vs_{base}"] = (
                    h["goodput"] / max(b["goodput"], 1e-9))
            # E2E inflation vs each policy's own event-free run — the
            # straggler haircut shows up here (Heron inflates least)
            fam_out["e2e_inflation"] = {
                pol: by_pol[pol]["mean_e2e"]
                / max(results["none"][pol]["mean_e2e"], 1e-9)
                for pol in POLICIES}
        payload["families"][fam] = fam_out

    n_runs = len(results) * len(POLICIES)
    for fam in ("site_failure", "grid_trip", "curtailment"):
        f = payload["families"][fam]
        h, w = results[fam]["heron"], results[fam]["wrr_dynamollm"]
        rows.append(row(f"scenario_{fam}", us_total / n_runs,
                        f"heron drops {h['drops']:.0f} vs wrr {w['drops']:.0f}"
                        f" (absorbed {f['absorbed_vs_wrr_dynamollm']:.0f} rps"
                        f"·slots, goodput x"
                        f"{f['goodput_ratio_vs_wrr_dynamollm']:.2f})"))
    infl = payload["families"]["straggler"]["e2e_inflation"]
    rows.append(row("scenario_straggler", us_total / n_runs,
                    f"e2e inflation heron x{infl['heron']:.2f} vs "
                    f"greedy x{infl['greedy_min_latency']:.2f} "
                    f"(haircut shifts load off the slow site)"))
    save_tracker("scenarios", payload)
    return rows


def main():
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    common.emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
