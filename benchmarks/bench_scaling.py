"""Multi-pod weak-scaling efficiency — large-scale runnability evidence.

Compares each cell's per-device roofline terms on the 256-chip single-pod
vs 512-chip multi-pod mesh. The ``pod`` axis is pure DP, so ideal weak
scaling halves per-device FLOPs at fixed global shape; the ratio of
(pod1 step time) / (2 x pod2 step time) is the scaling efficiency. Cells
whose collective term GROWS cross-pod expose where the pod axis hurts
(gradient reduction now crosses the DCN/pod boundary).
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import Timer, row, save
from repro.analysis.roofline import load_table

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def run(fast: bool = True):
    rows = []
    t = Timer()
    if not os.path.isdir(DRYRUN_DIR):
        return [row("scaling", 0.0, "NO ARTIFACTS — run dryrun --both-meshes")]
    with t():
        p1 = {(x.arch, x.shape): x for x in load_table(DRYRUN_DIR, pod="pod1")}
        p2 = {(x.arch, x.shape): x for x in load_table(DRYRUN_DIR, pod="pod2")}
    effs = []
    payload = []
    for k in sorted(p1):
        if k not in p2:
            continue
        a, b = p1[k], p2[k]
        # ideal: per-device compute halves; efficiency = t1 / (2*t2) for
        # compute-dominated cells, capped at 1 for fixed-cost cells
        eff = min(a.step_time_s / max(2 * b.step_time_s, 1e-30), 1.0)
        coll_growth = b.collective_s / max(a.collective_s, 1e-30)
        effs.append(eff)
        payload.append({"arch": k[0], "shape": k[1],
                        "step_pod1_ms": a.step_time_s * 1e3,
                        "step_pod2_ms": b.step_time_s * 1e3,
                        "weak_scaling_eff": eff,
                        "collective_growth": coll_growth})
    if not effs:
        return [row("scaling", t.us, "no pod2 artifacts")]
    worst = min(payload, key=lambda p: p["weak_scaling_eff"])
    rows.append(row("multipod_weak_scaling", t.us,
                    f"median eff {np.median(effs):.2f} over {len(effs)} "
                    f"cells; worst {worst['arch']}/{worst['shape']} "
                    f"{worst['weak_scaling_eff']:.2f}"))
    save("scaling", {"cells": payload})
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
