"""Serving-engine benchmark: burst admission latency + steady-state decode.

Times a 32-request burst into one ServingEngine under the admission modes
(``serial`` — the old one-request-at-a-time path with a B=1 decode tail —
``batched`` — grouped pow-2 prefills + chunked prefill-from-cache tails —
and the ISSUE 7 configs: ``paged`` riding the batched pipeline on the
shared page pool, and ``paged_async`` with a 32-slot paged engine whose
page pool holds the whole burst in HALF the HBM bytes the 8-slot dense
cache reserves), plus the steady-state decode rate. Every config's token
stream is asserted identical to the serial anchor on every run — the
per-(seed, rid, token-index) sampling keys make streams independent of
admission interleaving, slot count, and cache layout.

``admit_s`` times the FIRST admission wave (all of its prefill work + one
shared decode step); ``drain_s`` is the whole burst including the decode
drain. Acceptance (ISSUE 7): paged_async p99 burst TTFT >= 2x better
than the PR 4 batched anchor (136 ms).

``_continuous`` drives Poisson arrivals at a sustained rate and reports
p99 TBT: batched admission does a whole wave's prefill inside one step
(stalling in-flight decodes), while async spends a bounded
``admit_token_budget`` per step — bounded p99 TBT is the claim.

Writes ``BENCH_serving.json`` at the repo root under the
``--update-tracker`` discipline (artifacts/bench/serving.json always).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import row, save_tracker
from repro.configs import smoke_config
from repro.models.api import build
from repro.serving.engine import Request, ServingEngine

ARCH = "llama3.2-1b"
BURST = 32
MAX_BATCH = 8
MAX_SEQ = 64
LENGTHS = [5, 9, 13, 17, 21, 25, 29, 30] * 4     # pow-2 buckets 4/8/16
PAGE = 16

# paged_async burst config: every request's full contract is
# ceil((len + 4 - 1)/16) pages -> 56 pages for the 32-request burst; a
# 64-page pool (1024 cache tokens) admits the whole herd at once where
# a dense 32-slot cache would reserve 32*64 = 2048 tokens.
WIDE_BATCH = BURST
WIDE_PAGES = 64


def _requests(cfg, seed=0, n_new=4):
    rng = np.random.default_rng(seed)
    now = time.perf_counter()
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=n)
                    .astype(np.int32), max_new_tokens=n_new, arrival_s=now)
            for i, n in enumerate(LENGTHS[:BURST])]


def _burst(model, params, mode: str, *, reps: int,
           max_batch: int = MAX_BATCH, **eng_kw) -> dict:
    """Admission wall time for a BURST-request thundering herd. One engine
    per mode: rep 0 pays all compilations (the serving steady state), the
    timed reps measure the admission pipeline itself."""
    cfg = model.cfg
    eng = ServingEngine(model, params, max_batch=max_batch,
                        max_seq=MAX_SEQ, admit_mode=mode, **eng_kw)
    admit_s, drain_s, calls, steps = [], [], 0, 0
    ttfts, tbts = [], []
    for rep in range(reps + 1):                     # rep 0 warms compiles
        reqs = _requests(cfg)
        calls0, steps0 = eng.metrics.prefill_calls, eng.metrics.steps
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        # first wave: one step admits max_batch requests (all of the
        # wave's prefill/extend work) + a single mode-independent decode —
        # this is the admission-bound number; later waves interleave with
        # decode drain, which drain_s captures
        eng.step()
        jax.block_until_ready(eng.cache["pos"])
        t1 = time.perf_counter()
        eng.run()
        jax.block_until_ready(eng.cache["pos"])
        t2 = time.perf_counter()
        assert all(len(r.tokens) == r.max_new_tokens for r in reqs)
        if rep:                      # exclude the compile-warmup rep's tails
            admit_s.append(t1 - t0)
            drain_s.append(t2 - t0)
            calls = eng.metrics.prefill_calls - calls0
            steps = eng.metrics.steps - steps0
            ttfts += [r.ttft for r in reqs]
            tbts += [r.tbt for r in reqs if r.tbt is not None]
    last = {r.rid: list(r.tokens) for r in reqs}
    return {"admit_s": float(np.median(admit_s)),
            "drain_s": float(np.median(drain_s)),
            "p50_ttft_s": float(np.percentile(ttfts, 50)),
            "p99_ttft_s": float(np.percentile(ttfts, 99)),
            "p99_tbt_s": float(np.percentile(tbts, 99)),
            "prefill_calls": calls, "steps": steps,    # per-burst, like calls
            "streams": last}


def _continuous(model, params, *, reps: int, n_req: int, rate_hz: float,
                mode: str, max_batch: int = MAX_BATCH, **eng_kw) -> dict:
    """Sustained Poisson arrivals: submit each request at its drawn arrival
    time, step the engine continuously, report tail latencies. One engine
    per config; rep 0 warms compiles and is excluded from the stats."""
    cfg = model.cfg
    eng = ServingEngine(model, params, max_batch=max_batch,
                        max_seq=MAX_SEQ, admit_mode=mode, **eng_kw)
    ttfts, tbts, makespans = [], [], []
    streams = {}
    for rep in range(reps + 1):
        rng = np.random.default_rng(100)            # same draw every rep
        lens = rng.integers(5, 31, size=n_req)
        gaps = rng.exponential(1.0 / rate_hz, size=n_req)
        arrivals = np.cumsum(gaps)
        reqs = [Request(rid=i, prompt=rng.integers(
                    0, cfg.vocab_size, size=int(n)).astype(np.int32),
                    max_new_tokens=8)
                for i, n in enumerate(lens)]
        t0 = time.perf_counter()
        nxt = 0
        while True:
            now = time.perf_counter() - t0
            while nxt < n_req and arrivals[nxt] <= now:
                reqs[nxt].arrival_s = t0 + arrivals[nxt]
                eng.submit(reqs[nxt])
                nxt += 1
            live = eng.step()
            if (live == 0 and not eng.waiting and not eng._pend
                    and nxt >= n_req):
                break
            if live == 0 and nxt < n_req:           # idle until next arrival
                time.sleep(max(0.0, arrivals[nxt] - (time.perf_counter()
                                                     - t0)))
        jax.block_until_ready(eng.cache["pos"])
        assert all(len(r.tokens) == r.max_new_tokens for r in reqs)
        if rep:
            ttfts += [r.ttft for r in reqs]
            tbts += [r.tbt for r in reqs if r.tbt is not None]
            makespans.append(time.perf_counter() - t0)
        streams = {r.rid: list(r.tokens) for r in reqs}
    return {"p50_ttft_s": float(np.percentile(ttfts, 50)),
            "p99_ttft_s": float(np.percentile(ttfts, 99)),
            "p50_tbt_s": float(np.percentile(tbts, 50)),
            "p99_tbt_s": float(np.percentile(tbts, 99)),
            "makespan_s": float(np.median(makespans)),
            "streams": streams}


def _steady_tokens_per_s(model, params) -> float:
    """Decode throughput with all slots live (no admission in the loop)."""
    cfg = model.cfg
    eng = ServingEngine(model, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ)
    rng = np.random.default_rng(1)
    n_steps = 30
    for i in range(MAX_BATCH):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=16).astype(np.int32),
            max_new_tokens=n_steps + 10))
    eng.step()                                      # admit + first decode
    t0 = time.perf_counter()
    for _ in range(n_steps):
        live = eng.step()
    jax.block_until_ready(eng.cache["pos"])
    dt = time.perf_counter() - t0
    assert live == MAX_BATCH, "slots retired mid-measurement"
    return n_steps * MAX_BATCH / dt


def run(fast: bool = True):
    reps = 1 if common.SMOKE else (3 if fast else 10)
    cfg = smoke_config(ARCH)
    model = build(cfg)
    params = model.init_params(jax.random.key(0))

    res = {
        "serial": _burst(model, params, "serial", reps=reps),
        "batched": _burst(model, params, "batched", reps=reps),
        "paged": _burst(model, params, "batched", reps=reps,
                        paged=True, page_size=PAGE),
        "paged_async": _burst(model, params, "async", reps=reps,
                              paged=True, page_size=PAGE,
                              max_batch=WIDE_BATCH, num_pages=WIDE_PAGES,
                              admit_token_budget=10 ** 6),
    }
    # equivalence is part of the bench contract, not just the test suite:
    # every config must reproduce the serial anchor's streams bit-exactly
    anchor = res["serial"].pop("streams")
    for name in ("batched", "paged", "paged_async"):
        assert res[name].pop("streams") == anchor, \
            f"{name} token streams diverged from the serial anchor"
    tok_s = _steady_tokens_per_s(model, params)

    cont = {
        "batched": _continuous(model, params, reps=reps, n_req=48,
                               rate_hz=40.0, mode="batched"),
        "async_paged": _continuous(model, params, reps=reps, n_req=48,
                                   rate_hz=40.0, mode="async",
                                   paged=True, page_size=PAGE,
                                   admit_token_budget=16),
    }
    assert cont["batched"].pop("streams") == cont["async_paged"].pop(
        "streams"), "continuous batched vs async_paged streams diverged"

    sr, br, pa = res["serial"], res["batched"], res["paged_async"]
    payload = {
        "arch": ARCH, "burst": BURST, "max_batch": MAX_BATCH,
        "max_seq": MAX_SEQ, "reps": reps,
        "serial": sr, "batched": br,
        "paged": res["paged"], "paged_async": pa,
        "paged_async_config": {
            "max_batch": WIDE_BATCH, "num_pages": WIDE_PAGES,
            "page_size": PAGE, "pool_tokens": WIDE_PAGES * PAGE,
            "dense_equiv_tokens": WIDE_BATCH * MAX_SEQ,
        },
        "admit_speedup": sr["admit_s"] / max(br["admit_s"], 1e-9),
        "dispatch_ratio": sr["prefill_calls"] / max(br["prefill_calls"], 1),
        "paged_ttft_speedup": (br["p99_ttft_s"]
                               / max(pa["p99_ttft_s"], 1e-9)),
        "continuous": cont,
        "steady_tokens_per_s": tok_s,
    }
    save_tracker("serving", payload)

    rows = [
        row("serve_admit_serial", sr["admit_s"] * 1e6,
            f"first {MAX_BATCH}-req wave of a {BURST}-req burst; "
            f"{sr['prefill_calls']} dispatches/burst, "
            f"p99 TTFT {sr['p99_ttft_s']*1e3:.0f} ms"),
        row("serve_admit_batched", br["admit_s"] * 1e6,
            f"first wave {payload['admit_speedup']:.1f}x faster; "
            f"{br['prefill_calls']} dispatches/burst "
            f"({payload['dispatch_ratio']:.1f}x fewer), "
            f"p99 TTFT {br['p99_ttft_s']*1e3:.0f} ms"),
        row("serve_admit_paged", res["paged"]["admit_s"] * 1e6,
            f"batched pipeline on the page pool, p99 TTFT "
            f"{res['paged']['p99_ttft_s']*1e3:.0f} ms"),
        row("serve_admit_paged_async", pa["admit_s"] * 1e6,
            f"{WIDE_BATCH} slots / {WIDE_PAGES * PAGE} pool tokens "
            f"({WIDE_PAGES * PAGE / (WIDE_BATCH * MAX_SEQ):.0%} of dense), "
            f"p99 TTFT {pa['p99_ttft_s']*1e3:.0f} ms "
            f"({payload['paged_ttft_speedup']:.1f}x vs batched)"),
        row("serve_continuous_batched", cont["batched"]["p99_tbt_s"] * 1e6,
            f"Poisson 40/s: p99 TBT {cont['batched']['p99_tbt_s']*1e3:.1f} "
            f"ms, p99 TTFT {cont['batched']['p99_ttft_s']*1e3:.0f} ms"),
        row("serve_continuous_async", cont["async_paged"]["p99_tbt_s"] * 1e6,
            f"Poisson 40/s: p99 TBT "
            f"{cont['async_paged']['p99_tbt_s']*1e3:.1f} ms "
            f"({cont['batched']['p99_tbt_s']/max(cont['async_paged']['p99_tbt_s'], 1e-9):.1f}x vs batched), "
            f"p99 TTFT {cont['async_paged']['p99_ttft_s']*1e3:.0f} ms"),
        row("serve_steady_decode", 1e6 / max(tok_s, 1e-9),
            f"{tok_s:.0f} tok/s steady-state at B={MAX_BATCH}"),
    ]
    return rows


def main():
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    common.emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
