"""Serving-engine benchmark: burst admission latency + steady-state decode.

Times a 32-request burst into one ServingEngine under both admission modes
(``serial`` — the old one-request-at-a-time path with a B=1 decode tail —
vs ``batched`` — grouped pow-2 prefills + chunked prefill-from-cache
tails), plus the steady-state decode rate, and verifies the two modes'
token streams are identical on every run. ``admit_s`` times the FIRST
max_batch-sized admission wave (all of its prefill work + one shared
decode step); ``drain_s`` is the whole burst including the decode drain
that later waves interleave with. Acceptance (ISSUE 4): the burst admits
with >= 4x fewer compiled dispatches and lower admission wall time.

Writes ``BENCH_serving.json`` at the repo root under the
``--update-tracker`` discipline (artifacts/bench/serving.json always).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import row, save_tracker
from repro.configs import smoke_config
from repro.models.api import build
from repro.serving.engine import Request, ServingEngine

ARCH = "llama3.2-1b"
BURST = 32
MAX_BATCH = 8
MAX_SEQ = 64
LENGTHS = [5, 9, 13, 17, 21, 25, 29, 30] * 4     # pow-2 buckets 4/8/16


def _requests(cfg, seed=0, n_new=4):
    rng = np.random.default_rng(seed)
    now = time.perf_counter()
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=n)
                    .astype(np.int32), max_new_tokens=n_new, arrival_s=now)
            for i, n in enumerate(LENGTHS[:BURST])]


def _burst(model, params, mode: str, *, reps: int) -> dict:
    """Admission wall time for a BURST-request thundering herd. One engine
    per mode: rep 0 pays all compilations (the serving steady state), the
    timed reps measure the admission pipeline itself."""
    cfg = model.cfg
    eng = ServingEngine(model, params, max_batch=MAX_BATCH,
                        max_seq=MAX_SEQ, admit_mode=mode)
    admit_s, drain_s, calls, steps = [], [], 0, 0
    ttfts, tbts = [], []
    for rep in range(reps + 1):                     # rep 0 warms compiles
        reqs = _requests(cfg)
        calls0, steps0 = eng.metrics.prefill_calls, eng.metrics.steps
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        # first wave: one step admits max_batch requests (all of the
        # wave's prefill/extend work) + a single mode-independent decode —
        # this is the admission-bound number; later waves interleave with
        # decode drain, which drain_s captures
        eng.step()
        jax.block_until_ready(eng.cache["pos"])
        t1 = time.perf_counter()
        eng.run()
        jax.block_until_ready(eng.cache["pos"])
        t2 = time.perf_counter()
        assert all(len(r.tokens) == r.max_new_tokens for r in reqs)
        if rep:                      # exclude the compile-warmup rep's tails
            admit_s.append(t1 - t0)
            drain_s.append(t2 - t0)
            calls = eng.metrics.prefill_calls - calls0
            steps = eng.metrics.steps - steps0
            ttfts += [r.ttft for r in reqs]
            tbts += [r.tbt for r in reqs if r.tbt is not None]
    last = {r.rid: list(r.tokens) for r in reqs}
    return {"admit_s": float(np.median(admit_s)),
            "drain_s": float(np.median(drain_s)),
            "p50_ttft_s": float(np.percentile(ttfts, 50)),
            "p99_ttft_s": float(np.percentile(ttfts, 99)),
            "p99_tbt_s": float(np.percentile(tbts, 99)),
            "prefill_calls": calls, "steps": steps,    # per-burst, like calls
            "streams": last}


def _steady_tokens_per_s(model, params) -> float:
    """Decode throughput with all slots live (no admission in the loop)."""
    cfg = model.cfg
    eng = ServingEngine(model, params, max_batch=MAX_BATCH, max_seq=MAX_SEQ)
    rng = np.random.default_rng(1)
    n_steps = 30
    for i in range(MAX_BATCH):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=16).astype(np.int32),
            max_new_tokens=n_steps + 10))
    eng.step()                                      # admit + first decode
    t0 = time.perf_counter()
    for _ in range(n_steps):
        live = eng.step()
    jax.block_until_ready(eng.cache["pos"])
    dt = time.perf_counter() - t0
    assert live == MAX_BATCH, "slots retired mid-measurement"
    return n_steps * MAX_BATCH / dt


def run(fast: bool = True):
    reps = 3 if fast else 10
    cfg = smoke_config(ARCH)
    model = build(cfg)
    params = model.init_params(jax.random.key(0))

    res = {mode: _burst(model, params, mode, reps=reps)
           for mode in ("serial", "batched")}
    # equivalence is part of the bench contract, not just the test suite
    assert res["serial"]["streams"] == res["batched"]["streams"], \
        "serial vs batched token streams diverged"
    for m in res.values():
        m.pop("streams")
    tok_s = _steady_tokens_per_s(model, params)

    sr, br = res["serial"], res["batched"]
    payload = {
        "arch": ARCH, "burst": BURST, "max_batch": MAX_BATCH,
        "max_seq": MAX_SEQ, "reps": reps,
        "serial": sr, "batched": br,
        "admit_speedup": sr["admit_s"] / max(br["admit_s"], 1e-9),
        "dispatch_ratio": sr["prefill_calls"] / max(br["prefill_calls"], 1),
        "steady_tokens_per_s": tok_s,
    }
    save_tracker("serving", payload)

    rows = [
        row("serve_admit_serial", sr["admit_s"] * 1e6,
            f"first {MAX_BATCH}-req wave of a {BURST}-req burst; "
            f"{sr['prefill_calls']} dispatches/burst, "
            f"p99 TTFT {sr['p99_ttft_s']*1e3:.0f} ms"),
        row("serve_admit_batched", br["admit_s"] * 1e6,
            f"first wave {payload['admit_speedup']:.1f}x faster; "
            f"{br['prefill_calls']} dispatches/burst "
            f"({payload['dispatch_ratio']:.1f}x fewer), "
            f"p99 TTFT {br['p99_ttft_s']*1e3:.0f} ms"),
        row("serve_steady_decode", 1e6 / max(tok_s, 1e-9),
            f"{tok_s:.0f} tok/s steady-state at B={MAX_BATCH}"),
    ]
    return rows


def main():
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    common.emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
