"""§5.2 configuration stickiness — R_L sweep.

The paper: R_L down to 3% costs no significant E2E/power inflation;
below 3% latency inflates. We sweep r_frac over a drought-crossing window
and report the 95th-pctile of per-slot mean E2E and mean power.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import Timer, row, save
from repro.configs import PAPER_MODEL
from repro.core.lookup import build_table
from repro.core.planner_l import SiteSpec
from repro.data.wind import make_default_fleet
from repro.data.workload import make_trace
from repro.power.model import H100_DGX, SUPERPOD_GPUS, SUPERPOD_PEAK_MW
from repro.sim.cluster import simulate_week

GRID = dict(load_grid=(0.25, 1.0, 4.0, 16.0), freq_grid=(1.4, 2.0))


def run(fast: bool = True, trace_name: str = "coding"):
    rows = []
    t = Timer()
    trace = make_trace(trace_name, base_rps=1.0, seed=11)
    table = build_table(PAPER_MODEL, trace, H100_DGX, **GRID)
    fleet = make_default_fleet(seed=7)
    sites, thr = [], []
    for s in fleet.sites:
        pods = int(s.percentile_mw(20.0) // SUPERPOD_PEAK_MW)
        sites.append(SiteSpec(s.name, pods * SUPERPOD_GPUS))
        thr.append(s.percentile_mw(20.0))
    power = np.minimum(fleet.week(), np.array(thr)[:, None])
    sl = slice(480, 480 + (12 if common.SMOKE else (48 if fast else 672)))
    arr = trace.class_arrivals(multiplier=600.0)[:, sl] / (15 * 60)
    pw = power[:, sl]

    out = {}
    with t():
        for rf in (0.30, 0.03, 0.01):
            wk = simulate_week("heron", table, sites, pw, arr, r_frac=rf)
            e2e = wk.mean_e2e()
            out[rf] = {
                "e2e_p95": float(np.percentile(e2e[e2e > 0], 95)),
                "power_mean_mw": float(wk.power().mean() / 1e6),
                "reconfigs_total": int(sum(s.reconfigs for s in wk.slots)),
                "dropped": float(wk.drops().sum()),
            }
    base = out[0.30]["e2e_p95"]
    infl3 = out[0.03]["e2e_p95"] / base - 1
    infl1 = out[0.01]["e2e_p95"] / base - 1
    rows.append(row(f"s52_stickiness_{trace_name}", t.us,
                    f"E2E p95 inflation: {infl3:+.1%} @R_L=3%, "
                    f"{infl1:+.1%} @R_L=1% (paper: flat to 3%)"))
    save(f"stickiness_{trace_name}", {str(k): v for k, v in out.items()})
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
