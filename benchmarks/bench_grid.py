"""Grid-interactive A/B — Heron vs DR-Heron vs XWind (ISSUE 10).

Three scenario families on the healthy-power window, with site power
scaled down so the economic signals actually bind:

  * ``price_spike`` — the biggest site's electricity price AND grid
    carbon ramp to 4x for half the window. Plain Heron keeps serving
    through the spike and eats the bill; DR-Heron sheds the spiked
    site's effective power (demand response) and XWind re-plans under
    the ``"cost"`` objective with the announced prices as site rates.
    Reported: goodput, $/kilo-request and gCO2/request per policy, and
    DR-Heron's ratios vs Heron — the acceptance gate is DR-Heron at or
    below Heron on BOTH $/req and carbon/req within a 2% goodput loss.
  * ``curtailment`` — a 50% fleet-wide curtailment order; DR-Heron's
    pre-drain haircut sheds load before the brownout path has to.
  * ``ride_through`` — a depth-0.98 GridTrip brownout on the biggest
    site, Heron with and without a pre-charged ``BatteryBank``: the
    battery arm must serve strictly more than the batteryless arm.

Writes ``BENCH_grid.json`` at the repo root under the
``--update-tracker`` discipline (artifacts/bench/grid.json always).
"""
from __future__ import annotations

from benchmarks import common
from benchmarks.common import Timer, row, save_tracker
from repro.power.grid import BatteryBank
from repro.sim.cluster import simulate_week
from repro.sim.policy import make_policy
from repro.sim.scenarios import (CarbonRamp, Curtailment, GridTrip,
                                 PriceSpike, ScenarioEngine)
from repro.sim.testbed import paper_grid

POLICIES = ("heron", "dr_heron", "xwind")
START = 200                   # healthy-power window (events are the signal)
VOLUME = 60.0
ARRIVAL_X = 4.0               # stress volume on the window
POWER_SCALE = 0.04            # shrink caps so price shedding binds
TRIP_POWER_SCALE = 0.1        # ride-through arm: trip must bind, not bill
SPIKE = 4.0                   # price/carbon multiplier on site 0
DR_MIN_KEEP = 0.1
SEED = 5


def _metrics(wk) -> dict:
    srv = max(float(wk.goodput().sum()), 1e-9)
    cost = float(wk.cost_usd().sum())
    carbon = float(wk.carbon_g().sum())
    return {"goodput": srv, "drops": float(wk.drops().sum()),
            "cost_usd": cost, "carbon_g": carbon,
            "usd_per_kreq": cost / srv * 1e3, "g_per_req": carbon / srv}


def run(fast: bool = True):
    rows = []
    t = Timer()
    slots = 4 if common.SMOKE else (8 if fast else 16)
    q = max(slots // 4, 1)
    g = paper_grid("coding", multiplier=VOLUME)
    table, sites = g.table, g.sites
    pw = g.power_mw[:, START:START + slots]
    ar = g.arrivals_rps[:, START:START + slots] * ARRIVAL_X
    S = len(sites)

    families = {
        "price_spike": [PriceSpike(magnitude=SPIKE, start=q, duration=2 * q,
                                   sites=(0,)),
                        CarbonRamp(magnitude=SPIKE, start=q, duration=2 * q,
                                   sites=(0,))],
        "curtailment": [Curtailment(frac=0.5, start=q, duration=2 * q)],
    }

    payload = {"slots": slots, "start": START, "volume": VOLUME,
               "arrival_x": ARRIVAL_X, "power_scale": POWER_SCALE,
               "spike": SPIKE, "seed": SEED, "families": {}}
    with t():
        pws = pw * POWER_SCALE
        for fam, events in families.items():
            by_pol = {}
            for name in POLICIES:
                pol = make_policy(name, table, sites,
                                  dr_min_keep=DR_MIN_KEEP)
                wk = simulate_week(pol, table, sites, pws, ar, seed=SEED,
                                   scenario=ScenarioEngine(events,
                                                           seed=SEED))
                by_pol[name] = _metrics(wk)
            h, d = by_pol["heron"], by_pol["dr_heron"]
            payload["families"][fam] = {
                "policies": by_pol,
                "dr_goodput_ratio": d["goodput"] / h["goodput"],
                "dr_usd_ratio": d["usd_per_kreq"] / h["usd_per_kreq"],
                "dr_carbon_ratio": d["g_per_req"] / h["g_per_req"],
            }

        # ride-through: same trip, battery vs batteryless Heron
        pwt = pw * TRIP_POWER_SCALE
        trip = [GridTrip(site=0, start=slots // 2, duration=2, depth=0.98)]
        batt = BatteryBank.sized(S, capacity_mwh=3.0, charge_rate_mw=6.0,
                                 discharge_rate_mw=6.0, soc_frac=1.0)
        arms = {}
        for arm, bank in (("batteryless", None), ("battery", batt)):
            wk = simulate_week("heron", table, sites, pwt, ar, seed=SEED,
                               scenario=ScenarioEngine(trip, seed=SEED),
                               battery=bank)
            arms[arm] = _metrics(wk)
        payload["families"]["ride_through"] = {
            "arms": arms,
            "battery_goodput_gain": (arms["battery"]["goodput"]
                                     - arms["batteryless"]["goodput"]),
        }
    us_total = t.us
    n_runs = len(families) * len(POLICIES) + 2

    for fam in families:
        f = payload["families"][fam]
        h = f["policies"]["heron"]
        d = f["policies"]["dr_heron"]
        x = f["policies"]["xwind"]
        rows.append(row(
            f"grid_{fam}", us_total / n_runs,
            f"$/kreq heron {h['usd_per_kreq']:.1f} dr "
            f"{d['usd_per_kreq']:.1f} xwind {x['usd_per_kreq']:.1f} | "
            f"g/req heron {h['g_per_req']:.1f} dr {d['g_per_req']:.1f} "
            f"(dr goodput x{f['dr_goodput_ratio']:.3f})"))
    rt = payload["families"]["ride_through"]
    rows.append(row(
        "grid_ride_through", us_total / n_runs,
        f"goodput battery {rt['arms']['battery']['goodput']:.0f} vs "
        f"batteryless {rt['arms']['batteryless']['goodput']:.0f} "
        f"(+{rt['battery_goodput_gain']:.0f} rps*slots)"))
    save_tracker("grid", payload)
    return rows


def main():
    import argparse

    from benchmarks import common
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--update-tracker", action="store_true")
    args = ap.parse_args()
    common.UPDATE_TRACKER = args.update_tracker
    common.emit(run(fast=not args.full))


if __name__ == "__main__":
    main()
