"""Figs 6/7 + §2.3 — wind complementarity and predictability."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, row, save
from repro.core.predictor import (SeriesPredictor, autocorr_by_granularity,
                                  autocorrelation)
from repro.data.wind import lag1_autocorr, make_default_fleet
from repro.data.workload import make_trace


def run(fast: bool = True):
    rows = []
    t = Timer()
    fleet = make_default_fleet(seed=7)

    with t():
        site_ac = {s.name: lag1_autocorr(s.series_mw) for s in fleet.sites}
        agg_cov = fleet.aggregate_cov()
        site_covs = {s.name: fleet.site_cov(i)
                     for i, s in enumerate(fleet.sites)}
        reduction = 1.0 - agg_cov / np.mean(list(site_covs.values()))
    rows.append(row("fig6_complementarity", t.us,
                    f"agg CoV {agg_cov:.3f} (paper 0.475), "
                    f"{reduction:.0%} below mean single-site"))
    rows.append(row("s231_wind_autocorr", 0.0,
                    f"lag-1 mean {np.mean(list(site_ac.values())):.3f} "
                    "(paper 0.991)"))

    with t():
        pred_err = {}
        for kind in ("persistence", "ar2"):
            errs = [np.median(SeriesPredictor(s.series_mw, kind=kind).errors())
                    for s in fleet.sites]
            pred_err[kind] = float(np.mean(errs))
    rows.append(row("s231_predictors", t.us,
                    f"median rel-err persistence {pred_err['persistence']:.3f}"
                    f" / ar2 {pred_err['ar2']:.3f}"))

    with t():
        wl_ac = {}
        for name in ("coding", "conversation"):
            tr = make_trace(name, base_rps=1.0, seed=11)
            wl_ac[name] = autocorr_by_granularity(
                tr.arrivals.astype(float), [1, 2, 4])
    rows.append(row("fig7_workload_autocorr", t.us,
                    f"15-min lag-1: coding {wl_ac['coding'][1]:.3f} / "
                    f"conversation {wl_ac['conversation'][1]:.3f} "
                    "(paper >0.994)"))

    save("complementarity", {"site_autocorr": site_ac, "agg_cov": agg_cov,
                             "site_covs": site_covs,
                             "predictor_err": pred_err,
                             "workload_autocorr": {
                                 k: {str(w): v for w, v in d.items()}
                                 for k, d in wl_ac.items()}})
    return rows


def main():
    from benchmarks.common import emit
    emit(run(fast=True))


if __name__ == "__main__":
    main()
